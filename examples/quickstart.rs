//! Quickstart: the Table I relational operators on a small in-memory
//! table — the 30-line tour of the local API.
//!
//!     cargo run --release --example quickstart

use rylon::ops::select::CmpOp;
use rylon::prelude::*;

fn main() -> Result<()> {
    // Build two small tables (mirrors the PyCylon sequential example).
    let orders = Table::from_columns(vec![
        ("order_id", Column::from_i64(vec![1, 2, 3, 4, 5, 6])),
        ("user", Column::from_i64(vec![10, 11, 10, 12, 11, 10])),
        (
            "amount",
            Column::from_f64(vec![9.5, 120.0, 33.0, 5.0, 78.0, 61.5]),
        ),
    ])?;
    let users = Table::from_columns(vec![
        ("user", Column::from_i64(vec![10, 11, 13])),
        ("name", Column::from_str(&["ada", "grace", "edsger"])),
    ])?;

    // Select: orders above 20.
    let big = select(
        &orders,
        &rylon::ops::select::Predicate::cmp("amount", CmpOp::Gt, 20.0),
    )?;
    println!("orders over 20:\n{}", big.pretty(10));

    // Join: attach user names (inner, sort algorithm — Cylon's default).
    let joined = join(&big, &users, &JoinOptions::inner("user", "user"))?;
    println!("joined:\n{}", joined.pretty(10));

    // Project: drop the duplicate key column.
    let slim = project(&joined, &["order_id", "name", "amount"])?;

    // GroupBy: spend per user.
    let spend = groupby(
        &slim,
        &GroupByOptions::new(
            &["name"],
            vec![Agg::sum("amount"), Agg::count("amount")],
        ),
    )?;
    println!("spend per user:\n{}", spend.pretty(10));

    // OrderBy + set ops round out Table I.
    let sorted = orderby(&spend, &[SortKey::desc("sum_amount")])?;
    println!("top spender: {}", sorted.row(0)[0].render());

    let a = project(&orders, &["user"])?;
    let b = project(&users, &["user"])?;
    println!(
        "distinct users in both: {} | only one side: {}",
        intersect(&a, &b)?.num_rows(),
        difference(&a, &b)?.num_rows(),
    );
    Ok(())
}
