//! Distributed join on both fabrics — the paper's §V-1 experiment in
//! miniature, plus the simulated strong-scaling sweep that regenerates
//! Fig 10's rylon series.
//!
//!     cargo run --release --example distributed_join [total_rows]

use rylon::dist::{dist_join, Cluster, DistConfig};
use rylon::io::datagen::{gen_partition, DataGenSpec};
use rylon::net::CostModel;
use rylon::ops::join::JoinOptions;
use rylon::prelude::*;

fn main() -> Result<()> {
    let total_rows: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);

    // 1. Real rank threads (correctness-grade execution).
    let world = 4;
    let cluster = Cluster::new(DistConfig::threads(world))?;
    let timer = rylon::metrics::Timer::start();
    let outs = cluster.run(|ctx| {
        let l = gen_partition(
            &DataGenSpec::paper_scaling(total_rows, 1),
            ctx.rank,
            ctx.size,
        )?;
        let r = gen_partition(
            &DataGenSpec::paper_scaling(total_rows, 2),
            ctx.rank,
            ctx.size,
        )?;
        dist_join(ctx, &l, &r, &JoinOptions::inner("id", "id"))
    })?;
    let matches: usize = outs.iter().map(|t| t.num_rows()).sum();
    println!(
        "threads fabric: {world} ranks joined {total_rows}×2 rows -> {matches} matches in {:.3}s",
        timer.seconds()
    );

    // 2. Simulated cluster (the paper's 10-node/40-core testbed model):
    //    strong scaling sweep, makespan per parallelism.
    println!("\nsim fabric strong scaling (paper Fig 10 shape):");
    println!("{:>6} {:>14} {:>10}", "p", "makespan", "speedup");
    let mut t1 = None;
    for p in [1usize, 2, 4, 8, 16, 32, 64, 128, 160] {
        let cluster =
            Cluster::new(DistConfig::sim(p, CostModel::default()))?;
        cluster.run(|ctx| {
            let l = gen_partition(
                &DataGenSpec::paper_scaling(total_rows, 1),
                ctx.rank,
                ctx.size,
            )?;
            let r = gen_partition(
                &DataGenSpec::paper_scaling(total_rows, 2),
                ctx.rank,
                ctx.size,
            )?;
            dist_join(ctx, &l, &r, &JoinOptions::inner("id", "id"))
        })?;
        let mk = cluster.makespan().unwrap();
        let t1v = *t1.get_or_insert(mk);
        println!("{p:>6} {:>13.4}s {:>9.2}x", mk, t1v / mk);
    }
    println!(
        "\nExpect near-linear speedup early, then a communication-bound \
         plateau — the paper's §V-1 observation."
    );
    Ok(())
}
