//! SQL over distributed tables + the RYF columnar file format — the
//! paper's §II usability claim ("SQL interfaces can further enhance
//! usability") exercised end to end: generate → write RYF → per-rank
//! partitioned reads → the same SQL text runs locally and SPMD.
//!
//!     cargo run --release --example sql_analytics

use rylon::dist::{Cluster, DistConfig};
use rylon::io::datagen::{gen_table, DataGenSpec, KeyDist};
use rylon::io::ryf::{read_ryf, read_ryf_partition, write_ryf};
use rylon::pipeline::Env;
use rylon::prelude::*;
use rylon::sql::{execute_dist, execute_local};

const QUERY: &str = "SELECT id, SUM(d0) AS total, COUNT(d0) \
                     FROM events GROUP BY id ORDER BY total DESC LIMIT 8";

fn main() -> Result<()> {
    let dir = std::env::temp_dir().join("rylon_sql_example");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("events.ryf");

    // Zipf-skewed event stream: a few hot ids dominate.
    let events = gen_table(&DataGenSpec {
        rows: 200_000,
        payload_cols: 1,
        key_dist: KeyDist::Zipf {
            domain: 1000,
            s: 1.2,
        },
        seed: 31,
    })?;
    write_ryf(&events, &path, 16_384)?;
    println!(
        "wrote {} rows to {} ({} row groups)",
        events.num_rows(),
        path.display(),
        rylon::io::ryf::read_ryf_footer(&path)?.len()
    );

    // Local execution.
    let mut env = Env::new();
    env.insert("events".to_string(), read_ryf(&path)?);
    let local = execute_local(QUERY, &env)?;
    println!("\nlocal result:\n{}", local.pretty(8));

    // Distributed execution: each rank reads its share of row groups.
    let cluster = Cluster::new(DistConfig::threads(4))?;
    let outs = cluster.run(|ctx| {
        let part = read_ryf_partition(&path, ctx.rank, ctx.size)?;
        let mut env = Env::new();
        env.insert("events".to_string(), part);
        execute_dist(ctx, QUERY, &env)
    })?;
    // Ranks hold disjoint ranges of the global ORDER BY; merge + trim.
    let merged = Table::concat_all(outs[0].schema(), &outs)?;
    let merged = rylon::ops::orderby(
        &merged,
        &[SortKey::desc("total")],
    )?
    .head(8);
    println!("distributed result (4 ranks):\n{}", merged.pretty(8));

    // The two paths must agree. Totals are f64 sums folded in a
    // different order distributed vs local, so compare ids exactly and
    // totals to relative tolerance (not bitwise).
    assert_eq!(local.num_rows(), merged.num_rows());
    for i in 0..local.num_rows() {
        assert_eq!(
            local.row(i)[0],
            merged.row(i)[0],
            "rank order diverged at row {i}"
        );
        let a = local.row(i)[1].as_f64().unwrap();
        let b = merged.row(i)[1].as_f64().unwrap();
        assert!(
            (a - b).abs() <= 1e-9 * a.abs().max(1.0),
            "total diverged at row {i}: {a} vs {b}"
        );
    }
    println!("local == distributed (ids exact, totals to 1e-9) ✓");
    Ok(())
}
