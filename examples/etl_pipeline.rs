//! END-TO-END DRIVER (DESIGN.md §6, EXPERIMENTS.md §E2E): the full
//! data-engineering workload the paper motivates, across all three
//! layers:
//!
//!   CSV on disk → distributed ingest → select → join (fact ⋈ dim) →
//!   groupby → global sort → **table→tensor featurize through the AOT
//!   PJRT artifact** (L2/L1) → ML-ready tensor + stats.
//!
//! It reports rows, per-stage seconds, shuffle bytes, wall time, and the
//! paper's headline metric (distributed-join throughput), then
//! cross-checks the PJRT featurize against the native implementation.
//!
//!     make artifacts && cargo run --release --example etl_pipeline [rows]

use rylon::dist::{Cluster, DistConfig};
use rylon::io::csv::{read_csv, write_csv, CsvOptions};
use rylon::io::datagen::{gen_table, DataGenSpec, KeyDist};
use rylon::metrics::{Phases, Timer};
use rylon::ops::groupby::{Agg, GroupByOptions};
use rylon::ops::join::JoinOptions;
use rylon::ops::orderby::SortKey;
use rylon::pipeline::{Env, Pipeline};
use rylon::prelude::*;
use rylon::runtime::{FeaturizeKernel, Runtime};
use rylon::util::fmt::{human_bytes, human_count};

fn main() -> Result<()> {
    let rows: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(500_000);
    let world = 4;
    let dir = std::env::temp_dir().join("rylon_etl_example");
    std::fs::create_dir_all(&dir)?;

    // ---- 1. Produce the "raw data lake": CSV files on disk. -------------
    println!("[1/5] generating {} fact rows + dim table as CSV…", human_count(rows as u64));
    let fact_path = dir.join("fact.csv");
    let dim_path = dir.join("dim.csv");
    write_csv(
        &gen_table(&DataGenSpec::paper_scaling(rows, 0xE71))?,
        &fact_path,
        &CsvOptions::default(),
    )?;
    write_csv(
        &gen_table(&DataGenSpec {
            rows: (rows / 20).max(1),
            payload_cols: 1,
            key_dist: KeyDist::Sequential,
            seed: 0xD1,
        })?,
        &dim_path,
        &CsvOptions::default(),
    )?;

    // ---- 2. Distributed ETL over the cluster. ---------------------------
    println!("[2/5] running distributed ETL on {world} rank threads…");
    let pipeline = Pipeline::new()
        .select("d0 > -50")? // cheap row filter near the source
        .join("dim", JoinOptions::inner("id", "id"))
        .groupby(GroupByOptions::new(
            &["id"],
            vec![Agg::sum("d1"), Agg::mean("d2"), Agg::count("d1")],
        ))
        .orderby(vec![SortKey::asc("id")])
        .rebalance();

    let wall = Timer::start();
    let cluster = Cluster::new(DistConfig::threads(world))?;
    let fact = read_csv(&fact_path, &CsvOptions::default())?;
    let dim = read_csv(&dim_path, &CsvOptions::default())?;
    let outs = cluster.run(|ctx| {
        // Block-partition the CSVs across ranks (each rank reads its
        // slice; with a parallel FS each rank would read its own file).
        let slice = |t: &Table| {
            let n = t.num_rows();
            let base = n / ctx.size;
            let extra = n % ctx.size;
            let my = base + (ctx.rank < extra) as usize;
            let off = base * ctx.rank + ctx.rank.min(extra);
            t.slice(off, my)
        };
        let mut env = Env::new();
        env.insert("dim".to_string(), slice(&dim));
        pipeline.run_dist(ctx, &slice(&fact), &env)
    })?;
    let wall_s = wall.seconds();

    let mut phases = Phases::new();
    let mut result_rows = 0usize;
    for (t, p) in &outs {
        phases.merge(p);
        result_rows += t.num_rows();
    }
    println!(
        "      {} result rows in {wall_s:.3}s wall; shuffle bytes {}",
        human_count(result_rows as u64),
        human_bytes(cluster.bytes_sent()),
    );
    println!("      per-stage seconds (summed over ranks): {}", phases.to_json().to_string());
    // Headline metric, paper-style: joined rows per second.
    println!(
        "      headline: {:.1}M input rows/s through the full pipeline",
        rows as f64 / wall_s / 1e6
    );

    // ---- 3. Gather the (small) result and bridge to tensors. ------------
    println!("[3/5] gathering result + featurizing via the AOT artifact…");
    let parts: Vec<Table> = outs.iter().map(|(t, _)| t.clone()).collect();
    let result = Table::concat_all(parts[0].schema(), &parts)?;
    let sum = result.column_by_name("sum_d1")?.cast_f64()?;
    let mean = result.column_by_name("mean_d2")?.cast_f64()?;
    let cnt = result.column_by_name("count_d1")?.cast_f64()?;
    let n = sum.len();
    let mut x = Vec::with_capacity(n * 3);
    for i in 0..n {
        x.push(sum[i] as f32);
        x.push(mean[i] as f32);
        x.push(cnt[i] as f32);
    }

    let rt = Runtime::open("artifacts").ok();
    let (feats, via) = match &rt {
        Some(rt) => (FeaturizeKernel::new(rt).run(&x, n, 3)?, "pjrt"),
        None => (FeaturizeKernel::native().run(&x, n, 3)?, "native (run `make artifacts` for the PJRT path)"),
    };
    println!(
        "      tensor: {}×{} f32 via {via}; column means {:?}",
        feats.rows, feats.cols, feats.mean
    );

    // ---- 4. Cross-check PJRT vs native numerics. -------------------------
    println!("[4/5] cross-checking PJRT output against native…");
    let native = FeaturizeKernel::native().run(&x, n, 3)?;
    let mut max_abs = 0f32;
    for (a, b) in feats.features.iter().zip(&native.features) {
        max_abs = max_abs.max((a - b).abs());
    }
    println!("      max |pjrt - native| = {max_abs:e}");
    assert!(max_abs < 1e-3, "bridge mismatch");

    // ---- 5. Hand off: write the ML-ready matrix. -------------------------
    let out_path = dir.join("features.csv");
    let feat_table = Table::from_columns(vec![
        (
            "f0",
            Column::from_f64(
                (0..n).map(|i| feats.features[i * 3] as f64).collect(),
            ),
        ),
        (
            "f1",
            Column::from_f64(
                (0..n).map(|i| feats.features[i * 3 + 1] as f64).collect(),
            ),
        ),
        (
            "f2",
            Column::from_f64(
                (0..n).map(|i| feats.features[i * 3 + 2] as f64).collect(),
            ),
        ),
    ])?;
    write_csv(&feat_table, &out_path, &CsvOptions::default())?;
    println!(
        "[5/5] wrote ML-ready features to {} — done.",
        out_path.display()
    );
    Ok(())
}
