//! The table→tensor bridge in isolation (paper Fig 1 / §IV "to_numpy"):
//! load the AOT featurize artifact, run it on a table's numeric columns
//! through PJRT, compare against the native implementation, and time
//! both call paths.
//!
//!     make artifacts && cargo run --release --example tensor_bridge

use rylon::bench_harness::{measure, BenchOpts};
use rylon::io::datagen::{gen_table, DataGenSpec};
use rylon::prelude::*;
use rylon::runtime::{FeaturizeKernel, HashKernel, Runtime};

fn main() -> Result<()> {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("{e}\n(this example needs `make artifacts`)");
            std::process::exit(1);
        }
    };
    println!("artifacts loaded:");
    for a in rt.artifacts() {
        println!("  {:28} kind={}", a.name, a.kind);
    }

    // A table whose numeric columns become the feature matrix. The
    // featurize artifact variant r4096×c4 serves exactly 4096 rows.
    let rows = 4096usize;
    let t = gen_table(&DataGenSpec {
        rows,
        payload_cols: 4,
        key_dist: rylon::io::datagen::KeyDist::Sequential,
        seed: 9,
    })?;
    let cols = ["d0", "d1", "d2", "d3"];
    let mut x = vec![0f32; rows * cols.len()];
    for (c, name) in cols.iter().enumerate() {
        let v = t.column_by_name(name)?.f64_values();
        for r in 0..rows {
            x[r * cols.len() + c] = v[r] as f32;
        }
    }

    // PJRT vs native numerics.
    let aot = FeaturizeKernel::new(&rt);
    assert!(aot.is_aot(rows, cols.len()), "expected AOT artifact");
    let a = aot.run(&x, rows, cols.len())?;
    let b = FeaturizeKernel::native().run(&x, rows, cols.len())?;
    let max_abs: f32 = a
        .features
        .iter()
        .zip(&b.features)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max);
    println!(
        "\nfeaturize {rows}×{}: max |pjrt − native| = {max_abs:e}",
        cols.len()
    );
    assert!(max_abs < 1e-3);

    // Hash kernel the same way (bit-exact check).
    let keys = t.column_by_name("id")?.i64_values();
    let hk = HashKernel::new(&rt, 16);
    let batch = &keys[..keys.len().min(16384)];
    let (pids_aot, hist_aot) = hk.run(batch)?;
    let (pids_nat, hist_nat) = HashKernel::native(16).run(batch).unwrap();
    assert_eq!(pids_aot, pids_nat, "hash pids must be bit-exact");
    assert_eq!(hist_aot, hist_nat);
    println!("hash_partition: AOT vs native bit-exact over {} keys ✓", batch.len());

    // Timings for both call paths.
    let opts = BenchOpts {
        warmup_iters: 2,
        samples: 5,
    };
    let t_aot = measure(opts, || {
        std::hint::black_box(aot.run(&x, rows, cols.len()).unwrap());
    });
    let nat = FeaturizeKernel::native();
    let t_nat = measure(opts, || {
        std::hint::black_box(nat.run(&x, rows, cols.len()).unwrap());
    });
    println!(
        "\nfeaturize timing: pjrt {:.3}ms vs native {:.3}ms per call \
         (PJRT pays dispatch; both off the shuffle hot path)",
        t_aot.median * 1e3,
        t_nat.median * 1e3
    );
    Ok(())
}
