"""AOT pipeline tests: variant table sanity, manifest consistency, and
the §Perf structural kernel budgets (VMEM footprint of the chosen block
shapes)."""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot
from compile.kernels import featurize as fz
from compile.kernels import hash_partition as hp


def test_variants_are_block_aligned():
    for n, p in aot.HASH_VARIANTS:
        assert n % aot.HASH_BLOCK == 0, (n, p)
        assert p >= 1
    for rows, cols in aot.FEATURIZE_VARIANTS:
        assert rows % aot.FEATURIZE_BLOCK_R == 0, (rows, cols)


def test_hash_vmem_budget():
    # DESIGN.md §Perf: the chosen block shape must fit a 16 MB VMEM
    # budget at the largest partition count we compile.
    worst = max(p for _, p in aot.HASH_VARIANTS)
    bytes_ = hp.vmem_footprint_bytes(worst, aot.HASH_BLOCK)
    assert bytes_ < 16 * 1024 * 1024, bytes_


def test_featurize_vmem_budget():
    worst_cols = max(c for _, c in aot.FEATURIZE_VARIANTS)
    bytes_ = fz.vmem_footprint_bytes(worst_cols, aot.FEATURIZE_BLOCK_R)
    assert bytes_ < 16 * 1024 * 1024, bytes_


def test_manifest_matches_artifacts_if_built():
    # When artifacts/ exists (make artifacts), the manifest must list
    # files that exist with the declared shapes.
    out_dir = os.path.join(os.path.dirname(__file__), "..", "..",
                           "artifacts")
    mpath = os.path.join(out_dir, "manifest.json")
    if not os.path.exists(mpath):
        return  # fresh checkout — rust integration covers the rest
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    names = set()
    for a in manifest["artifacts"]:
        assert a["name"] not in names, "duplicate artifact name"
        names.add(a["name"])
        path = os.path.join(out_dir, a["file"])
        assert os.path.exists(path), path
        text = open(path).read()
        assert "HloModule" in text
        if a["kind"] == "hash_partition":
            assert f"u64[{a['n']}]" in text
            assert f"f32[{a['nparts']}]" in text
        elif a["kind"] == "featurize":
            assert f"f32[{a['rows']},{a['cols']}]" in text


def test_lowered_text_is_stable():
    # Same inputs → identical HLO text (reproducible builds).
    a = aot.lower_hash(16384, 4)
    b = aot.lower_hash(16384, 4)
    assert a == b
