"""Kernel-vs-oracle correctness: the CORE L1 signal.

hypothesis sweeps shapes/values/partition counts for the Pallas kernels
and asserts (bit-exact for integer outputs, allclose for floats) against
the pure-jnp oracles in kernels/ref.py.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import featurize as fz
from compile.kernels import hash_partition as hp
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# splitmix64
# ---------------------------------------------------------------------------

def test_splitmix64_known_vectors():
    # Golden values from the Rust implementation (compute/hash.rs), which
    # itself matches the published splitmix64 reference.
    xs = jnp.array([0, 1, 2, 0xDEADBEEF, 2**63, 2**64 - 1], dtype=jnp.uint64)
    got = np.asarray(hp.splitmix64(xs), dtype=np.uint64)
    want = np.asarray(ref.splitmix64_ref(xs), dtype=np.uint64)
    np.testing.assert_array_equal(got, want)
    # Spot-check one absolute value (splitmix64(0) is a published constant).
    assert int(got[0]) == 0xE220A8397B1DCDAF


@given(st.lists(st.integers(min_value=0, max_value=2**64 - 1),
                min_size=1, max_size=64))
@settings(**SETTINGS)
def test_splitmix64_matches_ref(vals):
    xs = jnp.array(vals, dtype=jnp.uint64)
    np.testing.assert_array_equal(
        np.asarray(hp.splitmix64(xs)), np.asarray(ref.splitmix64_ref(xs)))


def test_splitmix64_is_permutation_like():
    # No collisions over a contiguous range (sanity for partition balance).
    xs = jnp.arange(4096, dtype=jnp.uint64)
    hs = np.asarray(hp.splitmix64(xs))
    assert len(np.unique(hs)) == 4096


# ---------------------------------------------------------------------------
# hash_partition kernel
# ---------------------------------------------------------------------------

@given(
    nblocks=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([128, 256]),
    nparts=st.sampled_from([2, 3, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    pad=st.integers(min_value=0, max_value=100),
)
@settings(**SETTINGS)
def test_hash_partition_matches_ref(nblocks, block, nparts, seed, pad):
    n = nblocks * block
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2**63, size=n, dtype=np.uint64)
    pad = min(pad, n)
    mask = np.ones(n, np.float32)
    if pad:
        mask[n - pad:] = 0.0
    kj = jnp.asarray(keys)
    mj = jnp.asarray(mask)

    pids, hist_blocks = hp.hash_partition(kj, mj, nparts=nparts, block=block)
    hist = jnp.sum(hist_blocks, axis=0)
    rp, rh = ref.hash_partition_ref(kj, mj, nparts)

    np.testing.assert_array_equal(np.asarray(pids), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(hist), np.asarray(rh))
    # Histogram accounts for exactly the valid lanes.
    assert float(jnp.sum(hist)) == n - pad
    # All valid pids within range; padded lanes are -1.
    pn = np.asarray(pids)
    assert ((pn[mask > 0] >= 0) & (pn[mask > 0] < nparts)).all()
    if pad:
        assert (pn[mask == 0] == -1).all()


def test_hash_partition_balance():
    # splitmix64 should spread a contiguous key range near-uniformly.
    n, nparts = 65536, 16
    keys = jnp.arange(n, dtype=jnp.uint64)
    mask = jnp.ones(n, jnp.float32)
    _, hist_blocks = hp.hash_partition(keys, mask, nparts=nparts, block=4096)
    hist = np.asarray(jnp.sum(hist_blocks, axis=0))
    expect = n / nparts
    assert (np.abs(hist - expect) < 0.05 * expect).all(), hist


def test_hash_partition_deterministic():
    keys = jnp.arange(8192, dtype=jnp.uint64) * jnp.uint64(2654435761)
    mask = jnp.ones(8192, jnp.float32)
    a = hp.hash_partition(keys, mask, nparts=8, block=1024)
    b = hp.hash_partition(keys, mask, nparts=8, block=2048)
    # Block shape must not change results (only the partial-hist split).
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_allclose(np.asarray(jnp.sum(a[1], axis=0)),
                               np.asarray(jnp.sum(b[1], axis=0)))


def test_hash_partition_rejects_ragged():
    keys = jnp.zeros(100, jnp.uint64)
    mask = jnp.ones(100, jnp.float32)
    with pytest.raises(AssertionError):
        hp.hash_partition(keys, mask, nparts=4, block=64)


# ---------------------------------------------------------------------------
# featurize kernel
# ---------------------------------------------------------------------------

@given(
    nblocks=st.integers(min_value=1, max_value=3),
    block_r=st.sampled_from([64, 128]),
    cols=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    clip=st.sampled_from([0.0, 3.0]),
)
@settings(**SETTINGS)
def test_standardize_matches_ref(nblocks, block_r, cols, seed, clip):
    r = nblocks * block_r
    rng = np.random.default_rng(seed)
    x = rng.normal(3.0, 10.0, size=(r, cols)).astype(np.float32)
    mean = x.mean(axis=0, keepdims=True)
    inv_std = (1.0 / np.sqrt(x.var(axis=0, keepdims=True) + 1e-6)).astype(
        np.float32)
    got = fz.standardize(jnp.asarray(x), jnp.asarray(mean),
                         jnp.asarray(inv_std), block_r=block_r, clip=clip)
    want = ref.standardize_ref(jnp.asarray(x), jnp.asarray(mean),
                               jnp.asarray(inv_std), clip=clip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_standardize_constant_column():
    # A constant column standardises to ~0 (eps guards the 1/sqrt).
    x = jnp.full((256, 3), 7.5, jnp.float32)
    from compile import model
    feats, mean, inv_std = model.featurize_model(x, block_r=64)
    np.testing.assert_allclose(np.asarray(feats), 0.0, atol=1e-3)
    np.testing.assert_allclose(np.asarray(mean), 7.5, rtol=1e-6)
