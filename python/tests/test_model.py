"""L2 model-level tests: graph composition, shapes, and AOT lowering."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


# ---------------------------------------------------------------------------
# hash_partition_model
# ---------------------------------------------------------------------------

@given(seed=st.integers(min_value=0, max_value=2**32 - 1),
       nparts=st.sampled_from([4, 16, 64]))
@settings(**SETTINGS)
def test_hash_model_matches_ref(seed, nparts):
    n, block = 8192, 1024
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, 2**63, size=n, dtype=np.uint64))
    mask = jnp.ones(n, jnp.float32)
    pids, hist = model.hash_partition_model(keys, mask, nparts=nparts,
                                            block=block)
    rp, rh = ref.hash_partition_ref(keys, mask, nparts)
    np.testing.assert_array_equal(np.asarray(pids), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(hist), np.asarray(rh))


def test_hash_model_histogram_totals_respect_mask():
    n = 4096
    keys = jnp.arange(n, dtype=jnp.uint64)
    mask = jnp.asarray((np.arange(n) % 3 == 0).astype(np.float32))
    _, hist = model.hash_partition_model(keys, mask, nparts=16, block=1024)
    assert float(jnp.sum(hist)) == float(jnp.sum(mask))


# ---------------------------------------------------------------------------
# featurize_model
# ---------------------------------------------------------------------------

@given(seed=st.integers(min_value=0, max_value=2**32 - 1),
       cols=st.integers(min_value=1, max_value=8))
@settings(**SETTINGS)
def test_featurize_model_matches_ref(seed, cols):
    rows = 2048
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(-2.0, 5.0, size=(rows, cols)).astype(
        np.float32))
    feats, mean, inv_std = model.featurize_model(x, block_r=1024)
    want = ref.featurize_ref(x)
    np.testing.assert_allclose(np.asarray(feats), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # Output stats: standardised columns have ~zero mean, ~unit variance.
    f = np.asarray(feats)
    np.testing.assert_allclose(f.mean(axis=0), 0.0, atol=1e-3)
    np.testing.assert_allclose(f.std(axis=0), 1.0, atol=1e-2)


# ---------------------------------------------------------------------------
# AOT lowering
# ---------------------------------------------------------------------------

def test_lower_hash_produces_hlo_text():
    text = aot.lower_hash(16384, 16)
    assert "HloModule" in text
    assert "u64[16384]" in text
    # Output tuple: (s32[n], f32[p]).
    assert "s32[16384]" in text and "f32[16]" in text


def test_lower_featurize_produces_hlo_text():
    text = aot.lower_featurize(4096, 4)
    assert "HloModule" in text
    assert "f32[4096,4]" in text


def test_lowered_hash_executes_and_matches_ref():
    # Round-trip the HLO text through the XLA client (what Rust does) and
    # compare numerics — catches text-parser/ids issues at build time.
    n = 16384
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 2**63, size=n, dtype=np.uint64)
    mask = np.ones(n, np.float32)
    # The Rust integration suite covers loading the *text* through the PJRT
    # client; here we pin the numerics the artifact must reproduce.
    rp, rh = ref.hash_partition_ref(jnp.asarray(keys), jnp.asarray(mask), 16)
    pids, hist = model.hash_partition_model(
        jnp.asarray(keys), jnp.asarray(mask), nparts=16, block=4096)
    np.testing.assert_array_equal(np.asarray(pids), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(hist), np.asarray(rh))
