"""L2: the JAX compute graphs AOT-lowered for the Rust hot path.

Two graphs, both calling the L1 Pallas kernels:

* ``hash_partition_model`` — the shuffle hot-spot of every distributed
  relational operator (paper §III-C): uint64 join keys → destination
  partition ids + a summed partition histogram.  The Rust coordinator
  calls this through PJRT per shuffle batch (with a bit-exact native
  fallback, cross-checked in tests).

* ``featurize_model`` — the data-engineering→data-analytics bridge
  (paper Fig 1, §IV "to_numpy"): an (R, C) f32 matrix of numeric table
  columns → standardised feature tensor.  Column statistics are computed
  here in plain jnp (XLA fuses the reduction); the element-wise
  standardisation runs in the Pallas kernel so the whole bridge lowers
  into one HLO module.

Python runs only at build time (``make artifacts``); the lowered HLO text
is the interchange format (see aot.py for why text, not protos).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import hash_partition as hp
from compile.kernels import featurize as fz

EPS = 1e-6


@functools.partial(jax.jit, static_argnames=("nparts", "block"))
def hash_partition_model(keys: jax.Array, mask: jax.Array, *, nparts: int,
                         block: int = hp.DEFAULT_BLOCK):
    """keys uint64[n], mask f32[n] -> (pids int32[n], hist f32[nparts])."""
    pids, hist_blocks = hp.hash_partition(keys, mask, nparts=nparts,
                                          block=block)
    return pids, jnp.sum(hist_blocks, axis=0)


@functools.partial(jax.jit, static_argnames=("block_r", "clip"))
def featurize_model(x: jax.Array, *, block_r: int = fz.DEFAULT_BLOCK_R,
                    clip: float = 0.0):
    """x f32[R, C] -> (features f32[R, C], mean f32[C], inv_std f32[C]).

    Returns the stats too: the ML consumer needs them to apply the same
    transform to held-out data (and Rust asserts them against its native
    computation).
    """
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=0, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=0, keepdims=True)
    inv_std = 1.0 / jnp.sqrt(var + EPS)
    feats = fz.standardize(x, mean, inv_std, block_r=block_r, clip=clip)
    return feats, mean[0], inv_std[0]
