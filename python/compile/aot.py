"""AOT: lower the L2 graphs to HLO **text** artifacts for the Rust runtime.

Interchange format is HLO text, NOT ``lowered.compile()``/serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
the ``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the HLO text parser reassigns ids, so text round-trips
cleanly (see /opt/xla-example/README.md).

Each artifact is compiled for a fixed shape; the Rust runtime keeps a
registry (name → shape → path), pads batches up to the artifact shape and
masks the padding.  A JSON manifest describes every artifact so the Rust
side never hard-codes shapes.

Usage:  python -m compile.aot --out-dir ../artifacts
(``make artifacts`` from the repo root is a no-op when inputs are older
than the manifest.)
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402

# (n, nparts) variants for the shuffle kernel.  n is the shuffle batch
# size the Rust side pads to; nparts covers the parallelism sweep used by
# the figures (Fig 10: 1..160 ranks -> next-pow2 buckets).
HASH_VARIANTS = [
    (16384, 4),
    (16384, 16),
    (65536, 16),
    (65536, 64),
    (65536, 256),
]
HASH_BLOCK = 4096

# (rows, cols) variants for the featurize bridge.
FEATURIZE_VARIANTS = [
    (4096, 4),
    (16384, 8),
]
FEATURIZE_BLOCK_R = 1024


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_hash(n: int, nparts: int) -> str:
    keys = jax.ShapeDtypeStruct((n,), jnp.uint64)
    mask = jax.ShapeDtypeStruct((n,), jnp.float32)
    fn = lambda k, m: model.hash_partition_model(  # noqa: E731
        k, m, nparts=nparts, block=HASH_BLOCK)
    return to_hlo_text(jax.jit(fn).lower(keys, mask))


def lower_featurize(rows: int, cols: int) -> str:
    x = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    fn = lambda a: model.featurize_model(  # noqa: E731
        a, block_r=FEATURIZE_BLOCK_R)
    return to_hlo_text(jax.jit(fn).lower(x))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": []}

    for n, p in HASH_VARIANTS:
        name = f"hash_partition_n{n}_p{p}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_hash(n, p)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": name, "kind": "hash_partition", "file": f"{name}.hlo.txt",
            "n": n, "nparts": p, "block": HASH_BLOCK,
            "inputs": [
                {"dtype": "u64", "shape": [n]},
                {"dtype": "f32", "shape": [n]},
            ],
            "outputs": [
                {"dtype": "s32", "shape": [n]},
                {"dtype": "f32", "shape": [p]},
            ],
        })
        print(f"wrote {path} ({len(text)} chars)")

    for rows, cols in FEATURIZE_VARIANTS:
        name = f"featurize_r{rows}_c{cols}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_featurize(rows, cols)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": name, "kind": "featurize", "file": f"{name}.hlo.txt",
            "rows": rows, "cols": cols, "block_r": FEATURIZE_BLOCK_R,
            "inputs": [{"dtype": "f32", "shape": [rows, cols]}],
            "outputs": [
                {"dtype": "f32", "shape": [rows, cols]},
                {"dtype": "f32", "shape": [cols]},
                {"dtype": "f32", "shape": [cols]},
            ],
        })
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
