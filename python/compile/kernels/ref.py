"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package has a reference implementation here written
with plain jnp ops and no Pallas.  ``python/tests`` asserts allclose
(bit-exact for the integer hash) between kernel and oracle across a
hypothesis-driven sweep of shapes, dtypes and partition counts; the Rust
side additionally cross-checks its native implementations against the AOT
artifacts built from the L2 graph.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_SM64_M1 = 0xBF58476D1CE4E5B9
_SM64_M2 = 0x94D049BB133111EB
_GOLDEN = 0x9E3779B97F4A7C15


def splitmix64_ref(x: jax.Array) -> jax.Array:
    """Reference splitmix64 finalizer (uint64 lanes)."""
    x = x.astype(jnp.uint64)
    z = x + jnp.uint64(_GOLDEN)
    z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(_SM64_M1)
    z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(_SM64_M2)
    return z ^ (z >> jnp.uint64(31))


def hash_partition_ref(keys: jax.Array, mask: jax.Array, nparts: int):
    """Reference for kernels.hash_partition.hash_partition.

    Returns (pids int32[n], hist f32[nparts]) — note the histogram is
    already summed over blocks here (the L2 graph sums the kernel's
    block-partials, so compare against model-level outputs).
    """
    h = splitmix64_ref(keys.astype(jnp.uint64))
    pid = (h % jnp.uint64(nparts)).astype(jnp.int32)
    valid = mask > 0
    pid = jnp.where(valid, pid, jnp.int32(-1))
    hist = jnp.zeros((nparts,), jnp.float32).at[
        jnp.where(valid, pid, 0)
    ].add(jnp.where(valid, 1.0, 0.0))
    return pid, hist


def standardize_ref(x: jax.Array, mean: jax.Array, inv_std: jax.Array,
                    clip: float = 0.0) -> jax.Array:
    """Reference for kernels.featurize.standardize."""
    z = (x - mean) * inv_std
    if clip > 0.0:
        z = jnp.clip(z, -clip, clip)
    return z.astype(jnp.float32)


def featurize_ref(x: jax.Array, clip: float = 0.0, eps: float = 1e-6):
    """Reference for model.featurize: column stats + standardise."""
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=0, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=0, keepdims=True)
    inv_std = 1.0 / jnp.sqrt(var + eps)
    return standardize_ref(x, mean, inv_std, clip=clip)
