"""L1 Pallas kernel: hash-partition assignment + per-block histogram.

This is the compute hot-spot of Cylon's distributed relational operators
(paper §III-C): every distributed join/union/intersect/difference first
key-partitions each table with a hash function and shuffles rows to the
rank that owns the hash bucket.  The kernel maps a block of int64 join
keys to

  * ``pids``  — the destination partition id of every key, and
  * ``hist``  — a per-block partition histogram (so the caller can size
    send buffers before materialising the shuffle).

Hash function: **splitmix64** finalizer (Steele et al., the JDK
SplittableRandom mixer).  It is bit-exact with the Rust implementation in
``rust/src/compute/hash.rs`` — cross-checked by ``rust/tests/`` against
the AOT artifact — so a row hashed in Python land and a row hashed on the
Rust hot path always land in the same partition.

TPU shaping (DESIGN.md §Hardware-Adaptation): keys are tiled in
``(BLOCK,)`` chunks via ``BlockSpec``; the histogram is computed as a
one-hot ``(BLOCK, P)`` matrix summed over rows, which on a real TPU maps
the reduction onto the MXU as a matmul with an all-ones vector.  The hash
itself is element-wise VPU work.  On CPU we must run ``interpret=True``
(Mosaic custom-calls cannot execute on the CPU PJRT plugin).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# splitmix64 finalizer constants (Steele et al. 2014).
_SM64_M1 = 0xBF58476D1CE4E5B9
_SM64_M2 = 0x94D049BB133111EB
_GOLDEN = 0x9E3779B97F4A7C15

DEFAULT_BLOCK = 4096


def splitmix64(x: jax.Array) -> jax.Array:
    """splitmix64 finalizer over uint64 lanes (bit-exact w/ Rust)."""
    x = x.astype(jnp.uint64)
    z = x + jnp.uint64(_GOLDEN)
    z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(_SM64_M1)
    z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(_SM64_M2)
    return z ^ (z >> jnp.uint64(31))


def _kernel(key_ref, mask_ref, pid_ref, hist_ref, *, nparts: int):
    """One grid step: hash a block of keys, emit pids + partial histogram."""
    keys = key_ref[...].astype(jnp.uint64)
    h = splitmix64(keys)
    pid = (h % jnp.uint64(nparts)).astype(jnp.int32)
    # Mask padded lanes to partition -1 so they never count anywhere.
    mask = mask_ref[...] > 0
    pid_ref[...] = jnp.where(mask, pid, jnp.int32(-1))
    # Histogram as a one-hot reduction: (BLOCK, P) @ ones -> (P,).  f32 is
    # exact for counts < 2^24, far above any BLOCK we use.  On TPU this is
    # the MXU-shaped part of the kernel.
    onehot = (pid[:, None] == jnp.arange(nparts, dtype=jnp.int32)[None, :])
    onehot = onehot & mask[:, None]
    hist_ref[...] = jnp.sum(onehot.astype(jnp.float32), axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("nparts", "block"))
def hash_partition(keys: jax.Array, mask: jax.Array, *, nparts: int,
                   block: int = DEFAULT_BLOCK):
    """Partition-assign ``keys`` (uint64[n]) into ``nparts`` buckets.

    ``mask`` is f32[n] with 1.0 on valid lanes, 0.0 on padding.  Returns
    ``(pids int32[n], hist f32[nblocks, nparts])``; the caller sums the
    block-partial histograms (done in L2, see model.py).

    ``n`` must be a multiple of ``block``.
    """
    n = keys.shape[0]
    assert n % block == 0, f"n={n} not a multiple of block={block}"
    nblocks = n // block
    return pl.pallas_call(
        functools.partial(_kernel, nparts=nparts),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1, nparts), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, nparts), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(keys, mask)


def vmem_footprint_bytes(nparts: int, block: int = DEFAULT_BLOCK) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §Perf)."""
    keys = block * 8
    mask = block * 4
    pids = block * 4
    onehot = block * nparts * 4
    hist = nparts * 4
    return keys + mask + pids + onehot + hist
