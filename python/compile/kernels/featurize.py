"""L1 Pallas kernel: column-standardising table→tensor featurizer.

The paper's whole motivation (Fig 1, §I) is the handoff from data
engineering to data analytics: after the relational pipeline, the table's
numeric columns become the vector/matrix/tensor a DL framework consumes
(PyCylon's ``to_numpy``).  This kernel performs the numeric half of that
bridge: given an ``(R, C)`` block of f32 values and per-column
``mean``/``inv_std`` vectors, it emits the standardised f32 feature block
``(x - mean) * inv_std`` (optionally clipped) that is fed to the model.

Shaping: rows are tiled in ``(BLOCK_R, C)`` blocks — C is the (small)
feature width, padded to a lane-friendly multiple by the caller — and
each grid step is pure element-wise VPU work with a broadcast over the
column statistics.  The column statistics themselves are computed in the
L2 JAX graph (a reduction XLA fuses well on its own).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 1024


def _kernel(x_ref, mean_ref, inv_std_ref, o_ref, *, clip: float):
    x = x_ref[...]
    z = (x - mean_ref[...]) * inv_std_ref[...]
    if clip > 0.0:
        z = jnp.clip(z, -clip, clip)
    o_ref[...] = z


@functools.partial(jax.jit, static_argnames=("block_r", "clip"))
def standardize(x: jax.Array, mean: jax.Array, inv_std: jax.Array, *,
                block_r: int = DEFAULT_BLOCK_R, clip: float = 0.0):
    """Standardise ``x`` (f32[R, C]) with per-column stats (f32[1, C]).

    R must be a multiple of ``block_r``.  Returns f32[R, C].
    """
    r, c = x.shape
    assert r % block_r == 0, f"rows={r} not a multiple of block_r={block_r}"
    nblocks = r // block_r
    return pl.pallas_call(
        functools.partial(_kernel, clip=clip),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, mean, inv_std)


def vmem_footprint_bytes(c: int, block_r: int = DEFAULT_BLOCK_R) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §Perf)."""
    return block_r * c * 4 * 2 + 2 * c * 4
