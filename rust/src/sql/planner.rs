//! Lower a parsed [`SelectStmt`] onto a [`Pipeline`] + final projection,
//! executable locally or SPMD (the pipeline stages map 1:1 onto the
//! distributed operators).

use crate::compute::aggregate::AggKind;
use crate::error::{Result, RylonError};
use crate::ops::groupby::{Agg, GroupByOptions};
use crate::ops::join::{JoinOptions, JoinType};
use crate::ops::orderby::{SortKey, SortOrder};
use crate::pipeline::{Env, Pipeline};
use crate::sql::parser::{parse_select, SelectItem, SelectStmt};
use crate::table::Table;

/// A compiled query: the stage chain plus the final column projection
/// (applied after groupby renames settle).
pub struct CompiledQuery {
    pub stmt: SelectStmt,
    pub pipeline: Pipeline,
    /// Output column names, in order; None = passthrough (`SELECT *`).
    pub final_columns: Option<Vec<String>>,
    pub limit: Option<usize>,
}

/// Compile a SELECT statement.
pub fn plan(sql: &str) -> Result<CompiledQuery> {
    let stmt = parse_select(sql)?;
    let mut pipeline = Pipeline::new();

    // WHERE runs before joins only when it references the base table;
    // we keep the simple, predictable order: joins → where → group →
    // order (matching the semantics of the supported dialect).
    for j in &stmt.joins {
        let jt = if j.left {
            JoinType::Left
        } else {
            JoinType::Inner
        };
        pipeline = pipeline.join(
            &j.table,
            JoinOptions::new(jt, &[&j.left_on], &[&j.right_on]),
        );
    }
    if let Some(pred) = &stmt.where_clause {
        pipeline = pipeline.select_pred(pred.clone());
    }

    let has_aggs = stmt
        .items
        .iter()
        .any(|i| matches!(i, SelectItem::Agg { .. }));
    if has_aggs && stmt.group_by.is_empty() {
        return Err(RylonError::invalid(
            "aggregates require GROUP BY in this dialect",
        ));
    }

    let mut final_columns: Option<Vec<String>> = None;
    if !stmt.group_by.is_empty() {
        let mut aggs = Vec::new();
        let mut out_cols: Vec<String> = stmt.group_by.clone();
        for item in &stmt.items {
            match item {
                SelectItem::Star => {
                    return Err(RylonError::invalid(
                        "SELECT * cannot be combined with GROUP BY",
                    ))
                }
                SelectItem::Column { name, alias } => {
                    if !stmt.group_by.contains(name) {
                        return Err(RylonError::invalid(format!(
                            "column '{name}' is neither aggregated nor in GROUP BY"
                        )));
                    }
                    if let Some(a) = alias {
                        return Err(RylonError::invalid(format!(
                            "alias '{a}' on a grouping key is not supported"
                        )));
                    }
                }
                SelectItem::Agg {
                    func,
                    column,
                    alias,
                } => {
                    let kind =
                        AggKind::parse(func).ok_or_else(|| {
                            RylonError::invalid(format!(
                                "unknown aggregate '{func}'"
                            ))
                        })?;
                    let mut agg = Agg::new(kind, column);
                    if let Some(a) = alias {
                        agg = agg.named(a);
                    }
                    out_cols.push(agg.name.clone());
                    aggs.push(agg);
                }
            }
        }
        // Keys the user didn't project are still grouped; restrict the
        // output to the projected order below.
        let keys: Vec<&str> =
            stmt.group_by.iter().map(|s| s.as_str()).collect();
        pipeline = pipeline.groupby(GroupByOptions::new(&keys, aggs));
        // Projection order: as written in the SELECT list.
        let projected: Vec<String> = stmt
            .items
            .iter()
            .map(|i| match i {
                SelectItem::Column { name, .. } => name.clone(),
                SelectItem::Agg {
                    func,
                    column,
                    alias,
                } => alias.clone().unwrap_or_else(|| {
                    format!("{func}_{column}")
                }),
                SelectItem::Star => unreachable!(),
            })
            .collect();
        final_columns = Some(projected);
        let _ = out_cols;
    } else {
        // Plain projection (applied after ORDER BY so sort keys not in
        // the projection still work).
        let mut cols = Vec::new();
        let mut star = false;
        for item in &stmt.items {
            match item {
                SelectItem::Star => star = true,
                SelectItem::Column { name, alias } => {
                    if alias.is_some() {
                        return Err(RylonError::invalid(
                            "column aliases outside GROUP BY are not supported",
                        ));
                    }
                    cols.push(name.clone());
                }
                SelectItem::Agg { .. } => unreachable!(),
            }
        }
        if !star {
            final_columns = Some(cols);
        }
    }

    if !stmt.order_by.is_empty() {
        let keys: Vec<SortKey> = stmt
            .order_by
            .iter()
            .map(|o| SortKey {
                column: o.column.clone(),
                order: if o.descending {
                    SortOrder::Descending
                } else {
                    SortOrder::Ascending
                },
            })
            .collect();
        pipeline = pipeline.orderby(keys);
    }

    Ok(CompiledQuery {
        limit: stmt.limit,
        stmt,
        pipeline,
        final_columns,
    })
}

impl CompiledQuery {
    /// Apply the trailing projection + limit to a pipeline result.
    pub fn finish(&self, table: Table) -> Result<Table> {
        let projected = match &self.final_columns {
            None => table,
            Some(cols) => {
                let names: Vec<&str> =
                    cols.iter().map(|s| s.as_str()).collect();
                crate::ops::project(&table, &names)?
            }
        };
        Ok(match self.limit {
            Some(n) => projected.head(n),
            None => projected,
        })
    }
}

/// Parse, plan and execute a query against named tables. The `FROM`
/// table and all joined tables come from `env`.
pub fn execute_local(sql: &str, env: &Env) -> Result<Table> {
    let q = plan(sql)?;
    let input = env.get(&q.stmt.from).ok_or_else(|| {
        RylonError::invalid(format!("unknown table '{}'", q.stmt.from))
    })?;
    let (out, _phases) = q.pipeline.run_local(input, env)?;
    q.finish(out)
}

/// SPMD execution: every rank calls this with its partitions in `env`.
pub fn execute_dist(
    ctx: &mut crate::dist::RankCtx,
    sql: &str,
    env: &Env,
) -> Result<Table> {
    let q = plan(sql)?;
    let input = env.get(&q.stmt.from).ok_or_else(|| {
        RylonError::invalid(format!("unknown table '{}'", q.stmt.from))
    })?;
    let (out, _phases) = q.pipeline.run_dist(ctx, input, env)?;
    // LIMIT semantics distributed: each rank holds a range of the
    // global order after orderby; a global limit needs the first n of
    // the concatenation — take head(n) per rank and let the caller trim
    // after gather (documented behaviour).
    q.finish(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    #[test]
    fn plan_rejects_bad_shapes() {
        assert!(plan("SELECT SUM(x) FROM t").is_err());
        assert!(plan("SELECT * , a FROM t GROUP BY a").is_err());
        assert!(plan("SELECT b FROM t GROUP BY a").is_err());
    }

    #[test]
    fn dist_sql_matches_local() {
        use crate::dist::{Cluster, DistConfig};
        let sql = "SELECT grp, SUM(v) AS s FROM t GROUP BY grp ORDER BY grp";
        let whole = Table::from_columns(vec![
            (
                "grp",
                Column::from_i64((0..60).map(|i| i % 4).collect()),
            ),
            (
                "v",
                Column::from_f64((0..60).map(|i| i as f64).collect()),
            ),
        ])
        .unwrap();
        let mut env = Env::new();
        env.insert("t".to_string(), whole.clone());
        let local = execute_local(sql, &env).unwrap();

        let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let n = whole.num_rows();
                let base = n / ctx.size;
                let extra = n % ctx.size;
                let my = base + (ctx.rank < extra) as usize;
                let off = base * ctx.rank + ctx.rank.min(extra);
                let mut env = Env::new();
                env.insert("t".to_string(), whole.slice(off, my));
                execute_dist(ctx, sql, &env)
            })
            .unwrap();
        let merged = Table::concat_all(outs[0].schema(), &outs).unwrap();
        let sorted = crate::ops::orderby(
            &merged,
            &[crate::ops::orderby::SortKey::asc("grp")],
        )
        .unwrap();
        assert_eq!(sorted, local);
    }
}
