//! SQL tokenizer: keywords (case-insensitive), identifiers, numbers,
//! quoted strings, operators and punctuation.

use crate::error::{Result, RylonError};

/// One SQL token.
#[derive(Debug, Clone, PartialEq)]
pub enum Token {
    /// Upper-cased keyword (SELECT, FROM, …).
    Keyword(String),
    /// Bare identifier (column/table name), original case.
    Ident(String),
    /// Numeric literal.
    Number(f64),
    /// 'single-quoted' string literal.
    Str(String),
    /// Comparison / arithmetic operator.
    Op(String),
    Comma,
    LParen,
    RParen,
    Star,
}

const KEYWORDS: &[&str] = &[
    "SELECT", "FROM", "WHERE", "GROUP", "BY", "ORDER", "LIMIT", "JOIN",
    "LEFT", "INNER", "ON", "AS", "AND", "OR", "NOT", "ASC", "DESC",
    "NULL", "IS",
];

/// Tokenize a SQL string.
pub fn tokenize(sql: &str) -> Result<Vec<Token>> {
    let b: Vec<char> = sql.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        match c {
            c if c.is_whitespace() => i += 1,
            ',' => {
                out.push(Token::Comma);
                i += 1;
            }
            '(' => {
                out.push(Token::LParen);
                i += 1;
            }
            ')' => {
                out.push(Token::RParen);
                i += 1;
            }
            '*' => {
                out.push(Token::Star);
                i += 1;
            }
            '\'' => {
                let mut s = String::new();
                i += 1;
                loop {
                    match b.get(i) {
                        None => {
                            return Err(RylonError::parse(
                                "unterminated string literal",
                            ))
                        }
                        Some('\'') if b.get(i + 1) == Some(&'\'') => {
                            s.push('\'');
                            i += 2;
                        }
                        Some('\'') => {
                            i += 1;
                            break;
                        }
                        Some(&c) => {
                            s.push(c);
                            i += 1;
                        }
                    }
                }
                out.push(Token::Str(s));
            }
            '<' | '>' | '=' | '!' => {
                let mut op = String::from(c);
                if b.get(i + 1) == Some(&'=') {
                    op.push('=');
                    i += 1;
                }
                i += 1;
                if op == "!" {
                    return Err(RylonError::parse("lone '!' operator"));
                }
                out.push(Token::Op(op));
            }
            c if c.is_ascii_digit()
                || (c == '-'
                    && b.get(i + 1).is_some_and(|d| d.is_ascii_digit())) =>
            {
                let start = i;
                i += 1;
                while i < b.len()
                    && (b[i].is_ascii_digit()
                        || b[i] == '.'
                        || b[i] == 'e'
                        || b[i] == 'E'
                        || ((b[i] == '+' || b[i] == '-')
                            && matches!(b[i - 1], 'e' | 'E')))
                {
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                let n = text.parse::<f64>().map_err(|_| {
                    RylonError::parse(format!("bad number '{text}'"))
                })?;
                out.push(Token::Number(n));
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < b.len()
                    && (b[i].is_alphanumeric() || b[i] == '_')
                {
                    i += 1;
                }
                let word: String = b[start..i].iter().collect();
                let upper = word.to_ascii_uppercase();
                if KEYWORDS.contains(&upper.as_str()) {
                    out.push(Token::Keyword(upper));
                } else {
                    out.push(Token::Ident(word));
                }
            }
            other => {
                return Err(RylonError::parse(format!(
                    "unexpected character '{other}' in SQL"
                )))
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keywords_and_idents() {
        let t = tokenize("select Name FROM tbl").unwrap();
        assert_eq!(
            t,
            vec![
                Token::Keyword("SELECT".into()),
                Token::Ident("Name".into()),
                Token::Keyword("FROM".into()),
                Token::Ident("tbl".into()),
            ]
        );
    }

    #[test]
    fn numbers_strings_ops() {
        let t = tokenize("a >= -1.5e3 AND b = 'it''s'").unwrap();
        assert!(t.contains(&Token::Op(">=".into())));
        assert!(t.contains(&Token::Number(-1500.0)));
        assert!(t.contains(&Token::Str("it's".into())));
    }

    #[test]
    fn punctuation() {
        let t = tokenize("SUM(x), *").unwrap();
        assert_eq!(
            t,
            vec![
                Token::Ident("SUM".into()),
                Token::LParen,
                Token::Ident("x".into()),
                Token::RParen,
                Token::Comma,
                Token::Star,
            ]
        );
    }

    #[test]
    fn errors() {
        assert!(tokenize("'open").is_err());
        assert!(tokenize("a ! b").is_err());
        assert!(tokenize("price > 10; drop").is_err());
    }
}
