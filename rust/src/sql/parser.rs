//! Recursive-descent parser for the SELECT dialect (see `sql::mod` for
//! the grammar summary). Produces a [`SelectStmt`] AST; the planner
//! lowers it onto a pipeline.

use crate::error::{Result, RylonError};
use crate::ops::select::{CmpOp, Predicate};
use crate::sql::lexer::{tokenize, Token};
use crate::types::Value;

/// One item of the projection list.
#[derive(Debug, Clone, PartialEq)]
pub enum SelectItem {
    Star,
    /// Plain column, optional alias.
    Column { name: String, alias: Option<String> },
    /// `AGG(column)`, optional alias.
    Agg {
        func: String,
        column: String,
        alias: Option<String>,
    },
}

/// `[LEFT|INNER] JOIN table ON lcol = rcol`.
#[derive(Debug, Clone, PartialEq)]
pub struct JoinClause {
    pub table: String,
    pub left_on: String,
    pub right_on: String,
    pub left: bool,
}

/// `ORDER BY col [ASC|DESC]`.
#[derive(Debug, Clone, PartialEq)]
pub struct OrderClause {
    pub column: String,
    pub descending: bool,
}

/// The parsed statement.
#[derive(Debug, Clone)]
pub struct SelectStmt {
    pub items: Vec<SelectItem>,
    pub from: String,
    pub joins: Vec<JoinClause>,
    pub where_clause: Option<Predicate>,
    pub group_by: Vec<String>,
    pub order_by: Vec<OrderClause>,
    pub limit: Option<usize>,
}

struct P {
    toks: Vec<Token>,
    i: usize,
}

impl P {
    fn peek(&self) -> Option<&Token> {
        self.toks.get(self.i)
    }

    fn next(&mut self) -> Option<Token> {
        let t = self.toks.get(self.i).cloned();
        if t.is_some() {
            self.i += 1;
        }
        t
    }

    fn eat_kw(&mut self, kw: &str) -> bool {
        if matches!(self.peek(), Some(Token::Keyword(k)) if k == kw) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect_kw(&mut self, kw: &str) -> Result<()> {
        if self.eat_kw(kw) {
            Ok(())
        } else {
            Err(RylonError::parse(format!(
                "expected {kw}, found {:?}",
                self.peek()
            )))
        }
    }

    fn ident(&mut self) -> Result<String> {
        match self.next() {
            Some(Token::Ident(s)) => Ok(s),
            other => Err(RylonError::parse(format!(
                "expected identifier, found {other:?}"
            ))),
        }
    }
}

/// Parse one SELECT statement.
pub fn parse_select(sql: &str) -> Result<SelectStmt> {
    let mut p = P {
        toks: tokenize(sql)?,
        i: 0,
    };
    p.expect_kw("SELECT")?;
    let items = parse_items(&mut p)?;
    p.expect_kw("FROM")?;
    let from = p.ident()?;

    let mut joins = Vec::new();
    loop {
        let left = if p.eat_kw("LEFT") {
            p.expect_kw("JOIN")?;
            true
        } else if p.eat_kw("INNER") {
            p.expect_kw("JOIN")?;
            false
        } else if p.eat_kw("JOIN") {
            false
        } else {
            break;
        };
        let table = p.ident()?;
        p.expect_kw("ON")?;
        let lcol = p.ident()?;
        match p.next() {
            Some(Token::Op(op)) if op == "=" => {}
            other => {
                return Err(RylonError::parse(format!(
                    "expected '=' in ON clause, found {other:?}"
                )))
            }
        }
        let rcol = p.ident()?;
        joins.push(JoinClause {
            table,
            left_on: lcol,
            right_on: rcol,
            left,
        });
    }

    let where_clause = if p.eat_kw("WHERE") {
        Some(parse_or(&mut p)?)
    } else {
        None
    };

    let mut group_by = Vec::new();
    if p.eat_kw("GROUP") {
        p.expect_kw("BY")?;
        group_by.push(p.ident()?);
        while matches!(p.peek(), Some(Token::Comma)) {
            p.next();
            group_by.push(p.ident()?);
        }
    }

    let mut order_by = Vec::new();
    if p.eat_kw("ORDER") {
        p.expect_kw("BY")?;
        loop {
            let column = p.ident()?;
            let descending = if p.eat_kw("DESC") {
                true
            } else {
                p.eat_kw("ASC");
                false
            };
            order_by.push(OrderClause {
                column,
                descending,
            });
            if matches!(p.peek(), Some(Token::Comma)) {
                p.next();
            } else {
                break;
            }
        }
    }

    let limit = if p.eat_kw("LIMIT") {
        match p.next() {
            Some(Token::Number(n)) if n >= 0.0 && n.fract() == 0.0 => {
                Some(n as usize)
            }
            other => {
                return Err(RylonError::parse(format!(
                    "expected integer LIMIT, found {other:?}"
                )))
            }
        }
    } else {
        None
    };

    if p.peek().is_some() {
        return Err(RylonError::parse(format!(
            "trailing tokens after statement: {:?}",
            p.peek()
        )));
    }
    Ok(SelectStmt {
        items,
        from,
        joins,
        where_clause,
        group_by,
        order_by,
        limit,
    })
}

fn parse_items(p: &mut P) -> Result<Vec<SelectItem>> {
    let mut items = Vec::new();
    loop {
        let item = match p.next() {
            Some(Token::Star) => SelectItem::Star,
            Some(Token::Ident(name)) => {
                if matches!(p.peek(), Some(Token::LParen)) {
                    p.next(); // (
                    let column = p.ident()?;
                    match p.next() {
                        Some(Token::RParen) => {}
                        other => {
                            return Err(RylonError::parse(format!(
                                "expected ')', found {other:?}"
                            )))
                        }
                    }
                    SelectItem::Agg {
                        func: name.to_ascii_lowercase(),
                        column,
                        alias: parse_alias(p)?,
                    }
                } else {
                    SelectItem::Column {
                        name,
                        alias: parse_alias(p)?,
                    }
                }
            }
            other => {
                return Err(RylonError::parse(format!(
                    "expected projection item, found {other:?}"
                )))
            }
        };
        items.push(item);
        if matches!(p.peek(), Some(Token::Comma)) {
            p.next();
        } else {
            break;
        }
    }
    Ok(items)
}

fn parse_alias(p: &mut P) -> Result<Option<String>> {
    if p.eat_kw("AS") {
        Ok(Some(p.ident()?))
    } else {
        Ok(None)
    }
}

// WHERE expression grammar: OR > AND > NOT > cmp atom.
fn parse_or(p: &mut P) -> Result<Predicate> {
    let mut lhs = parse_and(p)?;
    while p.eat_kw("OR") {
        let rhs = parse_and(p)?;
        lhs = lhs.or(rhs);
    }
    Ok(lhs)
}

fn parse_and(p: &mut P) -> Result<Predicate> {
    let mut lhs = parse_not(p)?;
    while p.eat_kw("AND") {
        let rhs = parse_not(p)?;
        lhs = lhs.and(rhs);
    }
    Ok(lhs)
}

fn parse_not(p: &mut P) -> Result<Predicate> {
    if p.eat_kw("NOT") {
        Ok(parse_not(p)?.not())
    } else {
        parse_atom(p)
    }
}

fn parse_atom(p: &mut P) -> Result<Predicate> {
    if matches!(p.peek(), Some(Token::LParen)) {
        p.next();
        let inner = parse_or(p)?;
        match p.next() {
            Some(Token::RParen) => return Ok(inner),
            other => {
                return Err(RylonError::parse(format!(
                    "expected ')', found {other:?}"
                )))
            }
        }
    }
    let column = p.ident()?;
    // `col IS [NOT] NULL`
    if p.eat_kw("IS") {
        let negated = p.eat_kw("NOT");
        p.expect_kw("NULL")?;
        return Ok(Predicate::IsNull { column, negated });
    }
    let op = match p.next() {
        Some(Token::Op(op)) => match op.as_str() {
            "=" | "==" => CmpOp::Eq,
            "!=" => CmpOp::Ne,
            "<" => CmpOp::Lt,
            "<=" => CmpOp::Le,
            ">" => CmpOp::Gt,
            ">=" => CmpOp::Ge,
            other => {
                return Err(RylonError::parse(format!(
                    "unknown operator '{other}'"
                )))
            }
        },
        other => {
            return Err(RylonError::parse(format!(
                "expected comparison, found {other:?}"
            )))
        }
    };
    let literal = match p.next() {
        Some(Token::Number(n)) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                Value::Int64(n as i64)
            } else {
                Value::Float64(n)
            }
        }
        Some(Token::Str(s)) => Value::Utf8(s),
        Some(Token::Keyword(k)) if k == "NULL" => Value::Null,
        other => {
            return Err(RylonError::parse(format!(
                "expected literal, found {other:?}"
            )))
        }
    };
    Ok(Predicate::Cmp {
        column,
        op,
        literal,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_statement() {
        let s = parse_select(
            "SELECT name, SUM(amount) AS total FROM orders \
             LEFT JOIN users ON user = uid \
             WHERE amount > 10 AND NOT region = 'eu' \
             GROUP BY name ORDER BY total DESC, name LIMIT 5",
        )
        .unwrap();
        assert_eq!(s.items.len(), 2);
        assert_eq!(
            s.items[1],
            SelectItem::Agg {
                func: "sum".into(),
                column: "amount".into(),
                alias: Some("total".into()),
            }
        );
        assert_eq!(s.from, "orders");
        assert_eq!(s.joins.len(), 1);
        assert!(s.joins[0].left);
        assert_eq!(s.joins[0].right_on, "uid");
        assert!(s.where_clause.is_some());
        assert_eq!(s.group_by, vec!["name"]);
        assert_eq!(s.order_by.len(), 2);
        assert!(s.order_by[0].descending);
        assert!(!s.order_by[1].descending);
        assert_eq!(s.limit, Some(5));
    }

    #[test]
    fn star_and_minimal() {
        let s = parse_select("SELECT * FROM t").unwrap();
        assert_eq!(s.items, vec![SelectItem::Star]);
        assert!(s.joins.is_empty());
        assert!(s.where_clause.is_none());
    }

    #[test]
    fn parenthesised_where() {
        let s = parse_select(
            "SELECT * FROM t WHERE (a = 1 OR b = 2) AND c != 3",
        )
        .unwrap();
        assert!(s.where_clause.is_some());
    }

    #[test]
    fn is_null_clauses() {
        let s =
            parse_select("SELECT * FROM t WHERE x IS NOT NULL").unwrap();
        assert!(matches!(
            s.where_clause,
            Some(Predicate::IsNull { negated: true, .. })
        ));
    }

    #[test]
    fn parse_errors() {
        assert!(parse_select("FROM t").is_err());
        assert!(parse_select("SELECT * FROM t WHERE a >").is_err());
        assert!(parse_select("SELECT * FROM t LIMIT 1.5").is_err());
        assert!(parse_select("SELECT * FROM t extra").is_err());
        assert!(parse_select("SELECT SUM( FROM t").is_err());
        assert!(parse_select("SELECT * FROM t JOIN u ON a b").is_err());
    }
}
