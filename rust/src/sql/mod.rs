//! SQL frontend — the paper's §II observation made concrete: "Relational
//! algebraic operations are a natural fit for processing table data, and
//! SQL interfaces can further enhance usability."
//!
//! A small SELECT dialect compiled onto the [`crate::pipeline::Pipeline`]
//! stage chain (and therefore runnable locally *or* distributed):
//!
//! ```sql
//! SELECT name, SUM(amount) AS total, COUNT(amount)
//! FROM orders
//! JOIN users ON user = user
//! WHERE amount > 20 AND region != 'eu'
//! GROUP BY name
//! ORDER BY total DESC
//! LIMIT 10
//! ```
//!
//! Supported: projection (`*` or column list), aggregate calls with
//! optional `AS` aliases, one `FROM` table, any number of
//! `[LEFT|INNER] JOIN t ON lcol = rcol`, `WHERE` (via the predicate
//! expression grammar), `GROUP BY`, `ORDER BY col [ASC|DESC]`, `LIMIT`.

mod lexer;
mod parser;
mod planner;

pub use lexer::{tokenize, Token};
pub use parser::{parse_select, JoinClause, OrderClause, SelectItem, SelectStmt};
pub use planner::{execute_dist, execute_local, plan, CompiledQuery};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::pipeline::Env;
    use crate::table::Table;
    use crate::types::Value;

    fn env() -> Env {
        let mut env = Env::new();
        env.insert(
            "orders".to_string(),
            Table::from_columns(vec![
                ("oid", Column::from_i64(vec![1, 2, 3, 4, 5])),
                ("user", Column::from_i64(vec![10, 11, 10, 12, 11])),
                (
                    "amount",
                    Column::from_f64(vec![5.0, 120.0, 33.0, 7.5, 78.0]),
                ),
            ])
            .unwrap(),
        );
        env.insert(
            "users".to_string(),
            Table::from_columns(vec![
                ("user", Column::from_i64(vec![10, 11, 13])),
                ("name", Column::from_str(&["ada", "grace", "edsger"])),
            ])
            .unwrap(),
        );
        env
    }

    fn run(sql: &str) -> Table {
        execute_local(sql, &env()).unwrap()
    }

    #[test]
    fn select_star_where() {
        let t = run("SELECT * FROM orders WHERE amount > 20");
        assert_eq!(t.num_rows(), 3);
        assert_eq!(t.num_columns(), 3);
    }

    #[test]
    fn projection_subset() {
        let t = run("SELECT oid, amount FROM orders");
        assert_eq!(t.num_columns(), 2);
        assert_eq!(t.schema().field(0).name, "oid");
    }

    #[test]
    fn join_where_group_order() {
        let t = run(
            "SELECT name, SUM(amount) AS total, COUNT(amount) \
             FROM orders JOIN users ON user = user \
             WHERE amount > 10 GROUP BY name ORDER BY total DESC",
        );
        assert_eq!(t.num_rows(), 2);
        // grace: 120 + 78 = 198; ada: 33.
        assert_eq!(t.row(0)[0], Value::Utf8("grace".into()));
        assert_eq!(t.row(0)[1], Value::Float64(198.0));
        assert_eq!(t.row(1)[1], Value::Float64(33.0));
        assert_eq!(t.schema().field(1).name, "total");
    }

    #[test]
    fn left_join_keeps_unmatched() {
        let t = run(
            "SELECT oid, name FROM orders LEFT JOIN users ON user = user",
        );
        assert_eq!(t.num_rows(), 5);
        // user 12 has no match → null name.
        let nulls = (0..5)
            .filter(|&i| t.row(i)[1].is_null())
            .count();
        assert_eq!(nulls, 1);
    }

    #[test]
    fn order_and_limit() {
        let t = run("SELECT oid FROM orders ORDER BY amount DESC LIMIT 2");
        assert_eq!(t.num_rows(), 2);
        assert_eq!(t.row(0)[0], Value::Int64(2)); // amount 120
        assert_eq!(t.row(1)[0], Value::Int64(5)); // amount 78
    }

    #[test]
    fn string_literal_predicates() {
        let t = run("SELECT user FROM users WHERE name = 'grace'");
        assert_eq!(t.num_rows(), 1);
        assert_eq!(t.row(0)[0], Value::Int64(11));
    }

    #[test]
    fn aggregates_without_group_by_rejected() {
        // (kept simple: aggregates require GROUP BY in this dialect)
        assert!(execute_local("SELECT SUM(amount) FROM orders", &env())
            .is_err());
    }

    #[test]
    fn errors_are_helpful() {
        for bad in [
            "SELEC * FROM t",
            "SELECT * FROM",
            "SELECT * FROM missing_table",
            "SELECT * FROM orders WHERE",
            "SELECT nope FROM orders",
            "SELECT * FROM orders LIMIT abc",
        ] {
            assert!(execute_local(bad, &env()).is_err(), "{bad}");
        }
    }

    #[test]
    fn case_insensitive_keywords() {
        let t = run("select oid from orders where amount > 100");
        assert_eq!(t.num_rows(), 1);
    }
}
