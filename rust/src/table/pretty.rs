//! Aligned text rendering of a table head (the `Table::pretty` backend,
//! mirroring PyCylon's notebook-friendly repr).

use crate::table::Table;

/// Render the first `n` rows as an aligned grid with a header and a
/// trailing row-count line.
pub fn pretty_table(table: &Table, n: usize) -> String {
    let n = n.min(table.num_rows());
    let ncols = table.num_columns();
    // Header cells.
    let mut widths: Vec<usize> = (0..ncols)
        .map(|c| {
            let f = table.schema().field(c);
            f.name.len() + f.dtype.name().len() + 1
        })
        .collect();
    let mut cells: Vec<Vec<String>> = Vec::with_capacity(n);
    for r in 0..n {
        let row: Vec<String> = (0..ncols)
            .map(|c| table.column(c).value(r).render())
            .collect();
        for (c, cell) in row.iter().enumerate() {
            widths[c] = widths[c].max(cell.len());
        }
        cells.push(row);
    }

    let mut out = String::new();
    for c in 0..ncols {
        let f = table.schema().field(c);
        let head = format!("{}:{}", f.name, f.dtype.name());
        out.push_str(&format!("{:<w$}  ", head, w = widths[c]));
    }
    out.push('\n');
    for c in 0..ncols {
        out.push_str(&"-".repeat(widths[c]));
        out.push_str("  ");
    }
    out.push('\n');
    for row in &cells {
        for (c, cell) in row.iter().enumerate() {
            out.push_str(&format!("{:<w$}  ", cell, w = widths[c]));
        }
        out.push('\n');
    }
    if table.num_rows() > n {
        out.push_str(&format!("… ({} rows total)\n", table.num_rows()));
    } else {
        out.push_str(&format!("({} rows)\n", table.num_rows()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    #[test]
    fn renders_header_rows_and_footer() {
        let t = Table::from_columns(vec![
            ("id", Column::from_i64(vec![1, 22, 333])),
            ("name", Column::from_opt_str(&[Some("x"), None, Some("zzz")])),
        ])
        .unwrap();
        let s = t.pretty(2);
        assert!(s.contains("id:i64"));
        assert!(s.contains("name:str"));
        assert!(s.contains("22"));
        assert!(!s.contains("333")); // only 2 rows requested
        assert!(s.contains("… (3 rows total)"));
        let full = t.pretty(10);
        assert!(full.contains("333"));
        assert!(full.contains("(3 rows)"));
    }
}
