//! [`Table`] — the paper's central abstraction (§II): an ordered set of
//! named, typed, nullable columns with equal length, stored column-major.
//! Columns are behind `Arc`, so structural ops (project, clone, slice of
//! the schema) are O(columns) not O(rows).

mod pretty;

use std::sync::Arc;

pub use pretty::pretty_table;

use crate::column::Column;
use crate::error::{Result, RylonError};
use crate::types::{Schema, Value};

/// An immutable in-memory data table.
#[derive(Debug, Clone)]
pub struct Table {
    schema: Schema,
    columns: Vec<Arc<Column>>,
    num_rows: usize,
}

impl Table {
    /// Build from a schema and matching columns; validates arity, length
    /// and dtypes.
    pub fn try_new(schema: Schema, columns: Vec<Column>) -> Result<Table> {
        if schema.len() != columns.len() {
            return Err(RylonError::schema(format!(
                "schema has {} fields but {} columns given",
                schema.len(),
                columns.len()
            )));
        }
        let num_rows = columns.first().map_or(0, |c| c.len());
        for (f, c) in schema.fields().iter().zip(&columns) {
            if c.dtype() != f.dtype {
                return Err(RylonError::schema(format!(
                    "column '{}' is {} but schema says {}",
                    f.name,
                    c.dtype(),
                    f.dtype
                )));
            }
            if c.len() != num_rows {
                return Err(RylonError::schema(format!(
                    "column '{}' has {} rows, expected {}",
                    f.name,
                    c.len(),
                    num_rows
                )));
            }
        }
        Ok(Table {
            schema,
            columns: columns.into_iter().map(Arc::new).collect(),
            num_rows,
        })
    }

    /// Build from `(name, column)` pairs, inferring the schema.
    pub fn from_columns(cols: Vec<(&str, Column)>) -> Result<Table> {
        let fields = cols
            .iter()
            .map(|(n, c)| crate::types::Field::new(*n, c.dtype()))
            .collect();
        Table::try_new(
            Schema::new(fields),
            cols.into_iter().map(|(_, c)| c).collect(),
        )
    }

    /// Zero-row table with the given schema.
    pub fn empty(schema: Schema) -> Table {
        let columns = schema
            .fields()
            .iter()
            .map(|f| {
                Arc::new(match f.dtype {
                    crate::types::DataType::Int64 => Column::from_i64(vec![]),
                    crate::types::DataType::Float64 => Column::from_f64(vec![]),
                    crate::types::DataType::Utf8 => {
                        Column::from_str::<&str>(&[])
                    }
                    crate::types::DataType::Bool => Column::from_bool(vec![]),
                })
            })
            .collect();
        Table {
            schema,
            columns,
            num_rows: 0,
        }
    }

    /// Internal: assemble from Arc'd columns without re-validating (the
    /// operators uphold the invariants).
    pub(crate) fn from_parts(
        schema: Schema,
        columns: Vec<Arc<Column>>,
        num_rows: usize,
    ) -> Table {
        debug_assert_eq!(schema.len(), columns.len());
        debug_assert!(columns.iter().all(|c| c.len() == num_rows));
        Table {
            schema,
            columns,
            num_rows,
        }
    }

    // ---- introspection ---------------------------------------------------

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.num_rows
    }

    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.num_rows == 0
    }

    pub fn column(&self, i: usize) -> &Column {
        &self.columns[i]
    }

    pub fn column_arc(&self, i: usize) -> Arc<Column> {
        Arc::clone(&self.columns[i])
    }

    pub fn column_by_name(&self, name: &str) -> Result<&Column> {
        Ok(&self.columns[self.schema.index_of(name)?])
    }

    pub fn columns(&self) -> impl Iterator<Item = &Column> {
        self.columns.iter().map(|c| c.as_ref())
    }

    /// Total buffer bytes (metrics / comm cost model).
    pub fn byte_size(&self) -> usize {
        self.columns.iter().map(|c| c.byte_size()).sum()
    }

    /// Boxed row (off the hot path: debugging, binding layer, row engine).
    pub fn row(&self, i: usize) -> Vec<Value> {
        self.columns.iter().map(|c| c.value(i)).collect()
    }

    // ---- structural ops ----------------------------------------------------

    /// Gather rows by index into a new table.
    pub fn take(&self, indices: &[usize]) -> Table {
        let columns = self
            .columns
            .iter()
            .map(|c| Arc::new(c.take(indices)))
            .collect();
        Table::from_parts(self.schema.clone(), columns, indices.len())
    }

    /// Contiguous row range.
    pub fn slice(&self, offset: usize, len: usize) -> Table {
        let len = len.min(self.num_rows.saturating_sub(offset));
        let columns = self
            .columns
            .iter()
            .map(|c| Arc::new(c.slice(offset, len)))
            .collect();
        Table::from_parts(self.schema.clone(), columns, len)
    }

    /// First `n` rows.
    pub fn head(&self, n: usize) -> Table {
        self.slice(0, n)
    }

    /// Vertical concatenation (schemas must type-match).
    pub fn concat(&self, other: &Table) -> Result<Table> {
        if !self.schema.types_match(&other.schema) {
            return Err(RylonError::schema(format!(
                "concat schema mismatch: [{}] vs [{}]",
                self.schema, other.schema
            )));
        }
        let columns: Result<Vec<_>> = self
            .columns
            .iter()
            .zip(&other.columns)
            .map(|(a, b)| a.concat(b).map(Arc::new))
            .collect();
        Ok(Table::from_parts(
            self.schema.clone(),
            columns?,
            self.num_rows + other.num_rows,
        ))
    }

    /// Concatenate many tables (shuffle receive path).
    pub fn concat_all(schema: &Schema, parts: &[Table]) -> Result<Table> {
        let mut it = parts.iter().filter(|t| !t.is_empty());
        let first = match it.next() {
            None => return Ok(Table::empty(schema.clone())),
            Some(t) => t.clone(),
        };
        it.try_fold(first, |acc, t| acc.concat(t))
    }

    /// Render the first `n` rows as an aligned text grid.
    pub fn pretty(&self, n: usize) -> String {
        pretty_table(self, n)
    }
}

impl PartialEq for Table {
    /// Value equality: same schema types/names, same rows in order.
    fn eq(&self, other: &Self) -> bool {
        self.schema == other.schema
            && self.num_rows == other.num_rows
            && self
                .columns
                .iter()
                .zip(&other.columns)
                .all(|(a, b)| a == b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::DataType;

    fn t() -> Table {
        Table::from_columns(vec![
            ("id", Column::from_i64(vec![1, 2, 3])),
            ("v", Column::from_f64(vec![0.5, 1.5, 2.5])),
            ("tag", Column::from_str(&["a", "b", "c"])),
        ])
        .unwrap()
    }

    #[test]
    fn construct_and_introspect() {
        let t = t();
        assert_eq!(t.num_rows(), 3);
        assert_eq!(t.num_columns(), 3);
        assert_eq!(t.schema().field(1).dtype, DataType::Float64);
        assert_eq!(
            t.column_by_name("tag").unwrap().value(2),
            Value::Utf8("c".into())
        );
        assert!(t.column_by_name("nope").is_err());
    }

    #[test]
    fn validation_rejects_mismatch() {
        let schema = Schema::parse("a:i64,b:f64").unwrap();
        // Wrong arity.
        assert!(Table::try_new(schema.clone(), vec![Column::from_i64(vec![1])])
            .is_err());
        // Wrong dtype.
        assert!(Table::try_new(
            schema.clone(),
            vec![Column::from_i64(vec![1]), Column::from_i64(vec![1])]
        )
        .is_err());
        // Ragged lengths.
        assert!(Table::try_new(
            schema,
            vec![Column::from_i64(vec![1]), Column::from_f64(vec![1.0, 2.0])]
        )
        .is_err());
    }

    #[test]
    fn take_slice_head() {
        let t = t();
        let g = t.take(&[2, 0]);
        assert_eq!(g.num_rows(), 2);
        assert_eq!(g.row(0), vec![3i64.into(), 2.5.into(), "c".into()]);
        let s = t.slice(1, 5); // clamped
        assert_eq!(s.num_rows(), 2);
        assert_eq!(t.head(1).num_rows(), 1);
    }

    #[test]
    fn concat_and_equality() {
        let t = t();
        let c = t.concat(&t).unwrap();
        assert_eq!(c.num_rows(), 6);
        assert_eq!(c.slice(3, 3), t);
        let all =
            Table::concat_all(t.schema(), &[t.clone(), t.clone(), t.clone()])
                .unwrap();
        assert_eq!(all.num_rows(), 9);
        let none = Table::concat_all(t.schema(), &[]).unwrap();
        assert_eq!(none.num_rows(), 0);
        assert_eq!(none.schema(), t.schema());
    }

    #[test]
    fn empty_table_has_typed_columns() {
        let e = Table::empty(Schema::parse("a:i64,b:str").unwrap());
        assert_eq!(e.num_rows(), 0);
        assert_eq!(e.column(1).dtype(), DataType::Utf8);
    }

    #[test]
    fn byte_size_sums_columns() {
        let t = t();
        assert!(t.byte_size() > 3 * 8 * 2);
    }
}
