//! The dynamic "binding" layer — arm (b) of the Fig 12 reproduction.
//!
//! PyCylon's thesis (§IV, Fig 12) is that a *thin* dynamic binding over
//! a fast core costs almost nothing, because the per-call overhead
//! (string dispatch, boxed argument marshalling, option parsing) is
//! amortised over the whole columnar operation — unlike per-row
//! boundaries. This module is that thin layer for Rust: a string-keyed,
//! boxed-argument API with PyCylon's method surface. The Fig 12 bench
//! drives the identical join through (a) the typed core API, (b) this
//! layer, and (c) the PJRT artifact path, and measures the deltas.

use std::collections::HashMap;

use crate::error::{Result, RylonError};
use crate::ops;
use crate::ops::groupby::{Agg, GroupByOptions};
use crate::ops::join::{JoinAlgo, JoinOptions, JoinType};
use crate::ops::orderby::SortKey;
use crate::ops::select::Predicate;
use crate::table::Table;
use crate::types::Value;

/// Boxed call arguments: string → value, PyCylon-kwargs style.
pub type Kwargs = HashMap<String, Value>;

/// Build kwargs tersely.
pub fn kwargs(pairs: &[(&str, Value)]) -> Kwargs {
    pairs
        .iter()
        .map(|(k, v)| (k.to_string(), v.clone()))
        .collect()
}

/// A dynamically-dispatched table handle (the "DataTable" of §IV).
#[derive(Debug, Clone)]
pub struct DynTable {
    inner: Table,
}

impl DynTable {
    pub fn wrap(table: Table) -> DynTable {
        DynTable { inner: table }
    }

    pub fn unwrap(self) -> Table {
        self.inner
    }

    pub fn table(&self) -> &Table {
        &self.inner
    }

    fn str_arg<'k>(kw: &'k Kwargs, key: &str) -> Result<&'k str> {
        kw.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| {
                RylonError::invalid(format!("missing/invalid kwarg '{key}'"))
            })
    }

    /// One-table methods: `select`, `project`, `orderby`, `distinct`,
    /// `groupby`. Marshals every argument from boxed values, then calls
    /// the typed core.
    pub fn call(&self, method: &str, kw: &Kwargs) -> Result<DynTable> {
        let out = match method {
            "select" => {
                let expr = Self::str_arg(kw, "expr")?;
                ops::select(&self.inner, &Predicate::parse(expr)?)?
            }
            "project" => {
                let cols = Self::str_arg(kw, "columns")?;
                let names: Vec<&str> =
                    cols.split(',').map(|s| s.trim()).collect();
                ops::project(&self.inner, &names)?
            }
            "orderby" => {
                let keyspec = Self::str_arg(kw, "by")?;
                let keys: Vec<SortKey> = keyspec
                    .split(',')
                    .map(|s| {
                        let s = s.trim();
                        match s.strip_prefix('-') {
                            Some(col) => SortKey::desc(col),
                            None => SortKey::asc(s),
                        }
                    })
                    .collect();
                ops::orderby(&self.inner, &keys)?
            }
            "distinct" => ops::distinct(&self.inner),
            "groupby" => {
                let keyspec = Self::str_arg(kw, "by")?;
                let aggspec = Self::str_arg(kw, "agg")?;
                let keys: Vec<&str> =
                    keyspec.split(',').map(|s| s.trim()).collect();
                let mut aggs = Vec::new();
                for a in aggspec.split(',') {
                    // "sum(v)" form.
                    let a = a.trim();
                    let (kind, col) = a
                        .split_once('(')
                        .and_then(|(k, rest)| {
                            rest.strip_suffix(')').map(|c| (k, c))
                        })
                        .ok_or_else(|| {
                            RylonError::invalid(format!(
                                "bad agg spec '{a}' (want kind(col))"
                            ))
                        })?;
                    let kind =
                        crate::compute::aggregate::AggKind::parse(kind)
                            .ok_or_else(|| {
                                RylonError::invalid(format!(
                                    "unknown aggregate '{kind}'"
                                ))
                            })?;
                    aggs.push(Agg::new(kind, col));
                }
                ops::groupby(
                    &self.inner,
                    &GroupByOptions {
                        keys: keys.iter().map(|s| s.to_string()).collect(),
                        aggs,
                    },
                )?
            }
            other => {
                return Err(RylonError::invalid(format!(
                    "unknown method '{other}'"
                )))
            }
        };
        Ok(DynTable::wrap(out))
    }

    /// Two-table methods: `join`, `union`, `intersect`, `difference`.
    pub fn call2(
        &self,
        method: &str,
        other: &DynTable,
        kw: &Kwargs,
    ) -> Result<DynTable> {
        let out = match method {
            "join" => {
                let on = Self::str_arg(kw, "on")?;
                let jt = kw
                    .get("how")
                    .and_then(|v| v.as_str())
                    .unwrap_or("inner");
                let join_type = JoinType::parse(jt).ok_or_else(|| {
                    RylonError::invalid(format!("unknown join type '{jt}'"))
                })?;
                let algo = kw
                    .get("algorithm")
                    .and_then(|v| v.as_str())
                    .unwrap_or("sort");
                let algo = JoinAlgo::parse(algo).ok_or_else(|| {
                    RylonError::invalid(format!("unknown join algo '{algo}'"))
                })?;
                let keys: Vec<&str> =
                    on.split(',').map(|s| s.trim()).collect();
                let opts = JoinOptions::new(join_type, &keys, &keys)
                    .with_algo(algo);
                ops::join(&self.inner, &other.inner, &opts)?
            }
            "union" => ops::union(&self.inner, &other.inner)?,
            "intersect" => ops::intersect(&self.inner, &other.inner)?,
            "difference" => ops::difference(&self.inner, &other.inner)?,
            other => {
                return Err(RylonError::invalid(format!(
                    "unknown method '{other}'"
                )))
            }
        };
        Ok(DynTable::wrap(out))
    }

    /// Boxed row export (PyCylon's `to_pandas`-style materialisation) —
    /// deliberately pays the per-row boxing cost; used by the row-engine
    /// baselines and tests.
    pub fn to_rows(&self) -> Vec<Vec<Value>> {
        (0..self.inner.num_rows())
            .map(|i| self.inner.row(i))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    fn t() -> DynTable {
        DynTable::wrap(
            Table::from_columns(vec![
                ("id", Column::from_i64(vec![1, 2, 3])),
                ("v", Column::from_f64(vec![1.5, 0.5, 2.5])),
            ])
            .unwrap(),
        )
    }

    #[test]
    fn dynamic_select_project() {
        let r = t().call("select", &kwargs(&[("expr", "v > 1".into())]))
            .unwrap();
        assert_eq!(r.table().num_rows(), 2);
        let p = r
            .call("project", &kwargs(&[("columns", "v".into())]))
            .unwrap();
        assert_eq!(p.table().num_columns(), 1);
    }

    #[test]
    fn dynamic_join_matches_typed() {
        let l = t();
        let r = t();
        let dyn_out = l
            .call2(
                "join",
                &r,
                &kwargs(&[
                    ("on", "id".into()),
                    ("how", "inner".into()),
                    ("algorithm", "hash".into()),
                ]),
            )
            .unwrap();
        let typed = ops::join(
            l.table(),
            r.table(),
            &JoinOptions::inner("id", "id").with_algo(JoinAlgo::Hash),
        )
        .unwrap();
        assert_eq!(dyn_out.table().num_rows(), typed.num_rows());
    }

    #[test]
    fn dynamic_groupby_and_orderby() {
        let g = t()
            .call(
                "groupby",
                &kwargs(&[
                    ("by", "id".into()),
                    ("agg", "sum(v),count(v)".into()),
                ]),
            )
            .unwrap();
        assert_eq!(g.table().num_rows(), 3);
        assert!(g.table().schema().contains("sum_v"));
        let o = t().call("orderby", &kwargs(&[("by", "-v".into())])).unwrap();
        assert_eq!(o.table().column(1).f64_values()[0], 2.5);
    }

    #[test]
    fn error_paths() {
        assert!(t().call("nope", &kwargs(&[])).is_err());
        assert!(t().call("select", &kwargs(&[])).is_err());
        assert!(t()
            .call("groupby", &kwargs(&[
                ("by", "id".into()),
                ("agg", "sum v".into()),
            ]))
            .is_err());
        assert!(t()
            .call2("join", &t(), &kwargs(&[
                ("on", "id".into()),
                ("how", "sideways".into()),
            ]))
            .is_err());
    }

    #[test]
    fn to_rows_boxes() {
        let rows = t().to_rows();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0][0], Value::Int64(1));
    }
}
