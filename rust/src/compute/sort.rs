//! Sorting kernels. The paper's joins are sort-joins ("sorting … is the
//! core task in Cylon joins", §V-1), so argsort speed dominates the local
//! phase. Two paths:
//!
//! * [`argsort_i64`] — LSD radix sort on 64-bit keys (sign-flipped so
//!   order is numeric), 8 passes × 8 bits over index/key pairs. This is
//!   the hot path for the benchmark workloads (int64 join keys).
//! * [`argsort_by_columns`] — general multi-column comparison sort
//!   (stable `sort_unstable_by` over row indices with a lexicographic
//!   comparator), used for strings/mixed keys and orderby.

use std::cmp::Ordering;

use crate::column::Column;

/// Argsort of an i64 slice via LSD radix sort; `nulls_first` rows (given
/// by `validity`) are emitted ahead of all valid rows. Returns the
/// permutation `perm` such that `keys[perm]` is ascending.
pub fn argsort_i64(keys: &[i64], validity: Option<&crate::buffer::Bitmap>) -> Vec<usize> {
    let n = keys.len();
    // Partition nulls up front (rare path).
    let mut nulls: Vec<usize> = Vec::new();
    let mut pairs: Vec<(u64, u32)> = Vec::with_capacity(n);
    match validity {
        None => {
            for (i, &k) in keys.iter().enumerate() {
                pairs.push(((k as u64) ^ (1u64 << 63), i as u32));
            }
        }
        Some(bm) => {
            for (i, &k) in keys.iter().enumerate() {
                if bm.get(i) {
                    pairs.push(((k as u64) ^ (1u64 << 63), i as u32));
                } else {
                    nulls.push(i);
                }
            }
        }
    }

    radix_sort_pairs(&mut pairs);

    let mut out = nulls;
    out.extend(pairs.iter().map(|&(_, i)| i as usize));
    out
}

/// LSD radix sort of (key, payload) pairs, 8 bits per pass, skipping
/// passes whose byte is constant (common for small key domains).
pub fn radix_sort_pairs(pairs: &mut Vec<(u64, u32)>) {
    let n = pairs.len();
    if n <= 1 {
        return;
    }
    let mut scratch: Vec<(u64, u32)> = vec![(0, 0); n];
    let mut src_is_pairs = true;
    for pass in 0..8 {
        let shift = pass * 8;
        // Histogram.
        let mut counts = [0usize; 256];
        {
            let src: &[(u64, u32)] = if src_is_pairs { pairs } else { &scratch };
            for &(k, _) in src {
                counts[((k >> shift) & 0xFF) as usize] += 1;
            }
        }
        // Skip constant-byte passes.
        if counts.iter().any(|&c| c == n) {
            continue;
        }
        // Prefix sums.
        let mut offsets = [0usize; 256];
        let mut acc = 0usize;
        for b in 0..256 {
            offsets[b] = acc;
            acc += counts[b];
        }
        // Scatter.
        if src_is_pairs {
            for &(k, v) in pairs.iter() {
                let b = ((k >> shift) & 0xFF) as usize;
                scratch[offsets[b]] = (k, v);
                offsets[b] += 1;
            }
        } else {
            for &(k, v) in scratch.iter() {
                let b = ((k >> shift) & 0xFF) as usize;
                pairs[offsets[b]] = (k, v);
                offsets[b] += 1;
            }
        }
        src_is_pairs = !src_is_pairs;
    }
    if !src_is_pairs {
        pairs.copy_from_slice(&scratch);
    }
}

/// Generic argsort over several key columns with per-key direction
/// (`true` = descending). Stable so ties preserve input order.
pub fn argsort_by_columns(
    cols: &[&Column],
    descending: &[bool],
    nrows: usize,
) -> Vec<usize> {
    debug_assert_eq!(cols.len(), descending.len());
    let mut idx: Vec<usize> = (0..nrows).collect();
    idx.sort_by(|&a, &b| {
        for (c, &desc) in cols.iter().zip(descending) {
            let ord = c.cmp_rows(a, *c, b);
            let ord = if desc { ord.reverse() } else { ord };
            if ord != Ordering::Equal {
                return ord;
            }
        }
        Ordering::Equal
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Bitmap;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn radix_matches_std_sort() {
        let mut r = Xoshiro256::new(9);
        let keys: Vec<i64> =
            (0..10_000).map(|_| r.next_u64() as i64).collect();
        let perm = argsort_i64(&keys, None);
        let mut expect: Vec<i64> = keys.clone();
        expect.sort_unstable();
        let got: Vec<i64> = perm.iter().map(|&i| keys[i]).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn radix_handles_negatives_and_extremes() {
        let keys = vec![i64::MAX, -1, 0, i64::MIN, 5, -5];
        let perm = argsort_i64(&keys, None);
        let got: Vec<i64> = perm.iter().map(|&i| keys[i]).collect();
        assert_eq!(got, vec![i64::MIN, -5, -1, 0, 5, i64::MAX]);
    }

    #[test]
    fn nulls_sort_first() {
        let keys = vec![3, 1, 2];
        let bm = Bitmap::from_bools(&[true, false, true]);
        let perm = argsort_i64(&keys, Some(&bm));
        assert_eq!(perm, vec![1, 2, 0]);
    }

    #[test]
    fn small_domain_skips_passes() {
        // All keys < 256: only one meaningful pass; result still correct.
        let keys: Vec<i64> = (0..1000).map(|i| (i * 7 % 256) as i64).collect();
        let perm = argsort_i64(&keys, None);
        for w in perm.windows(2) {
            assert!(keys[w[0]] <= keys[w[1]]);
        }
    }

    #[test]
    fn multi_column_lexicographic_and_desc() {
        let a = Column::from_i64(vec![1, 1, 0, 0]);
        let b = Column::from_str(&["x", "a", "z", "z"]);
        let idx = argsort_by_columns(&[&a, &b], &[false, false], 4);
        assert_eq!(idx, vec![2, 3, 1, 0]);
        let idx = argsort_by_columns(&[&a, &b], &[true, false], 4);
        assert_eq!(idx, vec![1, 0, 2, 3]);
    }

    #[test]
    fn stability_on_ties() {
        let a = Column::from_i64(vec![5, 5, 5]);
        let idx = argsort_by_columns(&[&a], &[false], 3);
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn empty_and_single() {
        assert!(argsort_i64(&[], None).is_empty());
        assert_eq!(argsort_i64(&[7], None), vec![0]);
    }
}
