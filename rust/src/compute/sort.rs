//! Sorting kernels. The paper's joins are sort-joins ("sorting … is the
//! core task in Cylon joins", §V-1), so argsort speed dominates the local
//! phase. Two paths:
//!
//! * [`argsort_i64`] — LSD radix sort on 64-bit keys (sign-flipped so
//!   order is numeric), 8 passes × 8 bits over index/key pairs. This is
//!   the hot path for the benchmark workloads (int64 join keys).
//! * [`argsort_by_columns`] — general multi-column comparison sort
//!   (stable `sort_unstable_by` over row indices with a lexicographic
//!   comparator), used for strings/mixed keys and orderby.

use std::cmp::Ordering;
use std::mem::{ManuallyDrop, MaybeUninit};

use crate::column::Column;
use crate::exec::{self, ExecContext};

/// Sign-flip an i64 so its u64 bit pattern sorts numerically.
#[inline]
fn flip_i64(k: i64) -> u64 {
    (k as u64) ^ (1u64 << 63)
}

/// Argsort of an i64 slice via LSD radix sort; `nulls_first` rows (given
/// by `validity`) are emitted ahead of all valid rows. Returns the
/// permutation `perm` such that `keys[perm]` is ascending. Large inputs
/// run as a parallel run-sort + stable k-way (pairwise) merge on the
/// calling thread's morsel budget — both paths are stable sorts on the
/// same key, so the permutation is identical at any thread count.
pub fn argsort_i64(keys: &[i64], validity: Option<&crate::buffer::Bitmap>) -> Vec<usize> {
    let n = keys.len();
    let exec = exec::parallelism_for(n);
    if exec.is_parallel() {
        return argsort_i64_parallel(keys, validity, exec);
    }
    // Partition nulls up front (rare path).
    let mut nulls: Vec<usize> = Vec::new();
    let mut pairs: Vec<(u64, u32)> = Vec::with_capacity(n);
    match validity {
        None => {
            for (i, &k) in keys.iter().enumerate() {
                pairs.push((flip_i64(k), i as u32));
            }
        }
        Some(bm) => {
            for (i, &k) in keys.iter().enumerate() {
                if bm.get(i) {
                    pairs.push((flip_i64(k), i as u32));
                } else {
                    nulls.push(i);
                }
            }
        }
    }

    radix_sort_pairs(&mut pairs);

    let mut out = nulls;
    out.extend(pairs.iter().map(|&(_, i)| i as usize));
    out
}

/// Parallel run-sort: radix-sort index-contiguous runs concurrently,
/// then stable-merge adjacent runs pairwise (ties take the left run, so
/// equal keys keep original index order — exactly the serial radix
/// sort's stability).
fn argsort_i64_parallel(
    keys: &[i64],
    validity: Option<&crate::buffer::Bitmap>,
    exec: ExecContext,
) -> Vec<usize> {
    let runs_in = exec::split_even(keys.len(), exec.threads());
    let sorted_runs: Vec<(Vec<usize>, Vec<(u64, u32)>)> =
        exec::map_parallel(runs_in, |m| {
            let mut nulls: Vec<usize> = Vec::new();
            let mut pairs: Vec<(u64, u32)> = Vec::with_capacity(m.len());
            match validity {
                None => {
                    for i in m.range() {
                        pairs.push((flip_i64(keys[i]), i as u32));
                    }
                }
                Some(bm) => {
                    for i in m.range() {
                        if bm.get(i) {
                            pairs.push((flip_i64(keys[i]), i as u32));
                        } else {
                            nulls.push(i);
                        }
                    }
                }
            }
            radix_sort_pairs(&mut pairs);
            (nulls, pairs)
        });
    let mut out: Vec<usize> = Vec::with_capacity(keys.len());
    let mut runs: Vec<Vec<(u64, u32)>> = Vec::with_capacity(sorted_runs.len());
    for (nulls, pairs) in sorted_runs {
        out.extend(nulls); // runs are in index order → nulls stay in index order
        runs.push(pairs);
    }
    let merged = merge_runs_stable_by(runs, |b, a| b.0 < a.0);
    out.extend(merged.iter().map(|&(_, i)| i as usize));
    out
}

/// Elements per merge-path chunk: every merge level is cut into
/// output-contiguous chunks of about this many elements, so a level is
/// one wide, evenly sized pool batch instead of one task per pairwise
/// merge (whose count halves every level, starving workers — local or
/// stolen — near the top of the tree).
const MERGE_CHUNK_ELEMS: usize = exec::MORSEL_ROWS;

/// Number of elements of `a` among the first `k` outputs of the stable
/// merge of sorted runs `a` and `b` (ties take `a` — the left run).
/// Binary search over the merge path, so any output range of the merge
/// can be produced independently and exactly.
fn merge_split<T, F>(a: &[T], b: &[T], k: usize, take_right: &F) -> usize
where
    F: Fn(&T, &T) -> bool,
{
    let mut lo = k.saturating_sub(b.len());
    let mut hi = k.min(a.len());
    while lo < hi {
        // i < hi ≤ min(k, a.len()) keeps a[i] in bounds and j ≥ 1;
        // i ≥ lo ≥ k - b.len() keeps b[j-1] in bounds.
        let i = lo + (hi - lo) / 2;
        let j = k - i;
        if !take_right(&b[j - 1], &a[i]) {
            // a[i] is output before b[j-1]: too few taken from `a`.
            lo = i + 1;
        } else {
            hi = i;
        }
    }
    lo
}

/// Write output range `[out_lo, out_lo + dst.len())` of the stable
/// merge of `a` and `b` (ties take `a`) straight into `dst`. Chunks
/// computed at the same split points tile exactly the full stable
/// merge, so disjoint `dst` sub-slices of one output buffer need no
/// post-pass concatenation (each element is written once). `dst` is
/// uninitialized storage — this function writes every element of it
/// and reads none (the contract [`merge_runs_stable_by`]'s
/// `assume_init` step relies on).
fn merge_path_chunk_into<T, F>(
    a: &[T],
    b: &[T],
    out_lo: usize,
    take_right: &F,
    dst: &mut [MaybeUninit<T>],
) where
    T: Copy,
    F: Fn(&T, &T) -> bool,
{
    let out_hi = out_lo + dst.len();
    let i_lo = merge_split(a, b, out_lo, take_right);
    let i_hi = merge_split(a, b, out_hi, take_right);
    let (a, b) = (&a[i_lo..i_hi], &b[out_lo - i_lo..out_hi - i_hi]);
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        if take_right(&b[j], &a[i]) {
            dst[k] = MaybeUninit::new(b[j]);
            j += 1;
        } else {
            dst[k] = MaybeUninit::new(a[i]);
            i += 1;
        }
        k += 1;
    }
    for &x in &a[i..] {
        dst[k] = MaybeUninit::new(x);
        k += 1;
    }
    for &x in &b[j..] {
        dst[k] = MaybeUninit::new(x);
        k += 1;
    }
}

/// Reinterpret a fully initialized `Vec<MaybeUninit<T>>` as `Vec<T>`.
///
/// # Safety
///
/// Every element must have been initialized. `MaybeUninit<T>` has the
/// same size and alignment as `T`, so the raw parts carry over as-is.
unsafe fn assume_init_vec<T>(v: Vec<MaybeUninit<T>>) -> Vec<T> {
    let mut v = ManuallyDrop::new(v);
    let (ptr, len, cap) = (v.as_mut_ptr(), v.len(), v.capacity());
    // SAFETY: same allocation, same layout, all elements initialized
    // (caller contract).
    unsafe { Vec::from_raw_parts(ptr as *mut T, len, cap) }
}

/// Pairwise stable merge of adjacent sorted runs until one remains.
/// Each level is submitted as **one pool batch**: every pair's merge
/// is cut into output-disjoint merge-path chunks
/// ([`MERGE_CHUNK_ELEMS`]) and all chunks across all pairs fan out
/// together, so workers — including sibling ranks' workers stealing
/// into a skewed rank — see a whole level of uniform work even when
/// the level has a single pairwise merge left. `take_right(b, a)`
/// returns true only when `b` sorts *strictly* before `a` — on ties
/// the left (earlier-index) run wins, which with split points computed
/// by the same rule keeps parallel permutations bit-identical to the
/// serial stable sorts at any thread count and any chunk layout.
pub(crate) fn merge_runs_stable_by<T, F>(
    mut runs: Vec<Vec<T>>,
    take_right: F,
) -> Vec<T>
where
    T: Copy + Send + Sync,
    F: Fn(&T, &T) -> bool + Sync,
{
    if runs.is_empty() {
        return Vec::new();
    }
    while runs.len() > 1 {
        let mut it = runs.into_iter();
        let mut pairs: Vec<(Vec<T>, Vec<T>)> = Vec::new();
        let mut carry: Option<Vec<T>> = None;
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => pairs.push((a, b)),
                None => carry = Some(a),
            }
        }
        // The whole level as one batch of near-equal chunks, each task
        // writing its disjoint sub-slice of the pair's preallocated
        // output in place. Buffers stay **uninitialized** — a merge
        // level's output is all fresh writes, so the old `T::default()`
        // fill was a full O(n) memset per level of pure overhead. The
        // chunks tile each output exactly and every task writes every
        // element of its sub-slice ([`merge_path_chunk_into`]'s
        // contract), which is what makes the `assume_init_vec` below
        // sound; the miri CI leg runs these merges to hold that claim.
        let mut outs: Vec<Vec<MaybeUninit<T>>> = pairs
            .iter()
            .map(|(a, b)| vec![MaybeUninit::uninit(); a.len() + b.len()])
            .collect();
        let mut tasks: Vec<(usize, usize, &mut [MaybeUninit<T>])> =
            Vec::new();
        for ((p, (a, b)), out) in
            pairs.iter().enumerate().zip(outs.iter_mut())
        {
            let len = a.len() + b.len();
            // At least two chunks per pair (when the pair has ≥ 2
            // elements), so the split path runs — and is therefore
            // equivalence-tested — at every size.
            let chunks = len
                .div_ceil(MERGE_CHUNK_ELEMS)
                .max(if len >= 2 { 2 } else { 1 });
            let mut pos = 0usize;
            let mut rest: &mut [MaybeUninit<T>] = out.as_mut_slice();
            for c in 0..chunks {
                let hi = len * (c + 1) / chunks;
                if hi == pos {
                    continue;
                }
                let (head, tail) =
                    std::mem::take(&mut rest).split_at_mut(hi - pos);
                rest = tail;
                tasks.push((p, pos, head));
                pos = hi;
            }
        }
        let pairs_ref = &pairs;
        let take_right_ref = &take_right;
        // Budget-capped submission: the batch is deliberately wider
        // than the rank's budget so *stealing siblings* can help — it
        // must not grow the local pool past `exec` (oversubscription).
        exec::map_parallel_budgeted(tasks, |(p, lo, dst)| {
            let (a, b) = &pairs_ref[p];
            merge_path_chunk_into(a, b, lo, take_right_ref, dst);
        });
        let mut next: Vec<Vec<T>> = outs
            .into_iter()
            // SAFETY: the chunk tasks tiled `[0, len)` exactly (the
            // split loop advances `pos` to `len`) and the pool's
            // completion barrier sequences their writes before this
            // read, so every element is initialized.
            .map(|out| unsafe { assume_init_vec(out) })
            .collect();
        if let Some(c) = carry {
            next.push(c);
        }
        runs = next;
    }
    runs.pop().unwrap()
}

/// LSD radix sort of (key, payload) pairs, 8 bits per pass, skipping
/// passes whose byte is constant (common for small key domains).
pub fn radix_sort_pairs(pairs: &mut Vec<(u64, u32)>) {
    let n = pairs.len();
    if n <= 1 {
        return;
    }
    let mut scratch: Vec<(u64, u32)> = vec![(0, 0); n];
    let mut src_is_pairs = true;
    for pass in 0..8 {
        let shift = pass * 8;
        // Histogram.
        let mut counts = [0usize; 256];
        {
            let src: &[(u64, u32)] = if src_is_pairs { pairs } else { &scratch };
            for &(k, _) in src {
                counts[((k >> shift) & 0xFF) as usize] += 1;
            }
        }
        // Skip constant-byte passes.
        if counts.iter().any(|&c| c == n) {
            continue;
        }
        // Prefix sums.
        let mut offsets = [0usize; 256];
        let mut acc = 0usize;
        for b in 0..256 {
            offsets[b] = acc;
            acc += counts[b];
        }
        // Scatter.
        if src_is_pairs {
            for &(k, v) in pairs.iter() {
                let b = ((k >> shift) & 0xFF) as usize;
                scratch[offsets[b]] = (k, v);
                offsets[b] += 1;
            }
        } else {
            for &(k, v) in scratch.iter() {
                let b = ((k >> shift) & 0xFF) as usize;
                pairs[offsets[b]] = (k, v);
                offsets[b] += 1;
            }
        }
        src_is_pairs = !src_is_pairs;
    }
    if !src_is_pairs {
        pairs.copy_from_slice(&scratch);
    }
}

/// Generic argsort over several key columns with per-key direction
/// (`true` = descending). Stable so ties preserve input order. Large
/// inputs run as a parallel stable run-sort + stable merge — the same
/// permutation as the serial stable sort.
pub fn argsort_by_columns(
    cols: &[&Column],
    descending: &[bool],
    nrows: usize,
) -> Vec<usize> {
    debug_assert_eq!(cols.len(), descending.len());
    let cmp = |a: usize, b: usize| -> Ordering {
        for (c, &desc) in cols.iter().zip(descending) {
            let ord = c.cmp_rows(a, *c, b);
            let ord = if desc { ord.reverse() } else { ord };
            if ord != Ordering::Equal {
                return ord;
            }
        }
        Ordering::Equal
    };
    let exec = exec::parallelism_for(nrows);
    if exec.is_parallel() {
        let runs: Vec<Vec<usize>> =
            exec::map_parallel(exec::split_even(nrows, exec.threads()), |m| {
                let mut idx: Vec<usize> = m.range().collect();
                idx.sort_by(|&a, &b| cmp(a, b));
                idx
            });
        return merge_runs_stable_by(runs, |&b, &a| {
            cmp(b, a) == Ordering::Less
        });
    }
    let mut idx: Vec<usize> = (0..nrows).collect();
    idx.sort_by(|&a, &b| cmp(a, b));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Bitmap;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn radix_matches_std_sort() {
        let mut r = Xoshiro256::new(9);
        let keys: Vec<i64> =
            (0..10_000).map(|_| r.next_u64() as i64).collect();
        let perm = argsort_i64(&keys, None);
        let mut expect: Vec<i64> = keys.clone();
        expect.sort_unstable();
        let got: Vec<i64> = perm.iter().map(|&i| keys[i]).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn radix_handles_negatives_and_extremes() {
        let keys = vec![i64::MAX, -1, 0, i64::MIN, 5, -5];
        let perm = argsort_i64(&keys, None);
        let got: Vec<i64> = perm.iter().map(|&i| keys[i]).collect();
        assert_eq!(got, vec![i64::MIN, -5, -1, 0, 5, i64::MAX]);
    }

    #[test]
    fn nulls_sort_first() {
        let keys = vec![3, 1, 2];
        let bm = Bitmap::from_bools(&[true, false, true]);
        let perm = argsort_i64(&keys, Some(&bm));
        assert_eq!(perm, vec![1, 2, 0]);
    }

    #[test]
    fn small_domain_skips_passes() {
        // All keys < 256: only one meaningful pass; result still correct.
        let keys: Vec<i64> = (0..1000).map(|i| (i * 7 % 256) as i64).collect();
        let perm = argsort_i64(&keys, None);
        for w in perm.windows(2) {
            assert!(keys[w[0]] <= keys[w[1]]);
        }
    }

    #[test]
    fn multi_column_lexicographic_and_desc() {
        let a = Column::from_i64(vec![1, 1, 0, 0]);
        let b = Column::from_str(&["x", "a", "z", "z"]);
        let idx = argsort_by_columns(&[&a, &b], &[false, false], 4);
        assert_eq!(idx, vec![2, 3, 1, 0]);
        let idx = argsort_by_columns(&[&a, &b], &[true, false], 4);
        assert_eq!(idx, vec![1, 0, 2, 3]);
    }

    #[test]
    fn stability_on_ties() {
        let a = Column::from_i64(vec![5, 5, 5]);
        let idx = argsort_by_columns(&[&a], &[false], 3);
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn empty_and_single() {
        assert!(argsort_i64(&[], None).is_empty());
        assert_eq!(argsort_i64(&[7], None), vec![0]);
    }

    #[test]
    fn merge_path_chunks_reassemble_the_stable_merge() {
        // Heavy ties across every chunk boundary: the split points must
        // reproduce the exact left-wins-on-ties stable merge at any
        // chunk count.
        let mut r = Xoshiro256::new(41);
        let mut a: Vec<(u64, u32)> =
            (0..1000).map(|i| (r.next_below(7), i)).collect();
        let mut b: Vec<(u64, u32)> =
            (0..1300).map(|i| (r.next_below(7), 1000 + i)).collect();
        a.sort_by_key(|&(k, _)| k);
        b.sort_by_key(|&(k, _)| k);
        let take_right =
            |x: &(u64, u32), y: &(u64, u32)| -> bool { x.0 < y.0 };
        // Naive reference merge.
        let mut expect = Vec::new();
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() && j < b.len() {
            if take_right(&b[j], &a[i]) {
                expect.push(b[j]);
                j += 1;
            } else {
                expect.push(a[i]);
                i += 1;
            }
        }
        expect.extend_from_slice(&a[i..]);
        expect.extend_from_slice(&b[j..]);
        let len = a.len() + b.len();
        for chunks in [1usize, 2, 3, 7, 64, len] {
            let mut got: Vec<MaybeUninit<(u64, u32)>> =
                vec![MaybeUninit::uninit(); len];
            for c in 0..chunks {
                let lo = len * c / chunks;
                let hi = len * (c + 1) / chunks;
                merge_path_chunk_into(
                    &a,
                    &b,
                    lo,
                    &take_right,
                    &mut got[lo..hi],
                );
            }
            // SAFETY: the chunk ranges tile [0, len) exactly, so every
            // element was written above.
            let got = unsafe { assume_init_vec(got) };
            assert_eq!(got, expect, "chunks={chunks}");
        }
        // Degenerate inputs: one empty run, and an empty output chunk.
        let mut only_a: Vec<MaybeUninit<(u64, u32)>> =
            vec![MaybeUninit::uninit(); a.len()];
        merge_path_chunk_into(&a, &[], 0, &take_right, &mut only_a);
        // SAFETY: the full-range chunk writes every element.
        assert_eq!(unsafe { assume_init_vec(only_a) }, a);
        let mut only_b: Vec<MaybeUninit<(u64, u32)>> =
            vec![MaybeUninit::uninit(); b.len()];
        merge_path_chunk_into(&[], &b, 0, &take_right, &mut only_b);
        // SAFETY: the full-range chunk writes every element.
        assert_eq!(unsafe { assume_init_vec(only_b) }, b);
        merge_path_chunk_into(&a, &b, 5, &take_right, &mut []);
    }

    #[test]
    fn parallel_argsort_i64_identical_permutation() {
        let mut r = Xoshiro256::new(77);
        // Narrow domain forces heavy ties → stability is observable.
        let keys: Vec<i64> =
            (0..50_000).map(|_| (r.next_below(97) as i64) - 48).collect();
        let serial = argsort_i64(&keys, None);
        for threads in [2, 3, 4, 8] {
            let par = crate::exec::with_intra_op_threads(threads, || {
                argsort_i64(&keys, None)
            });
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn parallel_argsort_i64_nulls_first_in_index_order() {
        let mut r = Xoshiro256::new(78);
        let n = 20_000;
        let keys: Vec<i64> =
            (0..n).map(|_| r.next_below(1000) as i64).collect();
        let valid: Vec<bool> = (0..n).map(|i| i % 5 != 0).collect();
        let bm = Bitmap::from_bools(&valid);
        let serial = argsort_i64(&keys, Some(&bm));
        let par = crate::exec::with_intra_op_threads(4, || {
            argsort_i64(&keys, Some(&bm))
        });
        assert_eq!(par, serial);
    }

    #[test]
    fn parallel_argsort_by_columns_identical() {
        let mut r = Xoshiro256::new(79);
        let n = 20_000usize;
        let a = Column::from_i64(
            (0..n).map(|_| r.next_below(50) as i64).collect(),
        );
        let strs: Vec<String> =
            (0..n).map(|_| format!("s{}", r.next_below(20))).collect();
        let b = Column::from_str(
            &strs.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        let serial = argsort_by_columns(&[&a, &b], &[false, true], n);
        let par = crate::exec::with_intra_op_threads(4, || {
            argsort_by_columns(&[&a, &b], &[false, true], n)
        });
        assert_eq!(par, serial);
    }
}
