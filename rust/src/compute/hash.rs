//! Row hashing for partitioning and hash joins.
//!
//! The scalar finalizer is **splitmix64** — bit-exact with the L1 Pallas
//! kernel (`python/compile/kernels/hash_partition.py`), so a key hashed
//! on the Rust hot path lands in the same bucket as one hashed through
//! the AOT artifact. `rust/tests/pjrt_artifacts.rs` cross-checks the two
//! paths on real batches.

use std::hash::{BuildHasherDefault, Hasher};

use crate::column::Column;
use crate::error::{Result, RylonError};
use crate::table::Table;

/// No-op hasher for keys that are already splitmix64-mixed (§Perf:
/// avoids SipHash re-hashing inside hash joins / groupby / set ops —
/// the u64 *is* the hash).
#[derive(Default)]
pub struct IdentityHasher {
    state: u64,
}

impl Hasher for IdentityHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, _bytes: &[u8]) {
        unreachable!("IdentityHasher only accepts u64 keys");
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = v;
    }
}

/// `HashMap` keyed by pre-hashed u64s with no re-hashing.
pub type PreHashedMap<V> =
    std::collections::HashMap<u64, V, BuildHasherDefault<IdentityHasher>>;

/// A chained multimap for (hash → row ids): one `heads` map plus a
/// `next` chain indexed by row — a single allocation regardless of the
/// number of buckets (vs `HashMap<u64, Vec<u32>>`'s alloc per key).
pub struct HashChains {
    heads: PreHashedMap<u32>,
    next: Vec<u32>,
}

pub const CHAIN_END: u32 = u32::MAX;

impl HashChains {
    /// Build from row hashes, skipping rows where `skip(row)` is true.
    pub fn build<F: Fn(usize) -> bool>(hashes: &[u64], skip: F) -> HashChains {
        let mut heads: PreHashedMap<u32> = PreHashedMap::with_capacity_and_hasher(
            hashes.len() * 2,
            Default::default(),
        );
        let mut next = vec![CHAIN_END; hashes.len()];
        for (i, &h) in hashes.iter().enumerate() {
            if skip(i) {
                continue;
            }
            let e = heads.entry(h).or_insert(CHAIN_END);
            next[i] = *e;
            *e = i as u32;
        }
        HashChains { heads, next }
    }

    /// Iterate the rows in the bucket for hash `h` (reverse insertion
    /// order).
    #[inline]
    pub fn bucket(&self, h: u64) -> ChainIter<'_> {
        ChainIter {
            next: &self.next,
            cur: self.heads.get(&h).copied().unwrap_or(CHAIN_END),
        }
    }
}

/// Iterator over one hash chain.
pub struct ChainIter<'a> {
    next: &'a [u32],
    cur: u32,
}

impl Iterator for ChainIter<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        if self.cur == CHAIN_END {
            None
        } else {
            let i = self.cur as usize;
            self.cur = self.next[i];
            Some(i)
        }
    }
}

/// splitmix64 finalizer (Steele et al.) — the crate-wide scalar hash.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// FNV-1a 64 over bytes (strings) feeding into the finalizer.
#[inline]
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    splitmix64(h)
}

const NULL_SENTINEL: u64 = 0x6E75_6C6C_6E75_6C6C; // "nullnull"

/// Hash one row of one column.
#[inline]
pub fn hash_cell(col: &Column, row: usize) -> u64 {
    if !col.is_valid(row) {
        return splitmix64(NULL_SENTINEL);
    }
    match col {
        Column::Int64(c) => splitmix64(c.value(row) as u64),
        Column::Float64(c) => {
            // Normalise -0.0 to 0.0 so equal floats hash equal.
            let v = c.value(row);
            let v = if v == 0.0 { 0.0 } else { v };
            splitmix64(v.to_bits())
        }
        Column::Utf8(c) => hash_bytes(c.value(row).as_bytes()),
        Column::Bool(c) => splitmix64(c.value(row) as u64),
    }
}

/// Hash every row of a column into `out` (overwrites).
pub fn hash_column(col: &Column, out: &mut Vec<u64>) {
    out.clear();
    out.reserve(col.len());
    match col {
        // Monomorphic fast path for the common i64 join key: no validity
        // check per row when the column has no nulls.
        Column::Int64(c) if c.validity().is_none() => {
            out.extend(c.values().iter().map(|&v| splitmix64(v as u64)));
        }
        _ => out.extend((0..col.len()).map(|i| hash_cell(col, i))),
    }
}

/// Combined hash over multiple key columns (boost-style hash_combine on
/// top of the per-cell finalizer).
pub fn hash_columns(cols: &[&Column], nrows: usize, out: &mut Vec<u64>) {
    out.clear();
    if cols.is_empty() {
        out.resize(nrows, splitmix64(0));
        return;
    }
    hash_column(cols[0], out);
    for col in &cols[1..] {
        for (i, h) in out.iter_mut().enumerate() {
            let c = hash_cell(col, i);
            // hash_combine: h ^= c + golden + (h<<6) + (h>>2)
            *h ^= c
                .wrapping_add(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(*h << 6)
                .wrapping_add(*h >> 2);
        }
    }
}

/// Hash the named key columns of a table.
pub fn hash_table_keys(
    table: &Table,
    keys: &[String],
    out: &mut Vec<u64>,
) -> Result<()> {
    if keys.is_empty() {
        return Err(RylonError::invalid("empty key list"));
    }
    let cols: Result<Vec<&Column>> = keys
        .iter()
        .map(|k| table.column_by_name(k))
        .collect();
    hash_columns(&cols?, table.num_rows(), out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_reference_vector() {
        // Same golden constant pinned by the python test
        // (test_splitmix64_known_vectors): splitmix64(0).
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn equal_values_hash_equal_across_construction() {
        let a = Column::from_i64(vec![42, -1]);
        let b = Column::from_opt_i64(vec![Some(42), None]);
        assert_eq!(hash_cell(&a, 0), hash_cell(&b, 0));
        assert_ne!(hash_cell(&a, 1), hash_cell(&b, 1));
    }

    #[test]
    fn negative_zero_normalised() {
        let c = Column::from_f64(vec![0.0, -0.0]);
        assert_eq!(hash_cell(&c, 0), hash_cell(&c, 1));
    }

    #[test]
    fn nulls_hash_consistently() {
        let a = Column::from_opt_i64(vec![None]);
        let b = Column::from_opt_f64(vec![None]);
        assert_eq!(hash_cell(&a, 0), hash_cell(&b, 0));
    }

    #[test]
    fn fast_path_matches_generic() {
        let vals: Vec<i64> = (0..1000).map(|i| i * 31 - 500).collect();
        let dense = Column::from_i64(vals.clone());
        let opt = Column::from_opt_i64(vals.iter().map(|&v| Some(v)).collect());
        let (mut h1, mut h2) = (Vec::new(), Vec::new());
        hash_column(&dense, &mut h1);
        hash_column(&opt, &mut h2);
        assert_eq!(h1, h2);
    }

    #[test]
    fn multi_key_order_sensitive() {
        let a = Column::from_i64(vec![1]);
        let b = Column::from_i64(vec![2]);
        let (mut h_ab, mut h_ba) = (Vec::new(), Vec::new());
        hash_columns(&[&a, &b], 1, &mut h_ab);
        hash_columns(&[&b, &a], 1, &mut h_ba);
        assert_ne!(h_ab, h_ba);
    }

    #[test]
    fn string_hash_differs() {
        let c = Column::from_str(&["abc", "abd", ""]);
        assert_ne!(hash_cell(&c, 0), hash_cell(&c, 1));
        assert_ne!(hash_cell(&c, 0), hash_cell(&c, 2));
    }

    #[test]
    fn hash_chains_bucket_contents() {
        let hashes = vec![7u64, 9, 7, 7, 9, 1];
        let chains = HashChains::build(&hashes, |i| i == 3); // skip row 3
        let b7: Vec<usize> = chains.bucket(7).collect();
        assert_eq!(b7, vec![2, 0]); // reverse insertion, row 3 skipped
        let b9: Vec<usize> = chains.bucket(9).collect();
        assert_eq!(b9, vec![4, 1]);
        assert_eq!(chains.bucket(999).count(), 0);
    }

    #[test]
    fn table_key_hash_errors() {
        let t = Table::from_columns(vec![("a", Column::from_i64(vec![1]))])
            .unwrap();
        let mut out = Vec::new();
        assert!(hash_table_keys(&t, &[], &mut out).is_err());
        assert!(
            hash_table_keys(&t, &["nope".into()], &mut out).is_err()
        );
        hash_table_keys(&t, &["a".into()], &mut out).unwrap();
        assert_eq!(out.len(), 1);
    }
}
