//! Row hashing for partitioning and hash joins.
//!
//! The scalar finalizer is **splitmix64** — bit-exact with the L1 Pallas
//! kernel (`python/compile/kernels/hash_partition.py`), so a key hashed
//! on the Rust hot path lands in the same bucket as one hashed through
//! the AOT artifact. `rust/tests/pjrt_artifacts.rs` cross-checks the two
//! paths on real batches.

use std::hash::{BuildHasherDefault, Hasher};

use crate::column::Column;
use crate::error::{Result, RylonError};
use crate::exec::{self, ExecContext, SendPtr};
use crate::table::Table;

/// No-op hasher for keys that are already splitmix64-mixed (§Perf:
/// avoids SipHash re-hashing inside hash joins / groupby / set ops —
/// the u64 *is* the hash).
#[derive(Default)]
pub struct IdentityHasher {
    state: u64,
}

impl Hasher for IdentityHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, _bytes: &[u8]) {
        unreachable!("IdentityHasher only accepts u64 keys");
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = v;
    }
}

/// `HashMap` keyed by pre-hashed u64s with no re-hashing.
pub type PreHashedMap<V> =
    std::collections::HashMap<u64, V, BuildHasherDefault<IdentityHasher>>;

/// A chained multimap for (hash → row ids): one `heads` map plus a
/// `next` chain indexed by row — a single allocation regardless of the
/// number of buckets (vs `HashMap<u64, Vec<u32>>`'s alloc per key).
pub struct HashChains {
    heads: PreHashedMap<u32>,
    next: Vec<u32>,
}

pub const CHAIN_END: u32 = u32::MAX;

impl HashChains {
    /// Build from row hashes, skipping rows where `skip(row)` is true.
    pub fn build<F: Fn(usize) -> bool>(hashes: &[u64], skip: F) -> HashChains {
        let mut heads: PreHashedMap<u32> = PreHashedMap::with_capacity_and_hasher(
            hashes.len() * 2,
            Default::default(),
        );
        let mut next = vec![CHAIN_END; hashes.len()];
        for (i, &h) in hashes.iter().enumerate() {
            if skip(i) {
                continue;
            }
            let e = heads.entry(h).or_insert(CHAIN_END);
            next[i] = *e;
            *e = i as u32;
        }
        HashChains { heads, next }
    }

    /// Parallel build: rows are radix-partitioned by the **top** hash
    /// bits ([`hash_partition_of`] — independent of the map's low-bit
    /// bucket indexing), so each worker owns a disjoint slice of the
    /// hash space and inserts its rows in ascending row order. The
    /// resulting `next` chains and per-hash bucket contents are
    /// bit-identical to [`HashChains::build`]; only the (unobservable)
    /// heads-map memory layout differs.
    ///
    /// The partition count is sized by [`exec::split_width`] — the
    /// steal group's capacity, not just the local budget — so a rank
    /// with `intra_op_threads = 1` whose pool is steal-linked to idle
    /// siblings still cuts the build into widths they can help with
    /// (partition count never changes the chains, so this is free).
    pub fn build_parallel<F>(
        hashes: &[u64],
        skip: F,
        exec: ExecContext,
    ) -> HashChains
    where
        F: Fn(usize) -> bool + Sync,
    {
        let nparts = exec::split_width(exec);
        if nparts <= 1 || hashes.len() < exec::par_row_threshold() {
            return Self::build(hashes, skip);
        }
        let n = hashes.len();
        // One O(n) morsel-parallel prepass buckets row ids per
        // partition, so each insert worker touches only its own rows
        // (no per-worker full rescans of `hashes`).
        let rows_by_part = partition_rows(hashes, nparts, exec, skip);
        let mut next = vec![CHAIN_END; n];
        let ptr = SendPtr(next.as_mut_ptr());
        let maps = exec::run_partitions(nparts, |p| {
            let mut heads: PreHashedMap<u32> =
                PreHashedMap::with_capacity_and_hasher(
                    n * 2 / nparts + 8,
                    Default::default(),
                );
            for morsel_buckets in &rows_by_part {
                for &i in &morsel_buckets[p] {
                    let e =
                        heads.entry(hashes[i as usize]).or_insert(CHAIN_END);
                    // SAFETY: row i is written only by the worker owning
                    // its hash partition; partitions are disjoint.
                    unsafe {
                        *ptr.0.add(i as usize) = *e;
                    }
                    *e = i;
                }
            }
            heads
        });
        let mut heads: PreHashedMap<u32> =
            PreHashedMap::with_capacity_and_hasher(
                n * 2,
                Default::default(),
            );
        for m in maps {
            heads.extend(m);
        }
        HashChains { heads, next }
    }

    /// Iterate the rows in the bucket for hash `h` (reverse insertion
    /// order).
    #[inline]
    pub fn bucket(&self, h: u64) -> ChainIter<'_> {
        ChainIter {
            next: &self.next,
            cur: self.heads.get(&h).copied().unwrap_or(CHAIN_END),
        }
    }
}

/// Owner partition of a hash for the parallel builders: the high 32
/// bits scaled into `[0, nparts)`, so the split never correlates with
/// the map's low-bit bucket choice.
#[inline]
pub fn hash_partition_of(h: u64, nparts: usize) -> usize {
    (((h >> 32) as usize) * nparts) >> 32
}

/// Morsel-parallel scatter of row ids by hash partition. Indexed
/// `[morsel][partition] → ascending row ids`, so iterating morsels in
/// order yields each partition's rows in ascending row order — the
/// serial insertion order the bit-identity contract requires. Rows with
/// `skip(row)` true are dropped.
pub(crate) fn partition_rows<F>(
    hashes: &[u64],
    nparts: usize,
    exec: ExecContext,
    skip: F,
) -> Vec<Vec<Vec<u32>>>
where
    F: Fn(usize) -> bool + Sync,
{
    exec::for_each_morsel(hashes.len(), exec, |m| {
        let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); nparts];
        for i in m.range() {
            if !skip(i) {
                buckets[hash_partition_of(hashes[i], nparts)]
                    .push(i as u32);
            }
        }
        buckets
    })
}

/// Distinct-key interner on pre-hashed keys: chained group ids per hash
/// bucket over a [`PreHashedMap`], first-occurrence group numbering —
/// the one bucket structure behind both the serial and the parallel
/// groupby (and the layout sibling of [`HashChains`]).
pub struct GroupIndex {
    heads: PreHashedMap<u32>,
    next_group: Vec<u32>,
    rep_rows: Vec<usize>,
}

impl GroupIndex {
    pub fn with_capacity(capacity: usize) -> GroupIndex {
        GroupIndex {
            heads: PreHashedMap::with_capacity_and_hasher(
                capacity,
                Default::default(),
            ),
            next_group: Vec::new(),
            rep_rows: Vec::new(),
        }
    }

    /// Group id for `row` with hash `h`; `eq(rep, row)` decides key
    /// equality against a group's representative row. Returns
    /// `(gid, newly_created)`.
    #[inline]
    pub fn intern<EQ: Fn(usize, usize) -> bool>(
        &mut self,
        h: u64,
        row: usize,
        eq: EQ,
    ) -> (u32, bool) {
        let head = self.heads.entry(h).or_insert(CHAIN_END);
        let mut cur = *head;
        while cur != CHAIN_END {
            if eq(self.rep_rows[cur as usize], row) {
                return (cur, false);
            }
            cur = self.next_group[cur as usize];
        }
        let gid = self.rep_rows.len() as u32;
        self.rep_rows.push(row);
        self.next_group.push(*head);
        *head = gid;
        (gid, true)
    }

    pub fn num_groups(&self) -> usize {
        self.rep_rows.len()
    }

    /// Representative (first-occurrence) row per group, in group order.
    pub fn rep_rows(&self) -> &[usize] {
        &self.rep_rows
    }
}

/// Iterator over one hash chain.
pub struct ChainIter<'a> {
    next: &'a [u32],
    cur: u32,
}

impl Iterator for ChainIter<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        if self.cur == CHAIN_END {
            None
        } else {
            let i = self.cur as usize;
            self.cur = self.next[i];
            Some(i)
        }
    }
}

/// splitmix64 finalizer (Steele et al.) — the crate-wide scalar hash.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// FNV-1a 64 over bytes (strings) feeding into the finalizer.
#[inline]
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    splitmix64(h)
}

const NULL_SENTINEL: u64 = 0x6E75_6C6C_6E75_6C6C; // "nullnull"

/// The hash of a null cell — what [`hash_cell`] returns for an invalid
/// row. Exposed so the fused pipeline can hash the null-extended cells
/// of a left join (right row id `-1`) without materializing them.
#[inline]
pub(crate) fn hash_null() -> u64 {
    splitmix64(NULL_SENTINEL)
}

/// boost-style hash_combine — the multi-key fold step shared by
/// [`hash_columns`], [`hash_rows`] and the fused pipeline's entry
/// hashing (all three must agree bit-for-bit).
#[inline]
pub(crate) fn hash_combine(h: u64, c: u64) -> u64 {
    h ^ c
        .wrapping_add(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(h << 6)
        .wrapping_add(h >> 2)
}

/// Hash one row of one column.
#[inline]
pub fn hash_cell(col: &Column, row: usize) -> u64 {
    if !col.is_valid(row) {
        return splitmix64(NULL_SENTINEL);
    }
    match col {
        Column::Int64(c) => splitmix64(c.value(row) as u64),
        Column::Float64(c) => {
            // Normalise -0.0 to 0.0 so equal floats hash equal.
            let v = c.value(row);
            let v = if v == 0.0 { 0.0 } else { v };
            splitmix64(v.to_bits())
        }
        Column::Utf8(c) => hash_bytes(c.value(row).as_bytes()),
        Column::Bool(c) => splitmix64(c.value(row) as u64),
    }
}

/// Hash every row of a column into `out` (overwrites).
pub fn hash_column(col: &Column, out: &mut Vec<u64>) {
    out.clear();
    out.reserve(col.len());
    match col {
        // Monomorphic fast path for the common i64 join key: no validity
        // check per row when the column has no nulls.
        Column::Int64(c) if c.validity().is_none() => {
            out.extend(c.values().iter().map(|&v| splitmix64(v as u64)));
        }
        _ => out.extend((0..col.len()).map(|i| hash_cell(col, i))),
    }
}

/// Combined hash over multiple key columns (boost-style hash_combine on
/// top of the per-cell finalizer). Large inputs fan out over the
/// calling thread's morsel budget; per-row arithmetic is unchanged, so
/// the output is bit-identical at any thread count.
pub fn hash_columns(cols: &[&Column], nrows: usize, out: &mut Vec<u64>) {
    out.clear();
    if cols.is_empty() {
        out.resize(nrows, splitmix64(0));
        return;
    }
    out.resize(nrows, 0);
    let exec = exec::parallelism_for(nrows);
    exec::fill_parallel(out.as_mut_slice(), exec, |m, dst| {
        hash_range_into(cols, m.start, dst);
    });
}

/// Hash rows `[start, start + dst.len())` of the key columns into `dst`
/// — the shared per-morsel kernel of [`hash_columns`].
fn hash_range_into(cols: &[&Column], start: usize, dst: &mut [u64]) {
    match cols[0] {
        // Monomorphic fast path for the common dense i64 key.
        Column::Int64(c) if c.validity().is_none() => {
            let vals = &c.values()[start..start + dst.len()];
            for (d, &v) in dst.iter_mut().zip(vals) {
                *d = splitmix64(v as u64);
            }
        }
        first => {
            for (k, d) in dst.iter_mut().enumerate() {
                *d = hash_cell(first, start + k);
            }
        }
    }
    for col in &cols[1..] {
        for (k, h) in dst.iter_mut().enumerate() {
            *h = hash_combine(*h, hash_cell(col, start + k));
        }
    }
}

/// Combined hash ([`hash_columns`] arithmetic) over an explicit row
/// list: `out[k]` is the key hash of row `rows[k]`, cell-identical to
/// what [`hash_columns`] puts at that row — so a fused probe that
/// hashes only the rows surviving earlier stages sees exactly the
/// hashes the materialized path would have computed after a gather.
pub(crate) fn hash_rows(
    cols: &[&Column],
    rows: &[usize],
    out: &mut Vec<u64>,
) {
    out.clear();
    if cols.is_empty() {
        out.resize(rows.len(), splitmix64(0));
        return;
    }
    out.reserve(rows.len());
    out.extend(rows.iter().map(|&r| hash_cell(cols[0], r)));
    for col in &cols[1..] {
        for (h, &r) in out.iter_mut().zip(rows) {
            *h = hash_combine(*h, hash_cell(col, r));
        }
    }
}

/// Hash the named key columns of a table.
pub fn hash_table_keys(
    table: &Table,
    keys: &[String],
    out: &mut Vec<u64>,
) -> Result<()> {
    if keys.is_empty() {
        return Err(RylonError::invalid("empty key list"));
    }
    let cols: Result<Vec<&Column>> = keys
        .iter()
        .map(|k| table.column_by_name(k))
        .collect();
    hash_columns(&cols?, table.num_rows(), out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_reference_vector() {
        // Same golden constant pinned by the python test
        // (test_splitmix64_known_vectors): splitmix64(0).
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn equal_values_hash_equal_across_construction() {
        let a = Column::from_i64(vec![42, -1]);
        let b = Column::from_opt_i64(vec![Some(42), None]);
        assert_eq!(hash_cell(&a, 0), hash_cell(&b, 0));
        assert_ne!(hash_cell(&a, 1), hash_cell(&b, 1));
    }

    #[test]
    fn negative_zero_normalised() {
        let c = Column::from_f64(vec![0.0, -0.0]);
        assert_eq!(hash_cell(&c, 0), hash_cell(&c, 1));
    }

    #[test]
    fn nulls_hash_consistently() {
        let a = Column::from_opt_i64(vec![None]);
        let b = Column::from_opt_f64(vec![None]);
        assert_eq!(hash_cell(&a, 0), hash_cell(&b, 0));
    }

    #[test]
    fn fast_path_matches_generic() {
        let vals: Vec<i64> = (0..1000).map(|i| i * 31 - 500).collect();
        let dense = Column::from_i64(vals.clone());
        let opt = Column::from_opt_i64(vals.iter().map(|&v| Some(v)).collect());
        let (mut h1, mut h2) = (Vec::new(), Vec::new());
        hash_column(&dense, &mut h1);
        hash_column(&opt, &mut h2);
        assert_eq!(h1, h2);
    }

    #[test]
    fn multi_key_order_sensitive() {
        let a = Column::from_i64(vec![1]);
        let b = Column::from_i64(vec![2]);
        let (mut h_ab, mut h_ba) = (Vec::new(), Vec::new());
        hash_columns(&[&a, &b], 1, &mut h_ab);
        hash_columns(&[&b, &a], 1, &mut h_ba);
        assert_ne!(h_ab, h_ba);
    }

    #[test]
    fn string_hash_differs() {
        let c = Column::from_str(&["abc", "abd", ""]);
        assert_ne!(hash_cell(&c, 0), hash_cell(&c, 1));
        assert_ne!(hash_cell(&c, 0), hash_cell(&c, 2));
    }

    #[test]
    fn hash_chains_bucket_contents() {
        let hashes = vec![7u64, 9, 7, 7, 9, 1];
        let chains = HashChains::build(&hashes, |i| i == 3); // skip row 3
        let b7: Vec<usize> = chains.bucket(7).collect();
        assert_eq!(b7, vec![2, 0]); // reverse insertion, row 3 skipped
        let b9: Vec<usize> = chains.bucket(9).collect();
        assert_eq!(b9, vec![4, 1]);
        assert_eq!(chains.bucket(999).count(), 0);
    }

    #[test]
    fn parallel_chains_match_serial() {
        let hashes: Vec<u64> = (0..20_000u64)
            .map(|i| splitmix64(i % 500))
            .collect();
        let skip = |i: usize| i % 17 == 0;
        let serial = HashChains::build(&hashes, skip);
        let par = HashChains::build_parallel(
            &hashes,
            skip,
            crate::exec::ExecContext::new(4),
        );
        for h in hashes.iter().take(1000) {
            let a: Vec<usize> = serial.bucket(*h).collect();
            let b: Vec<usize> = par.bucket(*h).collect();
            assert_eq!(a, b, "bucket {h:#x}");
        }
    }

    #[test]
    fn parallel_hash_columns_match_serial() {
        let n = 10_000;
        let a = Column::from_i64((0..n as i64).collect());
        let b = Column::from_opt_f64(
            (0..n)
                .map(|i| if i % 7 == 0 { None } else { Some(i as f64) })
                .collect(),
        );
        let mut serial = Vec::new();
        crate::exec::with_intra_op_threads(1, || {
            hash_columns(&[&a, &b], n, &mut serial);
        });
        let mut par = Vec::new();
        crate::exec::with_intra_op_threads(4, || {
            hash_columns(&[&a, &b], n, &mut par);
        });
        assert_eq!(serial, par);
    }

    #[test]
    fn group_index_first_occurrence_order() {
        let keys = [5u64, 7, 5, 9, 7, 5];
        let mut gi = GroupIndex::with_capacity(8);
        let mut gids = Vec::new();
        for (row, &k) in keys.iter().enumerate() {
            let (g, _) =
                gi.intern(splitmix64(k), row, |rep, r| keys[rep] == keys[r]);
            gids.push(g);
        }
        assert_eq!(gids, vec![0, 1, 0, 2, 1, 0]);
        assert_eq!(gi.num_groups(), 3);
        assert_eq!(gi.rep_rows(), &[0, 1, 3]);
    }

    #[test]
    fn hash_partition_covers_and_bounds() {
        for nparts in [1usize, 2, 3, 8, 128] {
            let mut seen = vec![false; nparts];
            for i in 0..10_000u64 {
                let p = hash_partition_of(splitmix64(i), nparts);
                assert!(p < nparts);
                seen[p] = true;
            }
            assert!(seen.iter().all(|&s| s), "nparts={nparts}");
        }
    }

    #[test]
    fn hash_rows_matches_hash_columns_gather() {
        let n = 257;
        let a = Column::from_opt_i64(
            (0..n as i64)
                .map(|i| if i % 11 == 0 { None } else { Some(i % 37) })
                .collect(),
        );
        let strings: Vec<String> =
            (0..n).map(|i| format!("s{}", i % 13)).collect();
        let refs: Vec<&str> = strings.iter().map(String::as_str).collect();
        let b = Column::from_str(&refs);
        let mut full = Vec::new();
        hash_columns(&[&a, &b], n, &mut full);
        let rows: Vec<usize> = (0..n).filter(|i| i % 3 != 0).collect();
        let mut sub = Vec::new();
        hash_rows(&[&a, &b], &rows, &mut sub);
        let expect: Vec<u64> = rows.iter().map(|&r| full[r]).collect();
        assert_eq!(sub, expect);
        // Empty key list mirrors hash_columns' constant fill.
        hash_rows(&[], &rows, &mut sub);
        assert!(sub.iter().all(|&h| h == splitmix64(0)));
        assert_eq!(sub.len(), rows.len());
    }

    #[test]
    fn table_key_hash_errors() {
        let t = Table::from_columns(vec![("a", Column::from_i64(vec![1]))])
            .unwrap();
        let mut out = Vec::new();
        assert!(hash_table_keys(&t, &[], &mut out).is_err());
        assert!(
            hash_table_keys(&t, &["nope".into()], &mut out).is_err()
        );
        hash_table_keys(&t, &["a".into()], &mut out).unwrap();
        assert_eq!(out.len(), 1);
    }
}
