//! Aggregation primitives for groupby and whole-column reductions.
//! Null handling follows SQL: nulls are skipped; `count` counts non-null
//! rows; an all-null group yields null (except count = 0).

use crate::column::Column;
use crate::error::{Result, RylonError};
use crate::types::Value;

/// Streaming accumulator for one aggregate over one group.
#[derive(Debug, Clone)]
pub enum Accumulator {
    Sum { acc: f64, any: bool, int: bool },
    Min { acc: Option<Value> },
    Max { acc: Option<Value> },
    Count { n: i64 },
    Mean { sum: f64, n: i64 },
}

/// The aggregate functions offered by `groupby` (paper-adjacent set; the
/// paper's Table I covers relational ops, groupby is part of the
/// DataTable API surface PyCylon exposes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggKind {
    Sum,
    Min,
    Max,
    Count,
    Mean,
}

impl AggKind {
    pub fn parse(s: &str) -> Option<AggKind> {
        match s {
            "sum" => Some(AggKind::Sum),
            "min" => Some(AggKind::Min),
            "max" => Some(AggKind::Max),
            "count" => Some(AggKind::Count),
            "mean" | "avg" => Some(AggKind::Mean),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AggKind::Sum => "sum",
            AggKind::Min => "min",
            AggKind::Max => "max",
            AggKind::Count => "count",
            AggKind::Mean => "mean",
        }
    }

    pub fn new_acc(&self, input_is_int: bool) -> Accumulator {
        match self {
            AggKind::Sum => Accumulator::Sum {
                acc: 0.0,
                any: false,
                int: input_is_int,
            },
            AggKind::Min => Accumulator::Min { acc: None },
            AggKind::Max => Accumulator::Max { acc: None },
            AggKind::Count => Accumulator::Count { n: 0 },
            AggKind::Mean => Accumulator::Mean { sum: 0.0, n: 0 },
        }
    }

    /// Output dtype given the input dtype.
    pub fn output_dtype(
        &self,
        input: crate::types::DataType,
    ) -> Result<crate::types::DataType> {
        use crate::types::DataType::*;
        match self {
            AggKind::Count => Ok(Int64),
            AggKind::Mean => {
                if input.is_numeric() {
                    Ok(Float64)
                } else {
                    Err(RylonError::ty(format!("mean over {input}")))
                }
            }
            AggKind::Sum => {
                if input.is_numeric() {
                    Ok(input)
                } else {
                    Err(RylonError::ty(format!("sum over {input}")))
                }
            }
            AggKind::Min | AggKind::Max => Ok(input),
        }
    }
}

impl Accumulator {
    /// Fold row `i` of `col` into the accumulator.
    pub fn update(&mut self, col: &Column, i: usize) {
        if !col.is_valid(i) {
            return;
        }
        match self {
            Accumulator::Sum { acc, any, .. } => {
                *acc += cell_f64(col, i);
                *any = true;
            }
            Accumulator::Min { acc } => {
                let v = col.value(i);
                let better = acc
                    .as_ref()
                    .map_or(true, |cur| v.total_cmp(cur).is_lt());
                if better {
                    *acc = Some(v);
                }
            }
            Accumulator::Max { acc } => {
                let v = col.value(i);
                let better = acc
                    .as_ref()
                    .map_or(true, |cur| v.total_cmp(cur).is_gt());
                if better {
                    *acc = Some(v);
                }
            }
            Accumulator::Count { n } => *n += 1,
            Accumulator::Mean { sum, n } => {
                *sum += cell_f64(col, i);
                *n += 1;
            }
        }
    }

    /// Merge another accumulator of the same kind (distributed combine
    /// step — dist_groupby folds per-rank partials with this).
    pub fn merge(&mut self, other: &Accumulator) {
        match (self, other) {
            (
                Accumulator::Sum { acc, any, .. },
                Accumulator::Sum {
                    acc: oa, any: oany, ..
                },
            ) => {
                *acc += oa;
                *any |= oany;
            }
            (Accumulator::Min { acc }, Accumulator::Min { acc: oa }) => {
                if let Some(ov) = oa {
                    let better = acc
                        .as_ref()
                        .map_or(true, |cur| ov.total_cmp(cur).is_lt());
                    if better {
                        *acc = Some(ov.clone());
                    }
                }
            }
            (Accumulator::Max { acc }, Accumulator::Max { acc: oa }) => {
                if let Some(ov) = oa {
                    let better = acc
                        .as_ref()
                        .map_or(true, |cur| ov.total_cmp(cur).is_gt());
                    if better {
                        *acc = Some(ov.clone());
                    }
                }
            }
            (Accumulator::Count { n }, Accumulator::Count { n: on }) => {
                *n += on;
            }
            (
                Accumulator::Mean { sum, n },
                Accumulator::Mean { sum: os, n: on },
            ) => {
                *sum += os;
                *n += on;
            }
            _ => panic!("merging mismatched accumulators"),
        }
    }

    /// Final boxed result.
    pub fn finish(&self) -> Value {
        match self {
            Accumulator::Sum { acc, any, int } => {
                if !any {
                    Value::Null
                } else if *int {
                    Value::Int64(*acc as i64)
                } else {
                    Value::Float64(*acc)
                }
            }
            Accumulator::Min { acc } | Accumulator::Max { acc } => {
                acc.clone().unwrap_or(Value::Null)
            }
            Accumulator::Count { n } => Value::Int64(*n),
            Accumulator::Mean { sum, n } => {
                if *n == 0 {
                    Value::Null
                } else {
                    Value::Float64(sum / *n as f64)
                }
            }
        }
    }
}

#[inline]
fn cell_f64(col: &Column, i: usize) -> f64 {
    match col {
        Column::Int64(c) => c.value(i) as f64,
        Column::Float64(c) => c.value(i),
        Column::Bool(c) => c.value(i) as u8 as f64,
        Column::Utf8(_) => f64::NAN,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(kind: AggKind, col: &Column) -> Value {
        let mut acc = kind.new_acc(col.dtype() == crate::types::DataType::Int64);
        for i in 0..col.len() {
            acc.update(col, i);
        }
        acc.finish()
    }

    #[test]
    fn sum_min_max_count_mean() {
        let c = Column::from_opt_i64(vec![Some(3), None, Some(-1), Some(4)]);
        assert_eq!(run(AggKind::Sum, &c), Value::Int64(6));
        assert_eq!(run(AggKind::Min, &c), Value::Int64(-1));
        assert_eq!(run(AggKind::Max, &c), Value::Int64(4));
        assert_eq!(run(AggKind::Count, &c), Value::Int64(3));
        assert_eq!(run(AggKind::Mean, &c), Value::Float64(2.0));
    }

    #[test]
    fn all_null_group() {
        let c = Column::from_opt_f64(vec![None, None]);
        assert_eq!(run(AggKind::Sum, &c), Value::Null);
        assert_eq!(run(AggKind::Min, &c), Value::Null);
        assert_eq!(run(AggKind::Count, &c), Value::Int64(0));
        assert_eq!(run(AggKind::Mean, &c), Value::Null);
    }

    #[test]
    fn string_min_max() {
        let c = Column::from_str(&["pear", "apple", "zebra"]);
        assert_eq!(run(AggKind::Min, &c), Value::Utf8("apple".into()));
        assert_eq!(run(AggKind::Max, &c), Value::Utf8("zebra".into()));
        assert!(AggKind::Sum.output_dtype(crate::types::DataType::Utf8).is_err());
    }

    #[test]
    fn merge_equals_sequential() {
        let c = Column::from_f64(vec![1.0, 2.0, 3.0, 4.0]);
        for kind in [
            AggKind::Sum,
            AggKind::Min,
            AggKind::Max,
            AggKind::Count,
            AggKind::Mean,
        ] {
            let mut whole = kind.new_acc(false);
            for i in 0..4 {
                whole.update(&c, i);
            }
            let mut a = kind.new_acc(false);
            let mut b = kind.new_acc(false);
            a.update(&c, 0);
            a.update(&c, 1);
            b.update(&c, 2);
            b.update(&c, 3);
            a.merge(&b);
            assert_eq!(a.finish(), whole.finish(), "{kind:?}");
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(AggKind::parse("avg"), Some(AggKind::Mean));
        assert_eq!(AggKind::parse("sum").unwrap().name(), "sum");
        assert_eq!(AggKind::parse("nope"), None);
    }
}
