//! Selection vectors: turn a predicate into row indices and gather.
//! Select/project and the partition scatter all funnel through here.

use crate::error::Result;
use crate::table::Table;
use crate::types::Value;

/// Indices of rows where `pred` is true.
pub fn filter_indices<F: FnMut(usize) -> bool>(nrows: usize, mut pred: F) -> Vec<usize> {
    let mut out = Vec::new();
    for i in 0..nrows {
        if pred(i) {
            out.push(i);
        }
    }
    out
}

/// Gather rows of `table` by `indices`.
pub fn take_indices(table: &Table, indices: &[usize]) -> Table {
    table.take(indices)
}

/// Filter a table with a row-level predicate over boxed values — the
/// *convenience* select path (binding layer, examples). The typed
/// operators in `ops::select` offer columnar predicates that never box.
pub fn filter_table<F>(table: &Table, mut pred: F) -> Result<Table>
where
    F: FnMut(&[Value]) -> bool,
{
    let mut keep = Vec::new();
    let mut row: Vec<Value>;
    for i in 0..table.num_rows() {
        row = table.row(i);
        if pred(&row) {
            keep.push(i);
        }
    }
    Ok(table.take(&keep))
}

/// Scatter rows into `nparts` index lists according to `pids` (the
/// partition step of every distributed operator). `pids[i] == -1`
/// (masked/padded lanes from the kernel path) are dropped.
pub fn scatter_indices(pids: &[i32], nparts: usize) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); nparts];
    for (i, &p) in pids.iter().enumerate() {
        if p >= 0 {
            out[p as usize].push(i);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    fn t() -> Table {
        Table::from_columns(vec![
            ("id", Column::from_i64(vec![1, 2, 3, 4])),
            ("v", Column::from_f64(vec![0.1, 0.9, 0.5, 0.7])),
        ])
        .unwrap()
    }

    #[test]
    fn filter_indices_basic() {
        assert_eq!(filter_indices(5, |i| i % 2 == 0), vec![0, 2, 4]);
        assert!(filter_indices(0, |_| true).is_empty());
    }

    #[test]
    fn filter_table_by_row() {
        let t = t();
        let f = filter_table(&t, |row| row[1].as_f64().unwrap() > 0.6).unwrap();
        assert_eq!(f.num_rows(), 2);
        assert_eq!(f.column(0).i64_values(), &[2, 4]);
    }

    #[test]
    fn scatter_partitions_and_drops_masked() {
        let pids = vec![0, 1, 0, -1, 2];
        let parts = scatter_indices(&pids, 3);
        assert_eq!(parts[0], vec![0, 2]);
        assert_eq!(parts[1], vec![1]);
        assert_eq!(parts[2], vec![4]);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 4);
    }
}
