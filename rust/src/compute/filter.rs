//! Selection vectors: turn a predicate into row indices and gather.
//! Select/project and the partition scatter all funnel through here.
//! Gathers over dense fixed-width columns fan out across the calling
//! thread's morsel budget (bit-identical to the serial gather).

use std::sync::Arc;

use crate::column::{Column, PrimitiveColumn};
use crate::error::Result;
use crate::exec::{self, ExecContext};
use crate::table::Table;
use crate::types::Value;

/// Indices of rows where `pred` is true.
pub fn filter_indices<F: FnMut(usize) -> bool>(nrows: usize, mut pred: F) -> Vec<usize> {
    let mut out = Vec::new();
    for i in 0..nrows {
        if pred(i) {
            out.push(i);
        }
    }
    out
}

/// Gather rows of `table` by `indices`.
pub fn take_indices(table: &Table, indices: &[usize]) -> Table {
    table.take(indices)
}

/// Morsel-parallel `Table::take`: dense fixed-width columns gather into
/// disjoint output ranges concurrently; nullable and string columns use
/// the serial per-column gather. Output equals `table.take(indices)`.
pub fn take_parallel(
    table: &Table,
    indices: &[usize],
    exec: ExecContext,
) -> Table {
    if !exec.is_parallel() || indices.len() < exec::PAR_ROW_THRESHOLD {
        return table.take(indices);
    }
    let columns: Vec<Arc<Column>> = table
        .columns()
        .map(|c| Arc::new(take_column_parallel(c, indices, exec)))
        .collect();
    Table::from_parts(table.schema().clone(), columns, indices.len())
}

/// Morsel-parallel gather of one column (see [`take_parallel`]).
pub fn take_column_parallel(
    col: &Column,
    indices: &[usize],
    exec: ExecContext,
) -> Column {
    if !exec.is_parallel() || indices.len() < exec::PAR_ROW_THRESHOLD {
        return col.take(indices);
    }
    match col {
        Column::Int64(c) if c.validity().is_none() => Column::Int64(
            PrimitiveColumn::from_values(exec::par_gather(
                c.values(),
                indices,
                exec,
            )),
        ),
        Column::Float64(c) if c.validity().is_none() => Column::Float64(
            PrimitiveColumn::from_values(exec::par_gather(
                c.values(),
                indices,
                exec,
            )),
        ),
        Column::Bool(c) if c.validity().is_none() => Column::Bool(
            PrimitiveColumn::from_values(exec::par_gather(
                c.values(),
                indices,
                exec,
            )),
        ),
        // Validity bitmaps share words across morsel boundaries and
        // string gathers need byte-offset prefix sums — serial path.
        other => other.take(indices),
    }
}

/// Filter a table with a row-level predicate over boxed values — the
/// *convenience* select path (binding layer, examples). The typed
/// operators in `ops::select` offer columnar predicates that never box.
pub fn filter_table<F>(table: &Table, mut pred: F) -> Result<Table>
where
    F: FnMut(&[Value]) -> bool,
{
    let mut keep = Vec::new();
    let mut row: Vec<Value>;
    for i in 0..table.num_rows() {
        row = table.row(i);
        if pred(&row) {
            keep.push(i);
        }
    }
    Ok(table.take(&keep))
}

/// Scatter rows into `nparts` index lists according to `pids` (the
/// partition step of every distributed operator). `pids[i] == -1`
/// (masked/padded lanes from the kernel path) are dropped.
pub fn scatter_indices(pids: &[i32], nparts: usize) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); nparts];
    for (i, &p) in pids.iter().enumerate() {
        if p >= 0 {
            out[p as usize].push(i);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    fn t() -> Table {
        Table::from_columns(vec![
            ("id", Column::from_i64(vec![1, 2, 3, 4])),
            ("v", Column::from_f64(vec![0.1, 0.9, 0.5, 0.7])),
        ])
        .unwrap()
    }

    #[test]
    fn filter_indices_basic() {
        assert_eq!(filter_indices(5, |i| i % 2 == 0), vec![0, 2, 4]);
        assert!(filter_indices(0, |_| true).is_empty());
    }

    #[test]
    fn filter_table_by_row() {
        let t = t();
        let f = filter_table(&t, |row| row[1].as_f64().unwrap() > 0.6).unwrap();
        assert_eq!(f.num_rows(), 2);
        assert_eq!(f.column(0).i64_values(), &[2, 4]);
    }

    #[test]
    fn take_parallel_matches_serial() {
        let n = 20_000usize;
        let t = Table::from_columns(vec![
            ("id", Column::from_i64((0..n as i64).collect())),
            (
                "v",
                Column::from_f64((0..n).map(|i| i as f64 * 0.5).collect()),
            ),
            (
                "opt",
                Column::from_opt_i64(
                    (0..n)
                        .map(|i| if i % 3 == 0 { None } else { Some(i as i64) })
                        .collect(),
                ),
            ),
            (
                "s",
                Column::from_str(
                    &(0..n).map(|i| format!("r{i}")).collect::<Vec<_>>(),
                ),
            ),
        ])
        .unwrap();
        let indices: Vec<usize> = (0..n).rev().filter(|i| i % 2 == 0).collect();
        let serial = t.take(&indices);
        let par = take_parallel(&t, &indices, ExecContext::new(4));
        assert_eq!(par, serial);
    }

    #[test]
    fn scatter_partitions_and_drops_masked() {
        let pids = vec![0, 1, 0, -1, 2];
        let parts = scatter_indices(&pids, 3);
        assert_eq!(parts[0], vec![0, 2]);
        assert_eq!(parts[1], vec![1]);
        assert_eq!(parts[2], vec![4]);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 4);
    }
}
