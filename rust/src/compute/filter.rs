//! Selection vectors: turn a predicate into row indices and gather.
//! Select/project and the partition scatter all funnel through here.
//! Gathers over every column layout — dense fixed-width, nullable
//! (validity bitmaps), and variable-width strings — fan out across the
//! calling thread's morsel budget, bit-identical to the serial gather.

use std::sync::Arc;

use crate::buffer::Bitmap;
use crate::column::{Column, PrimitiveColumn, StringColumn};
use crate::error::Result;
use crate::exec::{self, ExecContext, SendPtr};
use crate::table::Table;
use crate::types::Value;

/// Indices of rows where `pred` is true.
pub fn filter_indices<F: FnMut(usize) -> bool>(nrows: usize, mut pred: F) -> Vec<usize> {
    let mut out = Vec::new();
    for i in 0..nrows {
        if pred(i) {
            out.push(i);
        }
    }
    out
}

/// Gather rows of `table` by `indices`.
pub fn take_indices(table: &Table, indices: &[usize]) -> Table {
    table.take(indices)
}

/// Morsel-parallel `Table::take`: every column layout gathers into
/// disjoint output ranges concurrently. Output equals
/// `table.take(indices)` bit for bit.
pub fn take_parallel(
    table: &Table,
    indices: &[usize],
    exec: ExecContext,
) -> Table {
    if !exec::morsel_parallel(exec)
        || indices.len() < exec::par_row_threshold()
    {
        return table.take(indices);
    }
    let columns: Vec<Arc<Column>> = table
        .columns()
        .map(|c| Arc::new(take_column_parallel(c, indices, exec)))
        .collect();
    Table::from_parts(table.schema().clone(), columns, indices.len())
}

/// Morsel-parallel gather of one column (see [`take_parallel`]). On a
/// parallel budget no layout falls back to serial above the row
/// threshold: fixed-width values gather into disjoint output ranges,
/// validity bitmaps gather word-aligned ranges, and string payloads
/// land via byte-length prefix sums. All passes split
/// [`exec::split_width`]-wide, so a serial-budget steal-linked rank
/// queues claimable ranges (including the bitmap pass) instead of
/// running one serial slab.
pub fn take_column_parallel(
    col: &Column,
    indices: &[usize],
    exec: ExecContext,
) -> Column {
    if !exec::morsel_parallel(exec)
        || indices.len() < exec::par_row_threshold()
    {
        return col.take(indices);
    }
    match col {
        Column::Int64(c) => {
            Column::Int64(take_primitive_parallel(c, indices, exec))
        }
        Column::Float64(c) => {
            Column::Float64(take_primitive_parallel(c, indices, exec))
        }
        Column::Bool(c) => {
            Column::Bool(take_primitive_parallel(c, indices, exec))
        }
        Column::Utf8(c) => {
            Column::Utf8(take_string_parallel(c, indices, exec))
        }
    }
}

/// Parallel fixed-width gather: values and (when present) validity.
fn take_primitive_parallel<T>(
    col: &PrimitiveColumn<T>,
    indices: &[usize],
    exec: ExecContext,
) -> PrimitiveColumn<T>
where
    T: Copy + Default + Send + Sync,
{
    PrimitiveColumn {
        values: exec::par_gather(col.values(), indices, exec),
        validity: col
            .validity()
            .map(|b| take_bitmap_parallel(b, indices, exec)),
    }
}

/// Parallel validity gather. Workers own **word-aligned** bit ranges of
/// the output, so no two workers ever touch the same `u64` — the
/// word-sharing hazard that used to force the serial fallback. Equals
/// `src.take(indices)` bit for bit (tail bits stay zero).
fn take_bitmap_parallel(
    src: &Bitmap,
    indices: &[usize],
    exec: ExecContext,
) -> Bitmap {
    let n = indices.len();
    let nwords = n.div_ceil(64);
    let width = exec::split_width(exec);
    if !exec::morsel_parallel(exec) || width <= 1 || nwords <= 1 {
        return src.take(indices);
    }
    let mut out = Bitmap::zeros(n);
    let ptr = SendPtr(out.words_mut().as_mut_ptr());
    let word_ranges = exec::split_even(nwords, width);
    exec::map_parallel_budgeted(word_ranges, |wr| {
        for w in wr.range() {
            let lo = w * 64;
            let hi = (lo + 64).min(n);
            let mut word = 0u64;
            for (bit, &idx) in indices[lo..hi].iter().enumerate() {
                if src.get(idx) {
                    word |= 1u64 << bit;
                }
            }
            // SAFETY: word ranges are disjoint per worker, and the
            // fan-out completes before `out` is read.
            unsafe {
                *ptr.0.add(w) = word;
            }
        }
    });
    out
}

/// Parallel string gather: a morsel-parallel byte-length pass feeds a
/// prefix sum over output offsets, after which every worker copies its
/// morsel's payload into a disjoint byte range. Offsets, bytes, and
/// validity all equal the serial `StringColumn::take`.
fn take_string_parallel(
    col: &StringColumn,
    indices: &[usize],
    exec: ExecContext,
) -> StringColumn {
    let n = indices.len();
    let src_offsets = col.offsets();
    let src_bytes = col.bytes();
    // Pass 1: per-row byte lengths, gathered morsel-parallel into the
    // offsets buffer (shifted by one)…
    let mut offsets = vec![0u64; n + 1];
    exec::fill_parallel(&mut offsets[1..], exec, |m, dst| {
        for (k, &idx) in indices[m.range()].iter().enumerate() {
            dst[k] = src_offsets[idx + 1] - src_offsets[idx];
        }
    });
    // …then a serial prefix sum turns lengths into absolute offsets
    // (O(n) adds — negligible next to the payload copy).
    for i in 1..=n {
        offsets[i] += offsets[i - 1];
    }
    // Pass 2: payload copy. Morsel m owns output bytes
    // [offsets[m.start], offsets[m.end]) — disjoint by construction.
    let mut bytes = vec![0u8; offsets[n] as usize];
    let bytes_ptr = SendPtr(bytes.as_mut_ptr());
    let offsets_ref = &offsets;
    exec::for_each_morsel(n, exec, |m| {
        let mut pos = offsets_ref[m.start] as usize;
        for &idx in &indices[m.range()] {
            let lo = src_offsets[idx] as usize;
            let hi = src_offsets[idx + 1] as usize;
            // SAFETY: source and destination never overlap (distinct
            // allocations) and each morsel's destination range is
            // disjoint from every other morsel's.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    src_bytes.as_ptr().add(lo),
                    bytes_ptr.0.add(pos),
                    hi - lo,
                );
            }
            pos += hi - lo;
        }
    });
    StringColumn {
        offsets,
        bytes,
        validity: col
            .validity()
            .map(|b| take_bitmap_parallel(b, indices, exec)),
    }
}

/// Filter a table with a row-level predicate over boxed values — the
/// *convenience* select path (binding layer, examples). The typed
/// operators in `ops::select` offer columnar predicates that never box.
pub fn filter_table<F>(table: &Table, mut pred: F) -> Result<Table>
where
    F: FnMut(&[Value]) -> bool,
{
    let mut keep = Vec::new();
    let mut row: Vec<Value>;
    for i in 0..table.num_rows() {
        row = table.row(i);
        if pred(&row) {
            keep.push(i);
        }
    }
    Ok(table.take(&keep))
}

/// Scatter rows into `nparts` index lists according to `pids` (the
/// partition step of every distributed operator). `pids[i] == -1`
/// (masked/padded lanes from the kernel path) are dropped.
pub fn scatter_indices(pids: &[i32], nparts: usize) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); nparts];
    for (i, &p) in pids.iter().enumerate() {
        if p >= 0 {
            out[p as usize].push(i);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    fn t() -> Table {
        Table::from_columns(vec![
            ("id", Column::from_i64(vec![1, 2, 3, 4])),
            ("v", Column::from_f64(vec![0.1, 0.9, 0.5, 0.7])),
        ])
        .unwrap()
    }

    #[test]
    fn filter_indices_basic() {
        assert_eq!(filter_indices(5, |i| i % 2 == 0), vec![0, 2, 4]);
        assert!(filter_indices(0, |_| true).is_empty());
    }

    #[test]
    fn filter_table_by_row() {
        let t = t();
        let f = filter_table(&t, |row| row[1].as_f64().unwrap() > 0.6).unwrap();
        assert_eq!(f.num_rows(), 2);
        assert_eq!(f.column(0).i64_values(), &[2, 4]);
    }

    #[test]
    fn take_parallel_matches_serial() {
        let n = 20_000usize;
        let t = Table::from_columns(vec![
            ("id", Column::from_i64((0..n as i64).collect())),
            (
                "v",
                Column::from_f64((0..n).map(|i| i as f64 * 0.5).collect()),
            ),
            (
                "opt",
                Column::from_opt_i64(
                    (0..n)
                        .map(|i| if i % 3 == 0 { None } else { Some(i as i64) })
                        .collect(),
                ),
            ),
            (
                "s",
                Column::from_str(
                    &(0..n).map(|i| format!("r{i}")).collect::<Vec<_>>(),
                ),
            ),
        ])
        .unwrap();
        let indices: Vec<usize> = (0..n).rev().filter(|i| i % 2 == 0).collect();
        let serial = t.take(&indices);
        let par = take_parallel(&t, &indices, ExecContext::new(4));
        assert_eq!(par, serial);
    }

    #[test]
    fn scatter_partitions_and_drops_masked() {
        let pids = vec![0, 1, 0, -1, 2];
        let parts = scatter_indices(&pids, 3);
        assert_eq!(parts[0], vec![0, 2]);
        assert_eq!(parts[1], vec![1]);
        assert_eq!(parts[2], vec![4]);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 4);
    }
}
