//! Column arithmetic — the numeric half of PyCylon's DataTable API
//! (derived columns feeding the table→tensor bridge). Element-wise
//! binary ops between numeric columns (or column ⊕ scalar) with SQL
//! null propagation (any null operand → null result).

use crate::buffer::Bitmap;
use crate::column::{Column, PrimitiveColumn};
use crate::error::{Result, RylonError};

/// Element-wise binary operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

impl BinOp {
    #[inline]
    fn apply_f64(&self, a: f64, b: f64) -> f64 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
        }
    }

    #[inline]
    fn apply_i64(&self, a: i64, b: i64) -> Option<i64> {
        match self {
            BinOp::Add => a.checked_add(b),
            BinOp::Sub => a.checked_sub(b),
            BinOp::Mul => a.checked_mul(b),
            BinOp::Div => a.checked_div(b), // None on /0 and MIN/-1
        }
    }
}

fn combined_validity(a: &Column, b: &Column) -> Option<Bitmap> {
    match (a.validity(), b.validity()) {
        (None, None) => None,
        (va, vb) => {
            let n = a.len();
            let mut bm = Bitmap::ones(n);
            for i in 0..n {
                let valid = va.map_or(true, |v| v.get(i))
                    && vb.map_or(true, |v| v.get(i));
                if !valid {
                    bm.set(i, false);
                }
            }
            Some(bm)
        }
    }
}

/// `a ⊕ b` element-wise. Int⊕Int stays Int64 (nulls on overflow or /0);
/// any float operand promotes to Float64.
pub fn binary(a: &Column, b: &Column, op: BinOp) -> Result<Column> {
    if a.len() != b.len() {
        return Err(RylonError::invalid(format!(
            "arithmetic length mismatch: {} vs {}",
            a.len(),
            b.len()
        )));
    }
    match (a, b) {
        (Column::Int64(x), Column::Int64(y)) => {
            let validity = combined_validity(a, b);
            let vals: Vec<Option<i64>> = (0..a.len())
                .map(|i| {
                    let valid =
                        validity.as_ref().map_or(true, |v| v.get(i));
                    if valid {
                        op.apply_i64(x.value(i), y.value(i))
                    } else {
                        None
                    }
                })
                .collect();
            Ok(Column::Int64(PrimitiveColumn::from_options(vals)))
        }
        _ => {
            let xf = a.cast_f64()?;
            let yf = b.cast_f64()?;
            let validity = combined_validity(a, b);
            let vals: Vec<Option<f64>> = (0..a.len())
                .map(|i| {
                    let valid =
                        validity.as_ref().map_or(true, |v| v.get(i));
                    if valid {
                        Some(op.apply_f64(xf[i], yf[i]))
                    } else {
                        None
                    }
                })
                .collect();
            Ok(Column::Float64(PrimitiveColumn::from_options(vals)))
        }
    }
}

/// `col ⊕ scalar` (f64 scalar; int columns promote).
pub fn scalar_f64(a: &Column, s: f64, op: BinOp) -> Result<Column> {
    let xf = a.cast_f64()?;
    let vals: Vec<Option<f64>> = (0..a.len())
        .map(|i| {
            if a.is_valid(i) {
                Some(op.apply_f64(xf[i], s))
            } else {
                None
            }
        })
        .collect();
    Ok(Column::Float64(PrimitiveColumn::from_options(vals)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    #[test]
    fn int_int_stays_int() {
        let a = Column::from_i64(vec![1, 2, 3]);
        let b = Column::from_i64(vec![10, 20, 30]);
        let c = binary(&a, &b, BinOp::Add).unwrap();
        assert_eq!(c.i64_values(), &[11, 22, 33]);
        let m = binary(&a, &b, BinOp::Mul).unwrap();
        assert_eq!(m.i64_values(), &[10, 40, 90]);
    }

    #[test]
    fn division_by_zero_is_null_for_ints_inf_for_floats() {
        let a = Column::from_i64(vec![6, 6]);
        let b = Column::from_i64(vec![2, 0]);
        let c = binary(&a, &b, BinOp::Div).unwrap();
        assert_eq!(c.value(0), Value::Int64(3));
        assert!(c.value(1).is_null());
        let fa = Column::from_f64(vec![1.0]);
        let fb = Column::from_f64(vec![0.0]);
        let fc = binary(&fa, &fb, BinOp::Div).unwrap();
        assert_eq!(fc.f64_values()[0], f64::INFINITY);
    }

    #[test]
    fn overflow_is_null() {
        let a = Column::from_i64(vec![i64::MAX]);
        let b = Column::from_i64(vec![1]);
        let c = binary(&a, &b, BinOp::Add).unwrap();
        assert!(c.value(0).is_null());
    }

    #[test]
    fn mixed_promotes_to_float() {
        let a = Column::from_i64(vec![1, 2]);
        let b = Column::from_f64(vec![0.5, 0.25]);
        let c = binary(&a, &b, BinOp::Sub).unwrap();
        assert_eq!(c.f64_values(), &[0.5, 1.75]);
    }

    #[test]
    fn null_propagation() {
        let a = Column::from_opt_i64(vec![Some(1), None, Some(3)]);
        let b = Column::from_opt_i64(vec![None, Some(2), Some(4)]);
        let c = binary(&a, &b, BinOp::Add).unwrap();
        assert!(c.value(0).is_null());
        assert!(c.value(1).is_null());
        assert_eq!(c.value(2), Value::Int64(7));
    }

    #[test]
    fn scalar_ops() {
        let a = Column::from_opt_i64(vec![Some(4), None]);
        let c = scalar_f64(&a, 2.0, BinOp::Div).unwrap();
        assert_eq!(c.value(0), Value::Float64(2.0));
        assert!(c.value(1).is_null());
    }

    #[test]
    fn errors() {
        let a = Column::from_i64(vec![1]);
        let b = Column::from_i64(vec![1, 2]);
        assert!(binary(&a, &b, BinOp::Add).is_err());
        let s = Column::from_str(&["x"]);
        assert!(binary(&a, &s, BinOp::Add).is_err());
        assert!(scalar_f64(&s, 1.0, BinOp::Add).is_err());
    }
}
