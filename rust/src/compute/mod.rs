//! Low-level compute kernels shared by the relational operators: hashing
//! (bit-exact sibling of the L1 Pallas kernel), sorting, selection-vector
//! filtering/gathering, and aggregation primitives.

pub mod hash;
pub mod sort;
pub mod filter;
pub mod aggregate;
pub mod arithmetic;

pub use filter::{filter_table, take_indices, take_parallel};
pub use hash::{hash_column, hash_columns, splitmix64};
pub use sort::{argsort_by_columns, argsort_i64};
