//! Crate-wide error type.
//!
//! A single flat enum rather than per-module errors: the operator surface
//! is small and callers (CLI, examples, benches) handle everything the
//! same way. `thiserror` is not available offline, so Display/Error are
//! hand-implemented.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, RylonError>;

/// Rank/op/step attribution attached to a collective abort: which rank
/// failed, which labelled operation it was running, and the rank's
/// collective-step count when the fault surfaced. Every rank of an
/// aborted job receives the *same* attribution (the fault-domain
/// contract of `net::checked` — see `docs/FAULTS.md`).
#[derive(Debug)]
pub struct AbortInfo {
    /// The rank whose failure aborted the collective.
    pub rank: usize,
    /// The labelled operation the failing rank was running (e.g.
    /// `"shuffle"`, `"ingest.summary"`, `"dist_sort"`).
    pub op: String,
    /// The failing rank's completed-collective count when the fault
    /// surfaced — the BSP superstep the abort was delivered at.
    pub step: u64,
    /// The failing rank's underlying error.
    pub source: Box<RylonError>,
}

/// All error conditions surfaced by the rylon public API.
#[derive(Debug)]
pub enum RylonError {
    /// Schema mismatch between tables or against an operator requirement.
    Schema(String),
    /// A named column does not exist in the table.
    ColumnNotFound(String),
    /// Type error: operator applied to an unsupported [`crate::types::DataType`].
    Type(String),
    /// Malformed input data (CSV parse errors, ragged rows, bad literals).
    Parse(String),
    /// Invalid argument to an API call (bad parallelism, empty key list…).
    Invalid(String),
    /// Communication-layer failure (rank exited, channel closed, timeout).
    Comm(String),
    /// PJRT / XLA runtime failure (artifact missing, compile/execute error).
    Runtime(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// A collective aborted: one rank's failure, delivered symmetrically
    /// to every rank with rank/op/step attribution.
    Aborted(AbortInfo),
}

impl fmt::Display for RylonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RylonError::Schema(m) => write!(f, "schema error: {m}"),
            RylonError::ColumnNotFound(c) => write!(f, "column not found: {c}"),
            RylonError::Type(m) => write!(f, "type error: {m}"),
            RylonError::Parse(m) => write!(f, "parse error: {m}"),
            RylonError::Invalid(m) => write!(f, "invalid argument: {m}"),
            RylonError::Comm(m) => write!(f, "communication error: {m}"),
            RylonError::Runtime(m) => write!(f, "runtime error: {m}"),
            RylonError::Io(e) => write!(f, "io error: {e}"),
            RylonError::Aborted(i) => write!(
                f,
                "collective aborted: rank {} failed in {} at step {}: {}",
                i.rank, i.op, i.step, i.source
            ),
        }
    }
}

impl std::error::Error for RylonError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RylonError::Io(e) => Some(e),
            RylonError::Aborted(i) => Some(i.source.as_ref()),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RylonError {
    fn from(e: std::io::Error) -> Self {
        RylonError::Io(e)
    }
}

/// Helpers for constructing the common variants tersely.
impl RylonError {
    pub fn schema(msg: impl Into<String>) -> Self {
        RylonError::Schema(msg.into())
    }
    pub fn ty(msg: impl Into<String>) -> Self {
        RylonError::Type(msg.into())
    }
    pub fn parse(msg: impl Into<String>) -> Self {
        RylonError::Parse(msg.into())
    }
    pub fn invalid(msg: impl Into<String>) -> Self {
        RylonError::Invalid(msg.into())
    }
    pub fn comm(msg: impl Into<String>) -> Self {
        RylonError::Comm(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        RylonError::Runtime(msg.into())
    }
    pub fn aborted(
        rank: usize,
        op: impl Into<String>,
        step: u64,
        source: RylonError,
    ) -> Self {
        RylonError::Aborted(AbortInfo {
            rank,
            op: op.into(),
            step,
            source: Box::new(source),
        })
    }

    /// The abort attribution, if this error is a collective abort.
    pub fn abort_info(&self) -> Option<&AbortInfo> {
        match self {
            RylonError::Aborted(i) => Some(i),
            _ => None,
        }
    }

    /// Flatten to a `(tag, message)` pair for the fault-verdict wire
    /// format (`docs/FAULTS.md`). Lossy for `Io`/`Aborted` (message
    /// only); the fault frame carries rank/op/step separately.
    pub fn to_wire(&self) -> (u8, String) {
        match self {
            RylonError::Schema(m) => (0, m.clone()),
            RylonError::ColumnNotFound(m) => (1, m.clone()),
            RylonError::Type(m) => (2, m.clone()),
            RylonError::Parse(m) => (3, m.clone()),
            RylonError::Invalid(m) => (4, m.clone()),
            RylonError::Comm(m) => (5, m.clone()),
            RylonError::Runtime(m) => (6, m.clone()),
            RylonError::Io(e) => (7, e.to_string()),
            RylonError::Aborted(i) => (8, i.to_string()),
        }
    }

    /// Inverse of [`RylonError::to_wire`]; unknown tags decode as `Comm`.
    pub fn from_wire(tag: u8, msg: String) -> RylonError {
        match tag {
            0 => RylonError::Schema(msg),
            1 => RylonError::ColumnNotFound(msg),
            2 => RylonError::Type(msg),
            3 => RylonError::Parse(msg),
            4 => RylonError::Invalid(msg),
            5 => RylonError::Comm(msg),
            6 => RylonError::Runtime(msg),
            7 => RylonError::Io(std::io::Error::other(msg)),
            _ => RylonError::Comm(msg),
        }
    }
}

impl fmt::Display for AbortInfo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rank {} failed in {} at step {}: {}",
            self.rank, self.op, self.step, self.source
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert_eq!(
            RylonError::ColumnNotFound("id".into()).to_string(),
            "column not found: id"
        );
        assert!(RylonError::schema("width").to_string().contains("width"));
        assert!(RylonError::comm("closed").to_string().contains("closed"));
    }

    #[test]
    fn io_source_preserved() {
        let e = RylonError::from(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
