//! Crate-wide error type.
//!
//! A single flat enum rather than per-module errors: the operator surface
//! is small and callers (CLI, examples, benches) handle everything the
//! same way. `thiserror` is not available offline, so Display/Error are
//! hand-implemented.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, RylonError>;

/// All error conditions surfaced by the rylon public API.
#[derive(Debug)]
pub enum RylonError {
    /// Schema mismatch between tables or against an operator requirement.
    Schema(String),
    /// A named column does not exist in the table.
    ColumnNotFound(String),
    /// Type error: operator applied to an unsupported [`crate::types::DataType`].
    Type(String),
    /// Malformed input data (CSV parse errors, ragged rows, bad literals).
    Parse(String),
    /// Invalid argument to an API call (bad parallelism, empty key list…).
    Invalid(String),
    /// Communication-layer failure (rank exited, channel closed, timeout).
    Comm(String),
    /// PJRT / XLA runtime failure (artifact missing, compile/execute error).
    Runtime(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for RylonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RylonError::Schema(m) => write!(f, "schema error: {m}"),
            RylonError::ColumnNotFound(c) => write!(f, "column not found: {c}"),
            RylonError::Type(m) => write!(f, "type error: {m}"),
            RylonError::Parse(m) => write!(f, "parse error: {m}"),
            RylonError::Invalid(m) => write!(f, "invalid argument: {m}"),
            RylonError::Comm(m) => write!(f, "communication error: {m}"),
            RylonError::Runtime(m) => write!(f, "runtime error: {m}"),
            RylonError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for RylonError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RylonError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RylonError {
    fn from(e: std::io::Error) -> Self {
        RylonError::Io(e)
    }
}

/// Helpers for constructing the common variants tersely.
impl RylonError {
    pub fn schema(msg: impl Into<String>) -> Self {
        RylonError::Schema(msg.into())
    }
    pub fn ty(msg: impl Into<String>) -> Self {
        RylonError::Type(msg.into())
    }
    pub fn parse(msg: impl Into<String>) -> Self {
        RylonError::Parse(msg.into())
    }
    pub fn invalid(msg: impl Into<String>) -> Self {
        RylonError::Invalid(msg.into())
    }
    pub fn comm(msg: impl Into<String>) -> Self {
        RylonError::Comm(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        RylonError::Runtime(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert_eq!(
            RylonError::ColumnNotFound("id".into()).to_string(),
            "column not found: id"
        );
        assert!(RylonError::schema("width").to_string().contains("width"));
        assert!(RylonError::comm("closed").to_string().contains("closed"));
    }

    #[test]
    fn io_source_preserved() {
        let e = RylonError::from(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
