//! The morsel scheduler: split a row range into cache-sized chunks,
//! fan them out over the calling thread's persistent worker pool
//! ([`super::pool`]) pulling from a shared atomic cursor, and
//! reassemble results in morsel order so parallel output is
//! bit-identical to serial output.

use std::ops::Range;

use super::{pool, ExecContext};

/// Rows per morsel: small enough that a morsel's working set stays
/// cache-resident, large enough to amortise scheduling.
pub const MORSEL_ROWS: usize = 1 << 16;

/// One contiguous row range, numbered in input order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Morsel {
    /// Position of this morsel in the split (results merge in this
    /// order, which is what makes parallel output bit-identical).
    pub index: usize,
    /// First row of the range (inclusive).
    pub start: usize,
    /// One past the last row of the range (exclusive).
    pub end: usize,
}

impl Morsel {
    /// The row range as a standard `Range`.
    pub fn range(&self) -> Range<usize> {
        self.start..self.end
    }

    /// Number of rows in the morsel.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// Whether the morsel covers no rows.
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Split `[0, nrows)` into cache-sized morsels — at least `threads`
/// pieces when the input allows, so every worker has work.
pub fn split_morsels(nrows: usize, threads: usize) -> Vec<Morsel> {
    if nrows == 0 {
        return Vec::new();
    }
    let t = threads.max(1);
    let step = MORSEL_ROWS.min(nrows.div_ceil(t)).max(1);
    let mut out = Vec::with_capacity(nrows.div_ceil(step));
    let mut start = 0;
    let mut index = 0;
    while start < nrows {
        let end = (start + step).min(nrows);
        out.push(Morsel { index, start, end });
        start = end;
        index += 1;
    }
    out
}

/// Split `[0, nrows)` into exactly `parts` near-equal ranges (empty
/// ranges dropped) — used by run-sort, where fewer, larger runs mean
/// fewer merge levels.
pub fn split_even(nrows: usize, parts: usize) -> Vec<Morsel> {
    let p = parts.max(1);
    let mut out = Vec::with_capacity(p);
    let base = nrows / p;
    let extra = nrows % p;
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        if len == 0 {
            continue;
        }
        out.push(Morsel {
            index: out.len(),
            start,
            end: start + len,
        });
        start += len;
    }
    out
}

/// Raw pointer wrapper for disjoint writes from scoped workers. Every
/// use site must guarantee non-overlapping write ranges (that contract
/// is what justifies the Send/Sync claims).
pub(crate) struct SendPtr<T>(pub *mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}

impl<T> Copy for SendPtr<T> {}

/// Morsel-driven fan-out: up to `exec.threads()` of the rank's own
/// pooled workers pull morsels off a shared cursor (plus any sibling
/// ranks' workers stealing into a linked pool — see
/// `crate::exec::pool`); results come back in morsel order
/// (deterministic merge), so who runs a morsel never changes output.
pub fn for_each_morsel<R, F>(nrows: usize, exec: ExecContext, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(Morsel) -> R + Sync,
{
    let morsels = split_morsels(nrows, exec.threads());
    let n = morsels.len();
    if !super::morsel_parallel(exec) || n <= 1 {
        return morsels.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let slot_ptr = SendPtr(slots.as_mut_ptr());
    let morsels = &morsels;
    let f = &f;
    pool::run_current(n, exec.threads(), &move |i| {
        let r = f(morsels[i]);
        // SAFETY: the pool hands each index to exactly one task, so
        // slot i is written once, and the pool's completion barrier
        // sequences the writes before the reads below.
        unsafe {
            *slot_ptr.0.add(i) = Some(r);
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("morsel result missing"))
        .collect()
}

/// Concurrency cap for the item-count-driven entry points
/// ([`map_parallel`], [`run_partitions`]): the larger of the calling
/// thread's budget and the machine's cores (read once — these entry
/// points now run per streamed ingest chunk, so the procfs lookup
/// behind `available_parallelism` must stay off the hot path). Honours
/// explicit budgets while keeping a huge item count from growing the
/// (persistent, never-shrinking) pool past the hardware.
fn local_concurrency_cap() -> usize {
    static CORES: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    let cores = *CORES.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    });
    cores.max(super::current().threads())
}

/// Run owned work items concurrently on the pool (up to
/// [`local_concurrency_cap`] at once), preserving item order in the
/// results. Callers keep the item count near the thread budget
/// (per-run sorts, per-range scans); wide batches meant to overfill
/// the local budget for stealing siblings go through
/// [`map_parallel_budgeted`] instead, or the cap would grow the
/// persistent local pool to machine width.
pub fn map_parallel<I, R, F>(items: Vec<I>, f: F) -> Vec<R>
where
    I: Send,
    R: Send,
    F: Fn(I) -> R + Sync,
{
    let cap = local_concurrency_cap();
    map_parallel_with_cap(items, cap, f)
}

/// Like [`map_parallel`], but capped at the calling thread's intra-op
/// budget instead of [`local_concurrency_cap`]: for batches that are
/// deliberately wider than the budget (sort merge levels cut into
/// merge-path chunks), where the surplus tasks exist so *stealing
/// sibling* workers can help — never so the local pool outgrows the
/// rank's budget (the no-oversubscription invariant).
pub(crate) fn map_parallel_budgeted<I, R, F>(items: Vec<I>, f: F) -> Vec<R>
where
    I: Send,
    R: Send,
    F: Fn(I) -> R + Sync,
{
    let cap = super::current().threads();
    map_parallel_with_cap(items, cap, f)
}

fn map_parallel_with_cap<I, R, F>(items: Vec<I>, cap: usize, f: F) -> Vec<R>
where
    I: Send,
    R: Send,
    F: Fn(I) -> R + Sync,
{
    let n = items.len();
    if n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let mut input: Vec<Option<I>> = items.into_iter().map(Some).collect();
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let in_ptr = SendPtr(input.as_mut_ptr());
    let slot_ptr = SendPtr(slots.as_mut_ptr());
    let f = &f;
    pool::run_current(n, n.min(cap), &move |i| {
        // SAFETY: each index is claimed by exactly one task (pool
        // cursor), so item i is taken once and slot i written once; the
        // pool's completion barrier sequences these against the caller.
        let item = unsafe { (*in_ptr.0.add(i)).take().expect("item taken twice") };
        let r = f(item);
        unsafe {
            *slot_ptr.0.add(i) = Some(r);
        }
    });
    drop(input);
    slots
        .into_iter()
        .map(|r| r.expect("map_parallel result missing"))
        .collect()
}

/// One task per partition id `0..nparts`, up to
/// [`local_concurrency_cap`] running at once — the radix-partitioned
/// builders (hash chains, grouping) where each worker owns a disjoint
/// slice of the hash space.
pub fn run_partitions<R, F>(nparts: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if nparts <= 1 {
        return (0..nparts).map(f).collect();
    }
    let mut slots: Vec<Option<R>> = Vec::with_capacity(nparts);
    slots.resize_with(nparts, || None);
    let slot_ptr = SendPtr(slots.as_mut_ptr());
    let f = &f;
    pool::run_current(nparts, nparts.min(local_concurrency_cap()), &move |p| {
        let r = f(p);
        // SAFETY: one task per partition id; writes are disjoint and
        // sequenced before the reads by the pool's completion barrier.
        unsafe {
            *slot_ptr.0.add(p) = Some(r);
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("partition result missing"))
        .collect()
}

/// Fill `out` by handing each worker the disjoint sub-slice for its
/// morsel. `f(morsel, slice)` writes `slice[k]` for row `morsel.start+k`.
pub fn fill_parallel<T, F>(out: &mut [T], exec: ExecContext, f: F)
where
    T: Send,
    F: Fn(Morsel, &mut [T]) + Sync,
{
    let n = out.len();
    if !super::morsel_parallel(exec) || n == 0 {
        for m in split_morsels(n, 1) {
            let range = m.range();
            f(m, &mut out[range]);
        }
        return;
    }
    let morsels = split_morsels(n, exec.threads());
    let ptr = SendPtr(out.as_mut_ptr());
    let morsels = &morsels;
    let f = &f;
    pool::run_current(morsels.len(), exec.threads(), &move |i| {
        let m = morsels[i];
        // SAFETY: morsels are disjoint subranges of `out`, and `out` is
        // not otherwise touched until the pool's completion barrier.
        let slice = unsafe {
            std::slice::from_raw_parts_mut(ptr.0.add(m.start), m.len())
        };
        f(m, slice);
    });
}

/// Parallel gather: `out[i] = src[indices[i]]`, chunked across workers.
/// Bit-identical to the serial gather.
pub fn par_gather<T>(src: &[T], indices: &[usize], exec: ExecContext) -> Vec<T>
where
    T: Copy + Default + Send + Sync,
{
    if !super::morsel_parallel(exec)
        || indices.len() < super::par_row_threshold()
    {
        return indices.iter().map(|&i| src[i]).collect();
    }
    let mut out = vec![T::default(); indices.len()];
    fill_parallel(&mut out, exec, |m, dst| {
        for (k, &idx) in indices[m.range()].iter().enumerate() {
            dst[k] = src[idx];
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn morsels_cover_range_exactly() {
        for (nrows, threads) in [(0, 4), (1, 4), (100, 3), (1 << 20, 4)] {
            let ms = split_morsels(nrows, threads);
            let total: usize = ms.iter().map(|m| m.len()).sum();
            assert_eq!(total, nrows);
            for w in ms.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                assert_eq!(w[0].index + 1, w[1].index);
            }
            if nrows > 0 {
                assert_eq!(ms[0].start, 0);
                assert_eq!(ms.last().unwrap().end, nrows);
            }
        }
    }

    #[test]
    fn even_split_balances() {
        let ms = split_even(10, 4);
        let sizes: Vec<usize> = ms.iter().map(|m| m.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        assert!(split_even(0, 4).is_empty());
        assert_eq!(split_even(2, 4).len(), 2);
    }

    #[test]
    fn for_each_morsel_orders_results() {
        let exec = ExecContext::new(4);
        let sums = for_each_morsel(1 << 18, exec, |m| {
            m.range().map(|i| i as u64).sum::<u64>()
        });
        let serial = for_each_morsel(1 << 18, ExecContext::serial(), |m| {
            m.range().map(|i| i as u64).sum::<u64>()
        });
        assert_eq!(sums, serial);
        let n = (1u64 << 18) - 1;
        assert_eq!(sums.iter().sum::<u64>(), n * (n + 1) / 2);
    }

    #[test]
    fn map_parallel_preserves_order() {
        let out = map_parallel(vec![3, 1, 4, 1, 5], |x| x * 2);
        assert_eq!(out, vec![6, 2, 8, 2, 10]);
    }

    #[test]
    fn run_partitions_indexes() {
        assert_eq!(run_partitions(4, |p| p * 10), vec![0, 10, 20, 30]);
        assert!(run_partitions(0, |p| p).is_empty());
    }

    #[test]
    fn fill_and_gather_match_serial() {
        let n = 100_000usize;
        let src: Vec<u64> = (0..n as u64).map(|v| v.wrapping_mul(31)).collect();
        let indices: Vec<usize> = (0..n).rev().collect();
        let par = par_gather(&src, &indices, ExecContext::new(4));
        let ser: Vec<u64> = indices.iter().map(|&i| src[i]).collect();
        assert_eq!(par, ser);

        let mut out = vec![0u64; n];
        fill_parallel(&mut out, ExecContext::new(3), |m, dst| {
            for (k, d) in dst.iter_mut().enumerate() {
                *d = (m.start + k) as u64;
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64));
    }
}
