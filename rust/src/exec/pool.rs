//! The persistent per-rank worker pool behind every scoped parallel
//! API in [`crate::exec`].
//!
//! PR 1's scoped pool spawned fresh `std::thread::scope` workers on
//! every operator call — fine at 64Ki-row morsels, measurable on tiny
//! ops and antithetical to the long-lived executor of "Supercharging
//! Distributed Computing Environments For High Performance Data
//! Engineering" (Perera et al. 2023). This module keeps one
//! [`WorkerPool`] alive per rank thread (installed by
//! `dist::Cluster::run`) or lazily per calling thread for local use.
//! Workers are spawned on first demand, **parked between operators**,
//! and woken by job submission, so back-to-back operators reuse the
//! same OS threads.
//!
//! Contract with the scoped callers:
//!
//! * A job is `ntasks` indexed closures `task(0..ntasks)` pulled off a
//!   shared atomic cursor by at most `concurrency` of the pool's
//!   **own** workers. The caller blocks until every task finished, so
//!   `task` may borrow stack data (the `'static` transmute below is
//!   justified by that barrier).
//! * Workers run tasks under a **serial** intra-op budget
//!   ([`crate::exec::set_intra_op_threads`]`(1)`), so nested kernels
//!   never multiply — identical to the scoped pool's invariant.
//! * A panicking task poisons nothing: the panic payload is captured,
//!   remaining tasks still drain, and the payload is re-raised on the
//!   **calling** thread once the job completes (`dist::Cluster` then
//!   maps that rank panic to an error). The worker survives for the
//!   next job.
//!
//! # Cross-rank work stealing
//!
//! Pools owned by one `dist::Cluster` can be **steal-linked**
//! ([`link_steal_group`], wired at pool installation when the `[exec]
//! work_steal` knob is on): each rank keeps its local queue — local
//! workers claim from the front, preserving cache affinity and the
//! per-job `concurrency` permits — but a worker that finds its own
//! queue drained scans sibling queues **back-to-front** and claims
//! from any job with unclaimed tasks, ignoring the victim's permits
//! (idle capacity elsewhere is exactly what permits exist to leave
//! room for) and taking **one task per steal**, re-checking its own
//! queue in between, so home work is never stuck behind the remainder
//! of a sibling's job. Because a job's tasks pull from one shared cursor and write
//! to pre-indexed output slots, stealing changes *who* runs a morsel,
//! never *where* its result lands or in what order results merge —
//! parallel output stays bit-identical — and a stolen task's panic is
//! recorded on the same job latch, so it still re-raises on the
//! submitting rank's thread. When a pool is steal-linked, even a
//! `concurrency == 1` multi-task job is queued (not inlined): the
//! submitting rank contributes one worker, and sibling ranks' idle
//! workers supply the rest — execution decoupled from static rank
//! ownership (Perera et al. 2023). That lone worker is a deliberate
//! trade-off: the rank thread parks on the latch while its worker
//! runs (so the per-rank budget still holds), paying one wake/handoff
//! per job — amortised over the ≥ 2 morsels a queued job always has —
//! and when *this* rank is the unloaded one, that same parked worker
//! is exactly the idle capacity that steals a skewed sibling's
//! morsels (if the submitter ran its own tasks instead, a serial-rank
//! cluster would have no workers free to steal at all). A steal
//! signal to a pool that has never spawned a worker spawns its first
//! one, so a fully idle rank — one that never even submitted a job —
//! still contributes a thief the moment a sibling queues work.

use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};
use std::thread::JoinHandle;

/// Best-effort text of a panic payload (`&str` / `String` payloads —
/// the two `panic!` produces). Used wherever a panic joins the fault
/// domain: a rank panic becomes a [`crate::net::Fault`] whose message
/// carries the payload instead of an opaque "a rank panicked".
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// A borrowed task smuggled across threads as a raw pointer (raw so a
/// worker still holding its `Arc<Job>` after the job completed keeps
/// no dangling *reference*, only a pointer it will never dereference).
/// Safety: the submitting caller blocks in [`WorkerPool::run`] until
/// the job's last task completed, and workers only dereference while
/// tasks remain unclaimed, so every dereference sees a live borrow.
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

/// One in-flight job: an indexed task set with a claim cursor and a
/// completion latch.
struct Job {
    task: TaskRef,
    ntasks: usize,
    cursor: AtomicUsize,
    done: Mutex<JobDone>,
    done_cv: Condvar,
}

struct JobDone {
    pending: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl Job {
    /// Pull task indices off the cursor until exhausted, recording
    /// completions (and at most one panic payload) on the latch —
    /// how a pool's own workers drain their local jobs.
    fn work(&self, stolen: Option<&AtomicU64>) {
        while self.work_one(stolen) {}
    }

    /// Claim and run at most one task (`false` = the job was already
    /// exhausted). The steal path runs jobs one task at a time so a
    /// thief re-checks its *own* queue between stolen morsels — a
    /// local job never waits behind the remainder of a sibling's job.
    /// `stolen` is the stealing pool's task counter when this worker
    /// joined the job from a sibling queue.
    fn work_one(&self, stolen: Option<&AtomicU64>) -> bool {
        {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= self.ntasks {
                return false;
            }
            if let Some(counter) = stolen {
                counter.fetch_add(1, Ordering::Relaxed);
            }
            // Re-pin the serial worker state before every task: a
            // previous task may have panicked out of a `with_*` scope
            // without restoring the thread-locals, and workers survive
            // panics, so a one-shot pin at thread start is not enough.
            super::set_intra_op_threads(1);
            super::set_par_row_threshold(super::PAR_ROW_THRESHOLD);
            super::set_ingest_chunk_bytes(super::default_ingest_chunk_bytes());
            // SAFETY: tasks are only claimed while the submitting
            // caller blocks in `WorkerPool::run`, so the pointee is a
            // live borrow for the duration of this call.
            let task = unsafe { &*self.task.0 };
            let result = catch_unwind(AssertUnwindSafe(|| task(i)));
            let mut d = self.done.lock().expect("job latch poisoned");
            d.pending -= 1;
            if let Err(payload) = result {
                d.panic.get_or_insert(payload);
            }
            if d.pending == 0 {
                self.done_cv.notify_all();
            }
        }
        true
    }

    fn exhausted(&self) -> bool {
        self.cursor.load(Ordering::Relaxed) >= self.ntasks
    }
}

/// A job queued on the pool plus how many more workers may join it.
struct QueuedJob {
    job: Arc<Job>,
    permits: usize,
}

struct PoolState {
    queue: Vec<QueuedJob>,
    handles: Vec<JoinHandle<()>>,
    /// Total worker threads ever spawned — the thread-generation
    /// counter: unchanged between two operators ⇔ threads were reused.
    spawned: usize,
    shutting_down: bool,
    /// Bumped (under this pool's lock) whenever a sibling pool queues a
    /// job. A worker records the value before scanning victims and
    /// parks only if it is unchanged afterwards, so a submission that
    /// races with the scan can never be slept through.
    steal_signal: u64,
}

struct PoolInner {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    /// Sibling pools this pool's idle workers may steal from — set once
    /// at cluster pool installation ([`link_steal_group`]). Weak, so
    /// mutually linked pools still drop.
    peers: OnceLock<Vec<Weak<PoolInner>>>,
    /// Rotating index into `peers` so victim scans don't always rob the
    /// same sibling first.
    next_victim: AtomicUsize,
    /// Tasks this pool's workers claimed from sibling queues.
    stolen_tasks: AtomicU64,
}

impl PoolInner {
    /// Linked steal peers (empty when the pool is isolated).
    fn peers(&self) -> &[Weak<PoolInner>] {
        self.peers.get().map(Vec::as_slice).unwrap_or(&[])
    }
}

/// A persistent worker pool. Workers spawn lazily up to the largest
/// concurrency any job asked for, park on a condvar between jobs, and
/// exit on [`WorkerPool::shutdown`] (also called on drop).
pub struct WorkerPool {
    inner: Arc<PoolInner>,
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkerPool {
    pub fn new() -> WorkerPool {
        WorkerPool {
            inner: Arc::new(PoolInner {
                state: Mutex::new(PoolState {
                    queue: Vec::new(),
                    handles: Vec::new(),
                    spawned: 0,
                    shutting_down: false,
                    steal_signal: 0,
                }),
                work_cv: Condvar::new(),
                peers: OnceLock::new(),
                next_victim: AtomicUsize::new(0),
                stolen_tasks: AtomicU64::new(0),
            }),
        }
    }

    /// Whether this pool is steal-linked to sibling pools.
    pub fn stealable(&self) -> bool {
        !self.inner.peers().is_empty()
    }

    /// Tasks this pool's workers claimed from sibling pools' queues.
    pub fn stolen_tasks(&self) -> u64 {
        self.inner.stolen_tasks.load(Ordering::Relaxed)
    }

    /// Run `task(0) … task(ntasks-1)` on up to `concurrency` of this
    /// pool's workers; returns when all tasks completed. Serial
    /// (inline) when the job cannot use a second thread — except on a
    /// steal-linked pool, where a multi-task job is queued even at
    /// `concurrency == 1` so idle sibling workers can claim the
    /// surplus. Re-raises the first task panic on the calling thread.
    pub fn run(&self, ntasks: usize, concurrency: usize, task: &(dyn Fn(usize) + Sync)) {
        if ntasks == 0 {
            return;
        }
        if ntasks == 1 || (concurrency <= 1 && !self.stealable()) {
            for i in 0..ntasks {
                task(i);
            }
            return;
        }
        let workers = concurrency.min(ntasks).max(1);
        // The borrow's lifetime is erased on the way into the raw
        // pointer (nothing keeps the transmuted reference); see
        // `TaskRef` for why every dereference stays in-lifetime.
        let task_ptr: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(task)
        };
        let job = Arc::new(Job {
            task: TaskRef(task_ptr),
            ntasks,
            cursor: AtomicUsize::new(0),
            done: Mutex::new(JobDone {
                pending: ntasks,
                panic: None,
            }),
            done_cv: Condvar::new(),
        });
        {
            let mut st = self.inner.state.lock().expect("pool state poisoned");
            if st.shutting_down {
                // A shut-down pool degrades to inline execution rather
                // than stranding the job (only reachable when a caller
                // outlives its Cluster — out of contract but safe).
                drop(st);
                for i in 0..ntasks {
                    task(i);
                }
                return;
            }
            while st.spawned < workers {
                st.spawned += 1;
                let inner = Arc::clone(&self.inner);
                let handle = std::thread::spawn(move || worker_loop(inner));
                st.handles.push(handle);
            }
            st.queue.push(QueuedJob {
                job: Arc::clone(&job),
                permits: workers,
            });
        }
        self.inner.work_cv.notify_all();
        // Wake idle sibling workers so they can steal — but only when
        // the job has surplus tasks beyond its own (parked-between-
        // operators, hence available) local workers: a job local
        // workers swallow whole has nothing worth a cross-rank wake,
        // and skipping the broadcast keeps balanced clusters free of
        // per-operator peer-lock chatter. (A worker that is itself off
        // stealing re-checks its local queue after every stolen task,
        // so even then a small unsignalled job is picked up within one
        // morsel.)
        // The signal bump happens under the *sibling's* lock (see
        // `steal_signal`), and only one state lock is ever held at a
        // time, so two pools submitting into each other cannot
        // deadlock.
        if ntasks > workers {
            for peer in self.inner.peers() {
                let Some(peer) = peer.upgrade() else { continue };
                {
                    let mut pst =
                        peer.state.lock().expect("pool state poisoned");
                    pst.steal_signal = pst.steal_signal.wrapping_add(1);
                    // A pool that never ran a job has no worker to wake
                    // — a fully idle rank would contribute no thief in
                    // exactly the skewed case stealing targets. Spawn
                    // its first worker now: this is precisely the
                    // moment there is work to steal, and a parked
                    // worker costs nothing afterwards.
                    if pst.spawned == 0 && !pst.shutting_down {
                        pst.spawned = 1;
                        let inner = Arc::clone(&peer);
                        pst.handles.push(std::thread::spawn(move || {
                            worker_loop(inner)
                        }));
                    }
                }
                peer.work_cv.notify_all();
            }
        }

        // Block until the last task completed, then unqueue and surface
        // any panic on this (the submitting) thread.
        let payload = {
            let mut d = job.done.lock().expect("job latch poisoned");
            while d.pending > 0 {
                d = job.done_cv.wait(d).expect("job latch poisoned");
            }
            d.panic.take()
        };
        {
            let mut st = self.inner.state.lock().expect("pool state poisoned");
            st.queue.retain(|qj| !Arc::ptr_eq(&qj.job, &job));
        }
        if let Some(payload) = payload {
            resume_unwind(payload);
        }
    }

    /// Total worker threads ever spawned by this pool (the
    /// thread-generation counter — stable across back-to-back
    /// operators when threads are being reused).
    pub fn spawned_threads(&self) -> usize {
        self.inner.state.lock().expect("pool state poisoned").spawned
    }

    /// Signal workers to exit once the queue drains and join them.
    /// Idempotent; also invoked on drop.
    pub fn shutdown(&self) {
        let handles = {
            let mut st = self.inner.state.lock().expect("pool state poisoned");
            st.shutting_down = true;
            std::mem::take(&mut st.handles)
        };
        self.inner.work_cv.notify_all();
        for h in handles {
            // A worker that panicked outside a task already surfaced
            // the failure via the job latch; ignore its join result.
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Park on the work condvar; claim a permit on any queued local job
/// with unclaimed tasks (front first — cache affinity); otherwise scan
/// sibling queues and steal; drain what was claimed; repeat. Exit once
/// shutdown is signalled and no claimable local work remains
/// (in-flight jobs always drain first).
fn worker_loop(inner: Arc<PoolInner>) {
    // Nested kernels on a worker stay serial — the oversubscription
    // invariant of the execution model (overrides any env default).
    super::set_intra_op_threads(1);
    loop {
        // One pass under the local lock: claim local work, or exit, or
        // fall out to the (lock-free-of-self) steal scan with the
        // current signal recorded so a racing submission is never
        // slept through.
        enum Next {
            Local(Arc<Job>),
            Scan(u64),
            Exit,
        }
        let next = {
            let mut st = inner.state.lock().expect("pool state poisoned");
            if let Some(qj) = st
                .queue
                .iter_mut()
                .find(|qj| qj.permits > 0 && !qj.job.exhausted())
            {
                qj.permits -= 1;
                Next::Local(Arc::clone(&qj.job))
            } else if st.shutting_down {
                Next::Exit
            } else {
                Next::Scan(st.steal_signal)
            }
        };
        match next {
            Next::Exit => return,
            Next::Local(job) => job.work(None),
            Next::Scan(seen) => {
                if let Some(job) = steal_victim_job(&inner) {
                    // One task per steal: loop back afterwards, where
                    // the local queue is checked first, so home work
                    // never waits behind the rest of a sibling's job.
                    job.work_one(Some(&inner.stolen_tasks));
                    continue;
                }
                // Nothing local, nothing to steal: park — unless a
                // local submission, a sibling signal, or shutdown
                // arrived while the scan ran without the local lock.
                let st = inner.state.lock().expect("pool state poisoned");
                let local_work = st
                    .queue
                    .iter()
                    .any(|qj| qj.permits > 0 && !qj.job.exhausted());
                if !local_work
                    && !st.shutting_down
                    && st.steal_signal == seen
                {
                    // Re-checked from the top of the loop on wake.
                    drop(inner.work_cv.wait(st).expect("pool state poisoned"));
                }
            }
        }
    }
}

/// Scan sibling pools (rotating start, each queue back-to-front) for a
/// job with unclaimed tasks. Only one pool's state lock is held at a
/// time. Returns the first stealable job, if any.
fn steal_victim_job(inner: &PoolInner) -> Option<Arc<Job>> {
    let peers = inner.peers();
    if peers.is_empty() {
        return None;
    }
    let start = inner.next_victim.fetch_add(1, Ordering::Relaxed);
    for k in 0..peers.len() {
        let Some(peer) = peers[(start + k) % peers.len()].upgrade() else {
            continue;
        };
        let st = peer.state.lock().expect("pool state poisoned");
        // Back-to-front: the most recently queued job is the one the
        // victim's own workers reach last.
        if let Some(qj) = st.queue.iter().rev().find(|qj| !qj.job.exhausted())
        {
            return Some(Arc::clone(&qj.job));
        }
    }
    None
}

/// Steal-link every pool in `pools` to all the others (each gets Weak
/// handles to its siblings). Called once per cluster, at pool
/// installation, when the `[exec] work_steal` knob is on; a second
/// call on the same pool is a no-op (the handle set is write-once).
pub(crate) fn link_steal_group(pools: &[Arc<WorkerPool>]) {
    for (i, pool) in pools.iter().enumerate() {
        let peers: Vec<Weak<PoolInner>> = pools
            .iter()
            .enumerate()
            .filter(|&(j, _)| j != i)
            .map(|(_, p)| Arc::downgrade(&p.inner))
            .collect();
        let _ = pool.inner.peers.set(peers);
    }
}

thread_local! {
    /// The calling thread's executor. Rank threads get theirs installed
    /// by `dist::Cluster::run` (one pool per rank, owned by the
    /// `Cluster`); other threads lazily create a private pool on first
    /// parallel submission, shut down when the thread exits.
    static THREAD_POOL: RefCell<Option<Arc<WorkerPool>>> = const { RefCell::new(None) };
}

/// Install `pool` as the calling thread's executor (used by
/// `dist::Cluster::run` so all ranks share the cluster's long-lived
/// pools). Replaces any previously installed pool for this thread.
pub fn install_thread_pool(pool: Arc<WorkerPool>) {
    THREAD_POOL.with(|p| *p.borrow_mut() = Some(pool));
}

/// Submit a job to the calling thread's executor, creating a private
/// persistent pool on first use. A serial-concurrency multi-task job
/// still goes through a steal-linked pool (sibling workers may claim
/// the surplus); on an isolated executor it runs inline, exactly the
/// original single-threaded behaviour.
pub(crate) fn run_current(
    ntasks: usize,
    concurrency: usize,
    task: &(dyn Fn(usize) + Sync),
) {
    if ntasks == 0 {
        return;
    }
    if ntasks == 1 || (concurrency <= 1 && !current_pool_stealable()) {
        for i in 0..ntasks {
            task(i);
        }
        return;
    }
    let pool = THREAD_POOL.with(|p| {
        let mut slot = p.borrow_mut();
        Arc::clone(slot.get_or_insert_with(|| Arc::new(WorkerPool::new())))
    });
    pool.run(ntasks, concurrency, task);
}

/// Whether the calling thread's installed executor is steal-linked to
/// sibling rank pools (false for lazily created private pools and for
/// threads with no pool yet).
pub(crate) fn current_pool_stealable() -> bool {
    THREAD_POOL.with(|p| {
        p.borrow().as_ref().map(|pool| pool.stealable()).unwrap_or(false)
    })
}

/// Number of pools in the calling thread's steal group, counting its
/// own (`1` for isolated or absent pools). Kernels that size their
/// split widths use this as the group's worker *capacity*: each linked
/// sibling pool can contribute at least one thief, so a
/// `intra_op_threads = 1` rank still splits wide enough for idle
/// siblings to claim a share instead of watching one worker run the
/// whole range ([`crate::exec::split_width`]).
pub(crate) fn current_pool_steal_group() -> usize {
    THREAD_POOL.with(|p| {
        p.borrow()
            .as_ref()
            .map(|pool| pool.inner.peers().len() + 1)
            .unwrap_or(1)
    })
}

/// Thread-generation counter of the calling thread's executor (see
/// [`WorkerPool::spawned_threads`]).
pub fn current_pool_spawned_threads() -> usize {
    THREAD_POOL.with(|p| {
        p.borrow()
            .as_ref()
            .map(|pool| pool.spawned_threads())
            .unwrap_or(0)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_all_tasks_once() {
        let pool = WorkerPool::new();
        let hits: Vec<AtomicUsize> =
            (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(100, 4, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(pool.spawned_threads(), 4);
        pool.shutdown();
    }

    #[test]
    fn reuses_workers_across_jobs() {
        // The pool-respawn fix: two back-to-back parallel operators
        // must run on the same worker threads (generation unchanged).
        let pool = WorkerPool::new();
        pool.run(16, 3, &|_| {});
        let gen_after_first = pool.spawned_threads();
        pool.run(16, 3, &|_| {});
        pool.run(16, 2, &|_| {});
        assert_eq!(pool.spawned_threads(), gen_after_first);
        assert_eq!(gen_after_first, 3);
        // A wider job grows the pool, narrower jobs never shrink it.
        pool.run(16, 5, &|_| {});
        assert_eq!(pool.spawned_threads(), 5);
    }

    #[test]
    fn serial_jobs_stay_inline() {
        let pool = WorkerPool::new();
        pool.run(8, 1, &|_| {});
        pool.run(1, 8, &|_| {});
        pool.run(0, 8, &|_| {});
        assert_eq!(pool.spawned_threads(), 0);
    }

    #[test]
    fn task_panic_resurfaces_on_caller_and_pool_survives() {
        let pool = WorkerPool::new();
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, 2, &|i| {
                if i == 3 {
                    panic!("task 3 exploded");
                }
            });
        }));
        assert!(r.is_err());
        // The pool is still serviceable after a task panic.
        let count = AtomicUsize::new(0);
        pool.run(8, 2, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn workers_run_serial_budget() {
        let pool = WorkerPool::new();
        let budgets: Vec<AtomicUsize> =
            (0..4).map(|_| AtomicUsize::new(0)).collect();
        crate::exec::with_intra_op_threads(8, || {
            pool.run(4, 4, &|i| {
                budgets[i]
                    .store(crate::exec::current().threads(), Ordering::Relaxed);
            });
        });
        assert!(budgets.iter().all(|b| b.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn steal_linked_pools_run_sibling_tasks() {
        use std::sync::atomic::AtomicBool;
        let a = Arc::new(WorkerPool::new());
        let b = Arc::new(WorkerPool::new());
        link_steal_group(&[Arc::clone(&a), Arc::clone(&b)]);
        assert!(a.stealable() && b.stealable());

        // Job 1 on A: 4 blocking tasks, local concurrency 2. The steal
        // signal spawns B's first worker (B never ran a job), so
        // exactly 3 workers exist to claim the 4 tasks — the gate
        // below proves A's 2 workers *and* B's thief are all pinned
        // inside job 1, i.e. at least one task was stolen.
        let started = AtomicUsize::new(0);
        let release = AtomicBool::new(false);
        std::thread::scope(|s| {
            let a1 = Arc::clone(&a);
            let (started, release) = (&started, &release);
            let t1 = s.spawn(move || {
                a1.run(4, 2, &|_| {
                    started.fetch_add(1, Ordering::SeqCst);
                    while !release.load(Ordering::SeqCst) {
                        std::thread::yield_now();
                    }
                });
            });
            while started.load(Ordering::SeqCst) < 3 {
                std::thread::yield_now();
            }
            assert_eq!(a.spawned_threads(), 2);
            assert_eq!(
                b.spawned_threads(),
                1,
                "the steal signal spawns an idle pool's first worker"
            );
            assert!(
                b.stolen_tasks() >= 1,
                "B's thief must have claimed part of job 1"
            );
            release.store(true, Ordering::SeqCst);
            t1.join().unwrap();
        });

        // A panicking task re-raises on the *submitting* thread
        // whichever pool's worker ran it.
        let r = catch_unwind(AssertUnwindSafe(|| {
            a.run(3, 2, &|i| {
                if i == 1 {
                    panic!("task exploded");
                }
            });
        }));
        assert!(r.is_err(), "panic must surface on submitter");

        // Both pools stay serviceable afterwards, and results/latches
        // behave identically however tasks were distributed.
        let count = AtomicUsize::new(0);
        a.run(8, 2, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 8);
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn steal_linked_pool_queues_serial_concurrency_jobs() {
        // On an isolated pool a concurrency-1 job runs inline; on a
        // steal-linked pool it is queued so sibling workers can join.
        // Wherever each task lands, results and counts are identical,
        // and the pool's own side spawns exactly one local worker.
        let a = Arc::new(WorkerPool::new());
        let b = Arc::new(WorkerPool::new());
        link_steal_group(&[Arc::clone(&a), Arc::clone(&b)]);
        let count = AtomicUsize::new(0);
        a.run(4, 1, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 4);
        assert_eq!(
            a.spawned_threads(),
            1,
            "a queued concurrency-1 job runs on one local worker"
        );
        assert!(
            b.spawned_threads() <= 1,
            "the steal signal spawns at most one thief"
        );
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn steal_signal_stress_no_lost_wakeups_under_job_storms() {
        // The no-lost-wakeup gate on the steal-signal protocol — and
        // the stress harness the ROADMAP's waiter-count follow-on
        // wants in hand before optimising the wake path: four
        // steal-linked pools take a storm of short jobs from four
        // concurrent submitters, with pseudo-random task sleeps
        // jittering every park/scan/submit interleaving. A submission
        // slept through (the race `steal_signal` closes) strands its
        // submitter on the job latch forever; the watchdog converts
        // that hang into a bounded failure. Every task must run
        // exactly once no matter which pool's worker claimed it.
        use std::time::{Duration, Instant};

        const POOLS: usize = 4;
        const JOBS_PER_POOL: usize = 250;
        const DEADLINE: Duration = Duration::from_secs(120);

        let pools: Vec<Arc<WorkerPool>> =
            (0..POOLS).map(|_| Arc::new(WorkerPool::new())).collect();
        link_steal_group(&pools);

        let ran = Arc::new(AtomicUsize::new(0));
        let handles: Vec<JoinHandle<()>> = pools
            .iter()
            .enumerate()
            .map(|(p, pool)| {
                let pool = Arc::clone(pool);
                let ran = Arc::clone(&ran);
                std::thread::spawn(move || {
                    // Splitmix-style per-submitter stream: determines
                    // job widths, permits, and sleep jitter.
                    let mut state = 0x9E37_79B9_7F4A_7C15u64 ^ ((p as u64) << 32);
                    let mut next = move || {
                        state = state
                            .wrapping_mul(6364136223846793005)
                            .wrapping_add(1442695040888963407);
                        state >> 33
                    };
                    for _ in 0..JOBS_PER_POOL {
                        let ntasks = 2 + (next() % 9) as usize;
                        let conc = 1 + (next() % 3) as usize;
                        let sleep_ns = next() % 80_000;
                        let hits: Vec<AtomicUsize> =
                            (0..ntasks).map(|_| AtomicUsize::new(0)).collect();
                        pool.run(ntasks, conc, &|i| {
                            if sleep_ns > 0 {
                                std::thread::sleep(Duration::from_nanos(
                                    sleep_ns,
                                ));
                            }
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        });
                        assert!(
                            hits.iter()
                                .all(|h| h.load(Ordering::Relaxed) == 1),
                            "a task ran zero times or twice"
                        );
                        ran.fetch_add(ntasks, Ordering::Relaxed);
                    }
                })
            })
            .collect();

        let start = Instant::now();
        while handles.iter().any(|h| !h.is_finished()) {
            assert!(
                start.elapsed() < DEADLINE,
                "lost wakeup: a submitter is still parked after {:?} \
                 ({} tasks ran)",
                DEADLINE,
                ran.load(Ordering::Relaxed)
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        for h in handles {
            h.join().expect("a submitter panicked");
        }

        // Every pool is still serviceable after the storm, and
        // shutdown joins every worker cleanly.
        for pool in &pools {
            let count = AtomicUsize::new(0);
            pool.run(16, 2, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), 16);
            pool.shutdown();
        }
    }

    #[test]
    fn shutdown_is_idempotent_and_drops_clean() {
        let pool = WorkerPool::new();
        pool.run(4, 2, &|_| {});
        pool.shutdown();
        pool.shutdown();
        // Post-shutdown jobs degrade to inline execution.
        let count = AtomicUsize::new(0);
        pool.run(4, 2, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 4);
        assert_eq!(pool.spawned_threads(), 2);
    }
}
