//! The persistent per-rank worker pool behind every scoped parallel
//! API in [`crate::exec`].
//!
//! PR 1's scoped pool spawned fresh `std::thread::scope` workers on
//! every operator call — fine at 64Ki-row morsels, measurable on tiny
//! ops and antithetical to the long-lived executor of "Supercharging
//! Distributed Computing Environments For High Performance Data
//! Engineering" (Perera et al. 2023). This module keeps one
//! [`WorkerPool`] alive per rank thread (installed by
//! `dist::Cluster::run`) or lazily per calling thread for local use.
//! Workers are spawned on first demand, **parked between operators**,
//! and woken by job submission, so back-to-back operators reuse the
//! same OS threads.
//!
//! Contract with the scoped callers:
//!
//! * A job is `ntasks` indexed closures `task(0..ntasks)` pulled off a
//!   shared atomic cursor by at most `concurrency` workers. The caller
//!   blocks until every task finished, so `task` may borrow stack data
//!   (the `'static` transmute below is justified by that barrier).
//! * Workers run tasks under a **serial** intra-op budget
//!   ([`crate::exec::set_intra_op_threads`]`(1)`), so nested kernels
//!   never multiply — identical to the scoped pool's invariant.
//! * A panicking task poisons nothing: the panic payload is captured,
//!   remaining tasks still drain, and the payload is re-raised on the
//!   **calling** thread once the job completes (`dist::Cluster` then
//!   maps that rank panic to an error). The worker survives for the
//!   next job.

use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A borrowed task smuggled across threads as a raw pointer (raw so a
/// worker still holding its `Arc<Job>` after the job completed keeps
/// no dangling *reference*, only a pointer it will never dereference).
/// Safety: the submitting caller blocks in [`WorkerPool::run`] until
/// the job's last task completed, and workers only dereference while
/// tasks remain unclaimed, so every dereference sees a live borrow.
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

/// One in-flight job: an indexed task set with a claim cursor and a
/// completion latch.
struct Job {
    task: TaskRef,
    ntasks: usize,
    cursor: AtomicUsize,
    done: Mutex<JobDone>,
    done_cv: Condvar,
}

struct JobDone {
    pending: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl Job {
    /// Pull task indices off the cursor until exhausted, recording
    /// completions (and at most one panic payload) on the latch.
    fn work(&self) {
        loop {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= self.ntasks {
                return;
            }
            // Re-pin the serial worker state before every task: a
            // previous task may have panicked out of a `with_*` scope
            // without restoring the thread-locals, and workers survive
            // panics, so a one-shot pin at thread start is not enough.
            super::set_intra_op_threads(1);
            super::set_par_row_threshold(super::PAR_ROW_THRESHOLD);
            super::set_ingest_chunk_bytes(super::default_ingest_chunk_bytes());
            // SAFETY: tasks are only claimed while the submitting
            // caller blocks in `WorkerPool::run`, so the pointee is a
            // live borrow for the duration of this call.
            let task = unsafe { &*self.task.0 };
            let result = catch_unwind(AssertUnwindSafe(|| task(i)));
            let mut d = self.done.lock().expect("job latch poisoned");
            d.pending -= 1;
            if let Err(payload) = result {
                d.panic.get_or_insert(payload);
            }
            if d.pending == 0 {
                self.done_cv.notify_all();
            }
        }
    }

    fn exhausted(&self) -> bool {
        self.cursor.load(Ordering::Relaxed) >= self.ntasks
    }
}

/// A job queued on the pool plus how many more workers may join it.
struct QueuedJob {
    job: Arc<Job>,
    permits: usize,
}

struct PoolState {
    queue: Vec<QueuedJob>,
    handles: Vec<JoinHandle<()>>,
    /// Total worker threads ever spawned — the thread-generation
    /// counter: unchanged between two operators ⇔ threads were reused.
    spawned: usize,
    shutting_down: bool,
}

struct PoolInner {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

/// A persistent worker pool. Workers spawn lazily up to the largest
/// concurrency any job asked for, park on a condvar between jobs, and
/// exit on [`WorkerPool::shutdown`] (also called on drop).
pub struct WorkerPool {
    inner: Arc<PoolInner>,
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkerPool {
    pub fn new() -> WorkerPool {
        WorkerPool {
            inner: Arc::new(PoolInner {
                state: Mutex::new(PoolState {
                    queue: Vec::new(),
                    handles: Vec::new(),
                    spawned: 0,
                    shutting_down: false,
                }),
                work_cv: Condvar::new(),
            }),
        }
    }

    /// Run `task(0) … task(ntasks-1)` on up to `concurrency` pooled
    /// workers; returns when all tasks completed. Serial (inline) when
    /// the job cannot use a second thread. Re-raises the first task
    /// panic on the calling thread.
    pub fn run(&self, ntasks: usize, concurrency: usize, task: &(dyn Fn(usize) + Sync)) {
        if ntasks == 0 {
            return;
        }
        if ntasks == 1 || concurrency <= 1 {
            for i in 0..ntasks {
                task(i);
            }
            return;
        }
        let workers = concurrency.min(ntasks);
        // The borrow's lifetime is erased on the way into the raw
        // pointer (nothing keeps the transmuted reference); see
        // `TaskRef` for why every dereference stays in-lifetime.
        let task_ptr: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(task)
        };
        let job = Arc::new(Job {
            task: TaskRef(task_ptr),
            ntasks,
            cursor: AtomicUsize::new(0),
            done: Mutex::new(JobDone {
                pending: ntasks,
                panic: None,
            }),
            done_cv: Condvar::new(),
        });
        {
            let mut st = self.inner.state.lock().expect("pool state poisoned");
            if st.shutting_down {
                // A shut-down pool degrades to inline execution rather
                // than stranding the job (only reachable when a caller
                // outlives its Cluster — out of contract but safe).
                drop(st);
                for i in 0..ntasks {
                    task(i);
                }
                return;
            }
            while st.spawned < workers {
                st.spawned += 1;
                let inner = Arc::clone(&self.inner);
                let handle = std::thread::spawn(move || worker_loop(inner));
                st.handles.push(handle);
            }
            st.queue.push(QueuedJob {
                job: Arc::clone(&job),
                permits: workers,
            });
        }
        self.inner.work_cv.notify_all();

        // Block until the last task completed, then unqueue and surface
        // any panic on this (the submitting) thread.
        let payload = {
            let mut d = job.done.lock().expect("job latch poisoned");
            while d.pending > 0 {
                d = job.done_cv.wait(d).expect("job latch poisoned");
            }
            d.panic.take()
        };
        {
            let mut st = self.inner.state.lock().expect("pool state poisoned");
            st.queue.retain(|qj| !Arc::ptr_eq(&qj.job, &job));
        }
        if let Some(payload) = payload {
            resume_unwind(payload);
        }
    }

    /// Total worker threads ever spawned by this pool (the
    /// thread-generation counter — stable across back-to-back
    /// operators when threads are being reused).
    pub fn spawned_threads(&self) -> usize {
        self.inner.state.lock().expect("pool state poisoned").spawned
    }

    /// Signal workers to exit once the queue drains and join them.
    /// Idempotent; also invoked on drop.
    pub fn shutdown(&self) {
        let handles = {
            let mut st = self.inner.state.lock().expect("pool state poisoned");
            st.shutting_down = true;
            std::mem::take(&mut st.handles)
        };
        self.inner.work_cv.notify_all();
        for h in handles {
            // A worker that panicked outside a task already surfaced
            // the failure via the job latch; ignore its join result.
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Park on the work condvar; claim a permit on any queued job with
/// unclaimed tasks; drain it; repeat. Exit once shutdown is signalled
/// and no claimable work remains (in-flight jobs always drain first).
fn worker_loop(inner: Arc<PoolInner>) {
    // Nested kernels on a worker stay serial — the oversubscription
    // invariant of the execution model (overrides any env default).
    super::set_intra_op_threads(1);
    loop {
        let job = {
            let mut st = inner.state.lock().expect("pool state poisoned");
            loop {
                if let Some(qj) = st
                    .queue
                    .iter_mut()
                    .find(|qj| qj.permits > 0 && !qj.job.exhausted())
                {
                    qj.permits -= 1;
                    break Arc::clone(&qj.job);
                }
                if st.shutting_down {
                    return;
                }
                st = inner.work_cv.wait(st).expect("pool state poisoned");
            }
        };
        job.work();
    }
}

thread_local! {
    /// The calling thread's executor. Rank threads get theirs installed
    /// by `dist::Cluster::run` (one pool per rank, owned by the
    /// `Cluster`); other threads lazily create a private pool on first
    /// parallel submission, shut down when the thread exits.
    static THREAD_POOL: RefCell<Option<Arc<WorkerPool>>> = const { RefCell::new(None) };
}

/// Install `pool` as the calling thread's executor (used by
/// `dist::Cluster::run` so all ranks share the cluster's long-lived
/// pools). Replaces any previously installed pool for this thread.
pub fn install_thread_pool(pool: Arc<WorkerPool>) {
    THREAD_POOL.with(|p| *p.borrow_mut() = Some(pool));
}

/// Submit a job to the calling thread's executor, creating a private
/// persistent pool on first use.
pub(crate) fn run_current(
    ntasks: usize,
    concurrency: usize,
    task: &(dyn Fn(usize) + Sync),
) {
    if ntasks == 0 {
        return;
    }
    if ntasks == 1 || concurrency <= 1 {
        for i in 0..ntasks {
            task(i);
        }
        return;
    }
    let pool = THREAD_POOL.with(|p| {
        let mut slot = p.borrow_mut();
        Arc::clone(slot.get_or_insert_with(|| Arc::new(WorkerPool::new())))
    });
    pool.run(ntasks, concurrency, task);
}

/// Thread-generation counter of the calling thread's executor (see
/// [`WorkerPool::spawned_threads`]).
pub fn current_pool_spawned_threads() -> usize {
    THREAD_POOL.with(|p| {
        p.borrow()
            .as_ref()
            .map(|pool| pool.spawned_threads())
            .unwrap_or(0)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_all_tasks_once() {
        let pool = WorkerPool::new();
        let hits: Vec<AtomicUsize> =
            (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(100, 4, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(pool.spawned_threads(), 4);
        pool.shutdown();
    }

    #[test]
    fn reuses_workers_across_jobs() {
        // The pool-respawn fix: two back-to-back parallel operators
        // must run on the same worker threads (generation unchanged).
        let pool = WorkerPool::new();
        pool.run(16, 3, &|_| {});
        let gen_after_first = pool.spawned_threads();
        pool.run(16, 3, &|_| {});
        pool.run(16, 2, &|_| {});
        assert_eq!(pool.spawned_threads(), gen_after_first);
        assert_eq!(gen_after_first, 3);
        // A wider job grows the pool, narrower jobs never shrink it.
        pool.run(16, 5, &|_| {});
        assert_eq!(pool.spawned_threads(), 5);
    }

    #[test]
    fn serial_jobs_stay_inline() {
        let pool = WorkerPool::new();
        pool.run(8, 1, &|_| {});
        pool.run(1, 8, &|_| {});
        pool.run(0, 8, &|_| {});
        assert_eq!(pool.spawned_threads(), 0);
    }

    #[test]
    fn task_panic_resurfaces_on_caller_and_pool_survives() {
        let pool = WorkerPool::new();
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, 2, &|i| {
                if i == 3 {
                    panic!("task 3 exploded");
                }
            });
        }));
        assert!(r.is_err());
        // The pool is still serviceable after a task panic.
        let count = AtomicUsize::new(0);
        pool.run(8, 2, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn workers_run_serial_budget() {
        let pool = WorkerPool::new();
        let budgets: Vec<AtomicUsize> =
            (0..4).map(|_| AtomicUsize::new(0)).collect();
        crate::exec::with_intra_op_threads(8, || {
            pool.run(4, 4, &|i| {
                budgets[i]
                    .store(crate::exec::current().threads(), Ordering::Relaxed);
            });
        });
        assert!(budgets.iter().all(|b| b.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn shutdown_is_idempotent_and_drops_clean() {
        let pool = WorkerPool::new();
        pool.run(4, 2, &|_| {});
        pool.shutdown();
        pool.shutdown();
        // Post-shutdown jobs degrade to inline execution.
        let count = AtomicUsize::new(0);
        pool.run(4, 2, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 4);
        assert_eq!(pool.spawned_threads(), 2);
    }
}
