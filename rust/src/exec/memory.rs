//! Per-rank memory governor: a tracked reservation facade plus the
//! spill-file plumbing operators fall back to when a reservation fails.
//!
//! The budget is the `[exec] memory_budget_bytes` knob (`0` =
//! unbounded — exactly today's in-memory behaviour). Operators ask the
//! governor for their estimated working set *before* building it
//! ([`MemoryBudget::try_reserve`]); a successful reservation is an RAII
//! [`Reservation`] released on drop, a failed one routes the operator
//! onto its out-of-core path (grace hash join, external merge sort,
//! partitioned spilling groupby — `docs/MEMORY.md`). Spill files live
//! in a per-episode [`SpillDir`] whose `Drop` removes the whole
//! directory, so cleanup happens on success *and* when an abort
//! unwinds through the operator (the PR 6 fault domain: a rank that
//! faults mid-spill must not leak temp files).
//!
//! Accounting is thread-local because the budget is *per rank*: rank
//! threads get their resolved budget from `dist::Cluster::run`, local
//! CLI commands and tests set it on the calling thread, and every
//! reservation/spill an operator makes happens on that same thread
//! (morsel workers never reserve — checks happen at operator entry).

use std::cell::Cell;
use std::marker::PhantomData;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::error::Result;

/// Default for the `[exec] memory_budget_bytes` knob: `0` = unbounded
/// (no reservation ever fails, so every operator keeps its in-memory
/// path — the oracle the spill paths are bit-identical to). A non-zero
/// value is the per-rank working-set ceiling in bytes; operators whose
/// estimated working set does not fit degrade to their spill-to-disk
/// paths (`docs/MEMORY.md`). Override per thread with
/// [`set_memory_budget_bytes`] / [`with_memory_budget_bytes`], per
/// cluster with `DistConfig::with_memory_budget`, on the CLI with
/// `--memory-budget`, in config via `[exec] memory_budget_bytes`, or
/// process-wide with the `MEMORY_BUDGET_BYTES` env var (the CI spill
/// leg).
pub const MEMORY_BUDGET_BYTES: usize = 0;

/// The process-wide default memory budget: the `MEMORY_BUDGET_BYTES`
/// env var (bytes; the CI spill leg sets a small value so every join,
/// sort, and groupby in the suite runs its out-of-core path), else
/// [`MEMORY_BUDGET_BYTES`] (0 = unbounded). Read once; explicit
/// setters and `DistConfig` always override it.
pub fn default_memory_budget_bytes() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("MEMORY_BUDGET_BYTES")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(MEMORY_BUDGET_BYTES)
    })
}

thread_local! {
    /// Per-thread memory budget in bytes (see [`MEMORY_BUDGET_BYTES`]).
    /// Rank threads get theirs from `dist::Cluster::run`.
    static BUDGET: Cell<usize> = Cell::new(default_memory_budget_bytes());

    /// Bytes currently reserved against the budget on this thread.
    static RESERVED: Cell<usize> = const { Cell::new(0) };

    /// High-water mark of [`RESERVED`] — what the governor ever let
    /// operators hold at once (the property tests pin this to the
    /// budget).
    static RESERVED_PEAK: Cell<usize> = const { Cell::new(0) };

    /// Bytes this thread has written to spill files.
    static SPILL_BYTES: Cell<u64> = const { Cell::new(0) };

    /// Spill partitions/runs this thread has written.
    static SPILL_PARTS: Cell<u64> = const { Cell::new(0) };
}

/// The calling thread's memory budget in bytes (`0` = unbounded).
pub fn memory_budget_bytes() -> usize {
    BUDGET.with(|c| c.get())
}

/// Set the calling thread's memory budget (`0` = unbounded — no clamp;
/// unlike the other byte knobs, zero is a meaningful value here).
pub fn set_memory_budget_bytes(bytes: usize) {
    BUDGET.with(|c| c.set(bytes));
}

/// Run `f` under a temporary memory budget, restoring the previous
/// budget afterwards — how the equivalence matrix forces spill paths
/// on small inputs.
pub fn with_memory_budget_bytes<T>(bytes: usize, f: impl FnOnce() -> T) -> T {
    let prev = BUDGET.with(|c| c.replace(bytes));
    let out = f();
    BUDGET.with(|c| c.set(prev));
    out
}

/// Resolve a configured memory budget: `0` = the process default
/// (env-overridable via `MEMORY_BUDGET_BYTES`), anything else passes
/// through. An explicit `0` and a default `0` mean the same thing —
/// unbounded — so the sentinel overload is harmless.
pub fn resolve_memory_budget_bytes(configured: usize) -> usize {
    if configured > 0 {
        configured
    } else {
        default_memory_budget_bytes()
    }
}

/// Bytes currently reserved against the calling thread's budget.
pub fn reserved_bytes() -> usize {
    RESERVED.with(|c| c.get())
}

/// High-water mark of reserved bytes on the calling thread since the
/// last [`reset_reserved_peak`] — the governor's own accounting never
/// exceeds the budget, and the property tests assert it.
pub fn reserved_peak() -> usize {
    RESERVED_PEAK.with(|c| c.get())
}

/// Reset the calling thread's reserved-bytes high-water mark.
pub fn reset_reserved_peak() {
    RESERVED_PEAK.with(|c| c.set(RESERVED.with(|r| r.get())));
}

/// Bytes the calling thread has written to spill files (cumulative).
pub fn spill_bytes() -> u64 {
    SPILL_BYTES.with(|c| c.get())
}

/// Spill partitions/runs the calling thread has written (cumulative).
pub fn spill_partitions() -> u64 {
    SPILL_PARTS.with(|c| c.get())
}

/// Book one spilled partition/run of `bytes` bytes on the calling
/// thread — called by the spill writers in `ops` / `compute::sort`.
pub(crate) fn note_spill(bytes: u64) {
    SPILL_BYTES.with(|c| c.set(c.get() + bytes));
    SPILL_PARTS.with(|c| c.set(c.get() + 1));
}

/// Drain the calling thread's spill counters: returns
/// `(bytes, partitions)` and resets both to zero. `dist::Cluster::run`
/// uses this to fold rank-thread spill activity into cluster totals.
pub(crate) fn take_spill_stats() -> (u64, u64) {
    let bytes = SPILL_BYTES.with(|c| c.replace(0));
    let parts = SPILL_PARTS.with(|c| c.replace(0));
    (bytes, parts)
}

/// The per-rank memory governor: a snapshot of the calling thread's
/// budget that operators reserve estimated working sets against.
///
/// `try_reserve` either books the bytes (returning an RAII
/// [`Reservation`]) or fails, telling the operator to take its spill
/// path. The governor is an *admission* facade, not an allocator hook:
/// operators declare their big structures before building them, and
/// one morsel's slack of small transient allocations is outside the
/// accounting by design (`docs/MEMORY.md`).
#[derive(Debug, Clone, Copy)]
pub struct MemoryBudget {
    limit: usize,
}

impl MemoryBudget {
    /// The governor for the calling thread's current budget.
    pub fn current() -> MemoryBudget {
        MemoryBudget {
            limit: memory_budget_bytes(),
        }
    }

    /// A governor with an explicit limit (`0` = unbounded) —
    /// reservations still account on the calling thread.
    pub fn with_limit(limit: usize) -> MemoryBudget {
        MemoryBudget { limit }
    }

    /// The budget ceiling in bytes (`0` = unbounded).
    pub fn limit(&self) -> usize {
        self.limit
    }

    /// Whether this governor admits everything (budget `0`).
    pub fn is_unbounded(&self) -> bool {
        self.limit == 0
    }

    /// Try to reserve `bytes` against the budget. Unbounded governors
    /// always succeed (and track nothing); bounded ones succeed only
    /// if the thread's total reserved bytes stay within the limit.
    /// The returned [`Reservation`] releases the bytes on drop.
    pub fn try_reserve(&self, bytes: usize) -> Option<Reservation> {
        if self.limit == 0 {
            return Some(Reservation {
                bytes: 0,
                _not_send: PhantomData,
            });
        }
        RESERVED.with(|r| {
            let cur = r.get();
            if cur.saturating_add(bytes) > self.limit {
                return None;
            }
            r.set(cur + bytes);
            RESERVED_PEAK.with(|p| p.set(p.get().max(cur + bytes)));
            Some(Reservation {
                bytes,
                _not_send: PhantomData,
            })
        })
    }
}

/// An accepted memory reservation; dropping it releases the bytes back
/// to the calling thread's budget. `!Send` so the release always lands
/// on the thread that reserved (the accounting is thread-local).
#[derive(Debug)]
pub struct Reservation {
    bytes: usize,
    _not_send: PhantomData<*const ()>,
}

impl Reservation {
    /// The bytes this reservation holds (0 for unbounded governors).
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for Reservation {
    fn drop(&mut self) {
        if self.bytes > 0 {
            RESERVED.with(|r| {
                r.set(r.get().saturating_sub(self.bytes));
            });
        }
    }
}

/// Live (not yet dropped) spill directories across the whole process —
/// the leak detector the fault-injection tests assert on: after a run
/// completes *or aborts*, this must return to its prior value.
static LIVE_SPILL_DIRS: AtomicUsize = AtomicUsize::new(0);

/// Monotonic suffix making concurrent spill dirs in one process unique.
static SPILL_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Root directory spill dirs are created under: the `RYLON_SPILL_DIR`
/// env var if set (the tcp fault tests point rank processes at a
/// per-test directory so leaks are observable from outside), else the
/// system temp dir. Read once.
pub fn spill_root() -> &'static Path {
    static ROOT: OnceLock<PathBuf> = OnceLock::new();
    ROOT.get_or_init(|| {
        std::env::var_os("RYLON_SPILL_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(std::env::temp_dir)
    })
}

/// Number of live spill directories in this process (0 = nothing to
/// leak). Global, so tests asserting it must not race other spillers.
pub fn live_spill_dirs() -> usize {
    LIVE_SPILL_DIRS.load(Ordering::SeqCst)
}

/// One spill episode's temp directory (`rylon-spill-<pid>-<seq>` under
/// [`spill_root`]). `Drop` removes the directory and everything in it,
/// which is what makes cleanup hold on *both* exits: the operator
/// returning normally, and an abort/panic unwinding through its frame
/// (`dist::Cluster::run` catches rank panics *after* the unwind has
/// run these drops).
#[derive(Debug)]
pub struct SpillDir {
    path: PathBuf,
}

impl SpillDir {
    /// Create a fresh, empty spill directory under [`spill_root`].
    pub fn create() -> Result<SpillDir> {
        let seq = SPILL_DIR_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = spill_root().join(format!(
            "rylon-spill-{}-{}",
            std::process::id(),
            seq
        ));
        std::fs::create_dir_all(&path)?;
        LIVE_SPILL_DIRS.fetch_add(1, Ordering::SeqCst);
        Ok(SpillDir { path })
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Path for a spill file named `name` inside this directory.
    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for SpillDir {
    fn drop(&mut self) {
        // Best effort: an ENOENT here (root already swept) must not
        // turn an orderly unwind into an abort.
        let _ = std::fs::remove_dir_all(&self.path);
        LIVE_SPILL_DIRS.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_knob_scopes_and_restores() {
        let prev = memory_budget_bytes();
        with_memory_budget_bytes(4096, || {
            assert_eq!(memory_budget_bytes(), 4096);
            // Zero is meaningful (unbounded), not clamped.
            with_memory_budget_bytes(0, || {
                assert_eq!(memory_budget_bytes(), 0);
                assert!(MemoryBudget::current().is_unbounded());
            });
        });
        assert_eq!(memory_budget_bytes(), prev);
        // 0 = the process default; explicit values pass through.
        assert_eq!(
            resolve_memory_budget_bytes(0),
            default_memory_budget_bytes()
        );
        assert_eq!(resolve_memory_budget_bytes(123), 123);
    }

    #[test]
    fn reservations_account_and_release() {
        with_memory_budget_bytes(1000, || {
            let base = reserved_bytes();
            let b = MemoryBudget::current();
            assert!(!b.is_unbounded());
            let r1 = b.try_reserve(600).expect("fits");
            assert_eq!(reserved_bytes(), base + 600);
            // Over budget → denied, accounting unchanged.
            assert!(b.try_reserve(600).is_none());
            let r2 = b.try_reserve(400).expect("exactly fits");
            assert_eq!(reserved_bytes(), base + 1000);
            assert!(b.try_reserve(1).is_none());
            drop(r1);
            assert_eq!(reserved_bytes(), base + 400);
            drop(r2);
            assert_eq!(reserved_bytes(), base);
            // The high-water mark saw the full occupancy.
            assert!(reserved_peak() >= base + 1000);
        });
    }

    #[test]
    fn unbounded_budget_admits_everything_untracked() {
        with_memory_budget_bytes(0, || {
            let base = reserved_bytes();
            let b = MemoryBudget::current();
            let r = b.try_reserve(usize::MAX).expect("unbounded");
            assert_eq!(r.bytes(), 0);
            assert_eq!(reserved_bytes(), base);
        });
    }

    #[test]
    fn spill_dir_created_and_removed_on_drop() {
        let d = SpillDir::create().unwrap();
        let path = d.path().to_path_buf();
        assert!(path.is_dir());
        std::fs::write(d.file("part0.ryf"), b"x").unwrap();
        drop(d);
        assert!(!path.exists(), "spill dir must vanish on drop");
    }

    #[test]
    fn spill_dir_removed_when_a_panic_unwinds_through_it() {
        let path = std::cell::RefCell::new(PathBuf::new());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                let d = SpillDir::create().unwrap();
                *path.borrow_mut() = d.path().to_path_buf();
                std::fs::write(d.file("run0.ryf"), b"x").unwrap();
                panic!("mid-spill fault");
            },
        ));
        assert!(r.is_err());
        let p = path.borrow();
        assert!(p.file_name().is_some());
        assert!(!p.exists(), "unwind must drop the spill dir");
    }

    #[test]
    fn spill_counters_accumulate_and_drain() {
        let (b0, p0) = (spill_bytes(), spill_partitions());
        note_spill(100);
        note_spill(28);
        assert_eq!(spill_bytes(), b0 + 128);
        assert_eq!(spill_partitions(), p0 + 2);
        let (b, p) = take_spill_stats();
        assert_eq!((b, p), (b0 + 128, p0 + 2));
        assert_eq!(spill_bytes(), 0);
        assert_eq!(spill_partitions(), 0);
    }
}
