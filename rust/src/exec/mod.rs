//! Intra-rank morsel-driven parallel execution.
//!
//! The paper's execution model gives each MPI rank exactly one thread
//! (§III-B), so a rank uses one core no matter the machine. This module
//! adds the second level of the hybrid model (cf. "Supercharging
//! Distributed Computing Environments For High Performance Data
//! Engineering", Perera et al. 2023): inside one rank, local compute
//! kernels split their row ranges into cache-sized **morsels** and fan
//! them out over a scoped worker pool (std threads, no dependencies).
//!
//! Two invariants every parallel kernel in this crate upholds:
//!
//! 1. **Bit-identical results.** Morsel results are merged in morsel
//!    order, hash structures are radix-partitioned so each worker owns
//!    disjoint buckets and inserts rows in the serial order, and sorts
//!    use stable run-sort + stable merge. A parallel kernel at any
//!    thread count produces exactly the serial kernel's output —
//!    including splitmix64 bucket placement, SQL null semantics, and
//!    f64 accumulation order.
//! 2. **No oversubscription.** The thread budget is per rank thread
//!    (thread-local), so `world × intra_op_threads` is bounded by the
//!    machine: `dist::Cluster` resolves the `intra_op_threads = 0`
//!    (auto) knob to `available cores / world`, and worker threads
//!    themselves default to a serial budget, so nested kernels never
//!    multiply.
//!
//! The knob is `DistConfig::intra_op_threads` for cluster runs, or
//! [`set_intra_op_threads`] / [`with_intra_op_threads`] for local use;
//! `1` reproduces the original single-threaded behaviour exactly.

mod morsel;

use std::cell::Cell;

pub use self::morsel::{
    fill_parallel, for_each_morsel, map_parallel, par_gather,
    run_partitions, split_even, split_morsels, Morsel, MORSEL_ROWS,
};
pub(crate) use self::morsel::SendPtr;

/// Kernels fall back to the serial path below this many rows — morsel
/// startup is not worth it for tiny inputs.
pub const PAR_ROW_THRESHOLD: usize = 4096;

/// Immutable per-operation thread budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecContext {
    threads: usize,
}

impl ExecContext {
    /// Budget of `threads` morsel workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> ExecContext {
        ExecContext {
            threads: threads.max(1),
        }
    }

    /// The original single-threaded behaviour.
    pub fn serial() -> ExecContext {
        ExecContext { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn is_parallel(&self) -> bool {
        self.threads > 1
    }
}

thread_local! {
    /// Per-thread intra-op budget. Rank threads get theirs from
    /// `dist::Cluster::run`; everything else defaults to serial.
    static CURRENT_THREADS: Cell<usize> = Cell::new(1);
}

/// The calling thread's current intra-op budget.
pub fn current() -> ExecContext {
    ExecContext::new(CURRENT_THREADS.with(|c| c.get()))
}

/// Set the calling thread's intra-op budget (`1` = serial).
pub fn set_intra_op_threads(threads: usize) {
    CURRENT_THREADS.with(|c| c.set(threads.max(1)));
}

/// Run `f` under a temporary intra-op budget, restoring the previous
/// budget afterwards.
pub fn with_intra_op_threads<T>(threads: usize, f: impl FnOnce() -> T) -> T {
    let prev = CURRENT_THREADS.with(|c| c.replace(threads.max(1)));
    let out = f();
    CURRENT_THREADS.with(|c| c.set(prev));
    out
}

/// The effective budget for an `nrows`-row kernel: the thread-local
/// budget, degraded to serial below [`PAR_ROW_THRESHOLD`].
pub fn parallelism_for(nrows: usize) -> ExecContext {
    if nrows < PAR_ROW_THRESHOLD {
        ExecContext::serial()
    } else {
        current()
    }
}

/// Resolve a configured knob value: `0` = auto (available cores divided
/// evenly over `world` rank threads, so the fabric's rank threads and
/// the morsel workers together never oversubscribe the machine).
pub fn resolve_intra_op_threads(configured: usize, world: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    (cores / world.max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_budget_is_serial() {
        assert_eq!(current().threads(), 1);
        assert!(!current().is_parallel());
    }

    #[test]
    fn scoped_budget_restores() {
        let inner = with_intra_op_threads(4, || current().threads());
        assert_eq!(inner, 4);
        assert_eq!(current().threads(), 1);
    }

    #[test]
    fn zero_clamps_to_one() {
        set_intra_op_threads(0);
        assert_eq!(current().threads(), 1);
    }

    #[test]
    fn threshold_degrades_small_inputs() {
        with_intra_op_threads(8, || {
            assert!(!parallelism_for(10).is_parallel());
            assert!(parallelism_for(PAR_ROW_THRESHOLD).is_parallel());
        });
    }

    #[test]
    fn auto_resolution_divides_cores() {
        let one_rank = resolve_intra_op_threads(0, 1);
        assert!(one_rank >= 1);
        // Explicit values pass through; huge worlds degrade to serial.
        assert_eq!(resolve_intra_op_threads(3, 128), 3);
        assert_eq!(resolve_intra_op_threads(0, 100_000), 1);
    }

    #[test]
    fn worker_threads_default_serial() {
        // Nested kernels inside a morsel worker must not multiply.
        with_intra_op_threads(4, || {
            let budgets = map_parallel(vec![(); 3], |_| current().threads());
            assert_eq!(budgets, vec![1, 1, 1]);
        });
    }
}
