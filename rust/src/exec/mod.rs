//! Intra-rank morsel-driven parallel execution.
//!
//! The paper's execution model gives each MPI rank exactly one thread
//! (§III-B), so a rank uses one core no matter the machine. This module
//! adds the second level of the hybrid model (cf. "Supercharging
//! Distributed Computing Environments For High Performance Data
//! Engineering", Perera et al. 2023): inside one rank, local compute
//! kernels split their row ranges into cache-sized **morsels** and fan
//! them out over a **persistent per-rank worker pool** ([`WorkerPool`],
//! std threads, no dependencies) that parks between operators, so
//! back-to-back kernels reuse the same OS threads instead of respawning
//! them per call.
//!
//! Two invariants every parallel kernel in this crate upholds:
//!
//! 1. **Bit-identical results.** Morsel results are merged in morsel
//!    order, hash structures are radix-partitioned so each worker owns
//!    disjoint buckets and inserts rows in the serial order, and sorts
//!    use stable run-sort + stable merge. A parallel kernel at any
//!    thread count produces exactly the serial kernel's output —
//!    including splitmix64 bucket placement, SQL null semantics, and
//!    f64 accumulation order.
//! 2. **No oversubscription.** The thread budget is per rank thread
//!    (thread-local), so `world × intra_op_threads` is bounded by the
//!    machine: `dist::Cluster` resolves the `intra_op_threads = 0`
//!    (auto) knob to `available cores / world`, and pool workers
//!    themselves run under a serial budget, so nested kernels never
//!    multiply.
//!
//! The knob is `DistConfig::intra_op_threads` for cluster runs, or
//! [`set_intra_op_threads`] / [`with_intra_op_threads`] for local use;
//! `1` reproduces the original single-threaded behaviour exactly. The
//! `INTRA_OP_THREADS` env var overrides the serial *default* budget
//! (CI uses it to exercise every parallel path); explicit setters and
//! `DistConfig` still win.

#![warn(missing_docs)]

mod memory;
mod morsel;
mod pool;

use std::cell::Cell;
use std::sync::OnceLock;

pub use self::memory::{
    default_memory_budget_bytes, live_spill_dirs, memory_budget_bytes,
    reserved_bytes, reserved_peak, reset_reserved_peak,
    resolve_memory_budget_bytes, set_memory_budget_bytes, spill_bytes,
    spill_partitions, spill_root, with_memory_budget_bytes, MemoryBudget,
    Reservation, SpillDir, MEMORY_BUDGET_BYTES,
};
pub(crate) use self::memory::{note_spill, take_spill_stats};
pub use self::morsel::{
    fill_parallel, for_each_morsel, map_parallel, par_gather,
    run_partitions, split_even, split_morsels, Morsel, MORSEL_ROWS,
};
pub(crate) use self::morsel::{map_parallel_budgeted, SendPtr};
// Executor plumbing for `dist::Cluster` and the reuse tests — not part
// of the public API (the knobs above are; the pool is an internal).
pub(crate) use self::pool::{
    current_pool_spawned_threads, current_pool_steal_group,
    current_pool_stealable, install_thread_pool, link_steal_group,
    panic_message, WorkerPool,
};

/// Default parallelism row threshold: kernels fall back to the serial
/// path below this many rows — morsel startup is not worth it for tiny
/// inputs. Override per thread with [`set_par_row_threshold`] /
/// [`with_par_row_threshold`], per cluster with
/// `DistConfig::par_row_threshold`, or in config via
/// `[exec] par_row_threshold`.
pub const PAR_ROW_THRESHOLD: usize = 4096;

/// Default streaming-ingest chunk size in bytes: CSV readers consume
/// the source in chunks this large, so peak raw-text memory is
/// O(chunk + longest record) instead of O(file). Override per thread
/// with [`set_ingest_chunk_bytes`] / [`with_ingest_chunk_bytes`], per
/// cluster with `DistConfig::ingest_chunk_bytes`, on the CLI with
/// `--ingest-chunk`, or in config via `[exec] ingest_chunk_bytes`.
pub const INGEST_CHUNK_BYTES: usize = 4 << 20;

/// Default for the `[exec] ingest_single_pass` knob: distributed CSV
/// ingest ([`crate::dist::read_csv_partition`]) uses the single-pass
/// byte-range scheme (each byte of the file is read exactly once
/// across the cluster) instead of the two-pass count-then-parse
/// fallback. Override per thread with [`set_ingest_single_pass`] /
/// [`with_ingest_single_pass`], per cluster with
/// `DistConfig::ingest_single_pass`, on the CLI with
/// `--ingest-single-pass`, in config via `[exec] ingest_single_pass`,
/// or process-wide with the `INGEST_SINGLE_PASS` env var.
pub const INGEST_SINGLE_PASS: bool = true;

/// Default for the `[exec] work_steal` knob: morsel workers that drain
/// their own rank's queue steal tasks from sibling ranks' queues, so a
/// skewed partition no longer idles every other rank's workers.
/// Stealing changes *who* runs a morsel, never *where* its result
/// lands (morsels write to pre-indexed output slots), so results stay
/// bit-identical either way. Override per cluster with
/// `DistConfig::with_work_steal`, on the CLI with `--work-steal`, in
/// config via `[exec] work_steal`, or process-wide with the
/// `WORK_STEAL` env var.
pub const WORK_STEAL: bool = true;

/// Default for the `[exec] pipeline_fuse` knob: the pipeline executor
/// ([`crate::pipeline::Pipeline`]) compiles stage chains into fused
/// segments — select → project → join-probe → partial-agg run as one
/// pass per morsel with no intermediate `Table` between fused stages,
/// breakers (join build sides, groupby merges, sorts, shuffles) being
/// the only materialization points. Fusion changes *when* a row is
/// touched, never the per-row arithmetic or the merge order, so
/// results stay bit-identical to the operator-at-a-time path (the CI
/// oracle, `PIPELINE_FUSE=0`). Override per cluster with
/// `DistConfig::with_pipeline_fuse`, on the CLI with
/// `--pipeline-fuse`, in config via `[exec] pipeline_fuse`, or
/// process-wide with the `PIPELINE_FUSE` env var.
pub const PIPELINE_FUSE: bool = true;

/// Default for the `[exec] ryf_encoding` knob: RYF writers emit the
/// encoded `RYF2` format — per-row-group encodings (dictionary for
/// strings, RLE + bit-packing for ints, null-stripped validity) plus
/// per-group min/max/null-count zone-map statistics, so scans with a
/// pushed-down predicate can skip whole groups without decoding them
/// (`docs/STORAGE.md`). `false` writes the raw `RYF1` format — the
/// bit-identity oracle (the CI `RYF_ENCODING=0` leg). Readers always
/// accept both formats regardless of this knob. Override per cluster
/// with `DistConfig::with_ryf_encoding`, on the CLI with
/// `--ryf-encoding`, in config via `[exec] ryf_encoding`, or
/// process-wide with the `RYF_ENCODING` env var.
pub const RYF_ENCODING: bool = true;

/// Default for the `[exec] fault_plan` knob: no injected faults. A
/// non-empty plan (grammar in [`crate::net::faulty::FaultPlan`]; e.g.
/// `error@1:2,delay250@0:5`) makes every `dist::Cluster` wrap its
/// fabric in a [`crate::net::faulty::FaultyFabric`] firing those
/// faults deterministically. Override per cluster with
/// `DistConfig::with_fault_plan`, on the CLI with `--fault-plan`, in
/// config via `[exec] fault_plan`, or process-wide with the
/// `FAULT_PLAN` env var (the CI fault-injection leg).
pub const FAULT_PLAN: &str = "";

/// Default for the `[cluster] fabric` knob: real rank threads in one
/// process. `sim` is the calibrated BSP simulator; `tcp` runs one OS
/// process per rank over sockets (`docs/NET.md`), rendezvousing at
/// [`RENDEZVOUS`]. Override per run on the CLI with `--fabric`, in
/// config via `[cluster] fabric`, or process-wide with the
/// `RYLON_FABRIC` env var; library code picks a fabric explicitly via
/// `DistConfig`.
pub const FABRIC: &str = "threads";

/// Default for the `[cluster] rendezvous` knob: where a TCP job's
/// ranks meet (`host:port`; rank 0 listens there, every other rank
/// dials it — `docs/NET.md`). Override per run on the CLI with
/// `--rendezvous`, in config via `[cluster] rendezvous`, or
/// process-wide with the `RYLON_RENDEZVOUS` env var.
pub const RENDEZVOUS: &str = "127.0.0.1:29400";

/// Default for the `[exec] collective_timeout_ms` knob: `0` = no
/// timeout (a rank that never arrives at a collective parks its peers
/// forever — the pre-fault-domain behaviour). A non-zero value bounds
/// every fabric collective: if any rank fails to arrive in time, every
/// waiting rank gets the same rank-attributed timeout error
/// (`docs/FAULTS.md`). Override per cluster with
/// `DistConfig::with_collective_timeout_ms`, on the CLI with
/// `--collective-timeout`, in config via
/// `[exec] collective_timeout_ms`, or process-wide with the
/// `COLLECTIVE_TIMEOUT_MS` env var (the CI hang-detection leg).
pub const COLLECTIVE_TIMEOUT_MS: u64 = 0;

/// Immutable per-operation thread budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecContext {
    threads: usize,
}

impl ExecContext {
    /// Budget of `threads` morsel workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> ExecContext {
        ExecContext {
            threads: threads.max(1),
        }
    }

    /// The original single-threaded behaviour.
    pub fn serial() -> ExecContext {
        ExecContext { threads: 1 }
    }

    /// The budgeted worker count (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Whether kernels should take their parallel paths.
    pub fn is_parallel(&self) -> bool {
        self.threads > 1
    }
}

/// The process-wide default intra-op budget: `INTRA_OP_THREADS` from
/// the environment (≥ 1), else `1` (serial — the paper's model). Read
/// once; explicit setters always override it.
pub fn default_intra_op_threads() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("INTRA_OP_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&v| v >= 1)
            .unwrap_or(1)
    })
}

/// The process-wide default streaming-ingest chunk size:
/// `INGEST_CHUNK_BYTES` from the environment (≥ 1 byte; the CI
/// low-memory leg sets a tiny value so chunk-seam paths run in every
/// test), else [`INGEST_CHUNK_BYTES`]. Read once; explicit setters and
/// `DistConfig` always override it.
pub fn default_ingest_chunk_bytes() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("INGEST_CHUNK_BYTES")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&v| v >= 1)
            .unwrap_or(INGEST_CHUNK_BYTES)
    })
}

/// Parse a boolean env toggle: `0`/`false` disable, `1`/`true`
/// enable, anything else (including unset) keeps `default` — the one
/// spelling rule every boolean `[exec]` env var shares.
fn env_bool(var: &str, default: bool) -> bool {
    match std::env::var(var).ok().as_deref() {
        Some("0") | Some("false") => false,
        Some("1") | Some("true") => true,
        _ => default,
    }
}

/// The process-wide default for single-pass distributed ingest: the
/// `INGEST_SINGLE_PASS` env var (`0`/`false` disable, `1`/`true`
/// enable), else [`INGEST_SINGLE_PASS`]. Read once; explicit setters
/// and `DistConfig` always override it.
pub fn default_ingest_single_pass() -> bool {
    static DEFAULT: OnceLock<bool> = OnceLock::new();
    *DEFAULT
        .get_or_init(|| env_bool("INGEST_SINGLE_PASS", INGEST_SINGLE_PASS))
}

/// The process-wide default for cross-rank work stealing: the
/// `WORK_STEAL` env var (`0`/`false` disable, `1`/`true` enable), else
/// [`WORK_STEAL`]. Read once; explicit settings always override it.
pub fn default_work_steal() -> bool {
    static DEFAULT: OnceLock<bool> = OnceLock::new();
    *DEFAULT.get_or_init(|| env_bool("WORK_STEAL", WORK_STEAL))
}

/// The process-wide default for fused pipeline execution: the
/// `PIPELINE_FUSE` env var (`0`/`false` disable, `1`/`true` enable),
/// else [`PIPELINE_FUSE`]. Read once; explicit settings always
/// override it.
pub fn default_pipeline_fuse() -> bool {
    static DEFAULT: OnceLock<bool> = OnceLock::new();
    *DEFAULT.get_or_init(|| env_bool("PIPELINE_FUSE", PIPELINE_FUSE))
}

/// The process-wide default for encoded RYF writes: the `RYF_ENCODING`
/// env var (`0`/`false` disable, `1`/`true` enable), else
/// [`RYF_ENCODING`]. Read once; explicit settings always override it.
pub fn default_ryf_encoding() -> bool {
    static DEFAULT: OnceLock<bool> = OnceLock::new();
    *DEFAULT.get_or_init(|| env_bool("RYF_ENCODING", RYF_ENCODING))
}

/// The process-wide default fault-injection plan: the `FAULT_PLAN` env
/// var, else [`FAULT_PLAN`] (empty — no faults). Read once; explicit
/// settings always override it. The plan is parsed (and validated) by
/// `dist::Cluster::new`, not here, so a malformed env plan surfaces as
/// a cluster-construction error rather than a silent no-op.
pub fn default_fault_plan() -> &'static str {
    static DEFAULT: OnceLock<String> = OnceLock::new();
    DEFAULT.get_or_init(|| {
        std::env::var("FAULT_PLAN").unwrap_or_else(|_| FAULT_PLAN.into())
    })
}

/// The process-wide default collective timeout: the
/// `COLLECTIVE_TIMEOUT_MS` env var (milliseconds), else
/// [`COLLECTIVE_TIMEOUT_MS`] (0 = no timeout). Read once; explicit
/// settings always override it.
pub fn default_collective_timeout_ms() -> u64 {
    static DEFAULT: OnceLock<u64> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("COLLECTIVE_TIMEOUT_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(COLLECTIVE_TIMEOUT_MS)
    })
}

/// The process-wide default fabric name: the `RYLON_FABRIC` env var,
/// else [`FABRIC`] (`threads`). Read once. Flows into configuration
/// defaults (`conf::RylonConfig`, the CLI) — *not* into
/// `DistConfig::default()`, so library callers always get the fabric
/// they name.
pub fn default_fabric() -> &'static str {
    static DEFAULT: OnceLock<String> = OnceLock::new();
    DEFAULT.get_or_init(|| {
        std::env::var("RYLON_FABRIC").unwrap_or_else(|_| FABRIC.into())
    })
}

/// The process-wide default rendezvous address: the `RYLON_RENDEZVOUS`
/// env var, else [`RENDEZVOUS`]. Read once; flows into configuration
/// defaults like [`default_fabric`].
pub fn default_rendezvous() -> &'static str {
    static DEFAULT: OnceLock<String> = OnceLock::new();
    DEFAULT.get_or_init(|| {
        std::env::var("RYLON_RENDEZVOUS")
            .unwrap_or_else(|_| RENDEZVOUS.into())
    })
}

/// Resolve a configured fault plan: `None` = the process default
/// (env-overridable via `FAULT_PLAN`), `Some` passes through.
pub fn resolve_fault_plan(configured: Option<&str>) -> String {
    configured.unwrap_or_else(default_fault_plan).to_string()
}

/// Resolve a configured collective timeout: `None` = the process
/// default (env-overridable via `COLLECTIVE_TIMEOUT_MS`), `Some`
/// passes through; `0` always means "no timeout".
pub fn resolve_collective_timeout_ms(configured: Option<u64>) -> u64 {
    configured.unwrap_or_else(default_collective_timeout_ms)
}

thread_local! {
    /// Per-thread intra-op budget. Rank threads get theirs from
    /// `dist::Cluster::run`; everything else starts at the process
    /// default (serial unless `INTRA_OP_THREADS` is set). Pool workers
    /// explicitly pin themselves to serial.
    static CURRENT_THREADS: Cell<usize> = Cell::new(default_intra_op_threads());

    /// Per-thread parallelism row threshold (see [`PAR_ROW_THRESHOLD`]).
    static ROW_THRESHOLD: Cell<usize> = const { Cell::new(PAR_ROW_THRESHOLD) };

    /// Per-thread streaming-ingest chunk size (see
    /// [`INGEST_CHUNK_BYTES`]).
    static CHUNK_BYTES: Cell<usize> = Cell::new(default_ingest_chunk_bytes());

    /// Per-thread single-pass-ingest toggle (see [`INGEST_SINGLE_PASS`]).
    static SINGLE_PASS: Cell<bool> = Cell::new(default_ingest_single_pass());

    /// Per-thread work-stealing toggle (see [`WORK_STEAL`]). Purely a
    /// mirror for observability: the authoritative wiring is whether
    /// `dist::Cluster` linked the rank pools' steal handles at
    /// installation.
    static STEAL: Cell<bool> = Cell::new(default_work_steal());

    /// Per-thread fused-pipeline toggle (see [`PIPELINE_FUSE`]). Read
    /// by `pipeline::Pipeline::{run_local,run_dist}` at entry to pick
    /// the fused or operator-at-a-time executor.
    static FUSE: Cell<bool> = Cell::new(default_pipeline_fuse());

    /// Per-thread encoded-RYF-writes toggle (see [`RYF_ENCODING`]).
    /// Read by `io::ryf::RyfWriter::create` to pick the raw or encoded
    /// file format.
    static RYF_ENC: Cell<bool> = Cell::new(default_ryf_encoding());

    /// Per-thread RYF scan-pushdown counters, drained by
    /// `dist::Cluster::run` into the cluster-wide atomics (and by the
    /// CLI into the ETL phase JSON): groups skipped via zone maps,
    /// groups decoded, bytes decoded, bytes whose decode was avoided
    /// (skipped groups + pruned column payloads), and column payloads
    /// pruned by projection pushdown.
    static SCAN_STATS: Cell<ScanCounters> =
        const { Cell::new(ScanCounters::new()) };
}

/// Cumulative RYF scan-pushdown counters (`docs/STORAGE.md`): one
/// value per observability surface, additive across scans.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScanCounters {
    /// Row groups considered by scans.
    pub groups_total: u64,
    /// Row groups skipped whole via zone-map statistics (never
    /// decoded).
    pub groups_skipped: u64,
    /// Serialized group/column bytes actually decoded.
    pub decoded_bytes: u64,
    /// Serialized bytes whose decode was avoided (skipped groups plus
    /// pruned column payloads).
    pub decoded_bytes_avoided: u64,
    /// Column payloads skipped by projection pushdown.
    pub pruned_columns: u64,
}

impl ScanCounters {
    /// All-zero counters.
    pub const fn new() -> ScanCounters {
        ScanCounters {
            groups_total: 0,
            groups_skipped: 0,
            decoded_bytes: 0,
            decoded_bytes_avoided: 0,
            pruned_columns: 0,
        }
    }

    /// Element-wise sum.
    pub fn add(&mut self, other: &ScanCounters) {
        self.groups_total += other.groups_total;
        self.groups_skipped += other.groups_skipped;
        self.decoded_bytes += other.decoded_bytes;
        self.decoded_bytes_avoided += other.decoded_bytes_avoided;
        self.pruned_columns += other.pruned_columns;
    }
}

/// Record one scan's pushdown counters on the calling thread
/// (accumulated; drained by [`take_scan_stats`]).
pub(crate) fn note_scan(stats: &ScanCounters) {
    SCAN_STATS.with(|c| {
        let mut cur = c.get();
        cur.add(stats);
        c.set(cur);
    });
}

/// Drain the calling thread's accumulated scan counters (resetting
/// them to zero) — `dist::Cluster::run` calls this on every rank
/// thread after the rank closure finishes.
pub fn take_scan_stats() -> ScanCounters {
    SCAN_STATS.with(|c| c.replace(ScanCounters::new()))
}

/// The calling thread's current intra-op budget.
pub fn current() -> ExecContext {
    ExecContext::new(CURRENT_THREADS.with(|c| c.get()))
}

/// Set the calling thread's intra-op budget (`1` = serial).
pub fn set_intra_op_threads(threads: usize) {
    CURRENT_THREADS.with(|c| c.set(threads.max(1)));
}

/// Run `f` under a temporary intra-op budget, restoring the previous
/// budget afterwards.
pub fn with_intra_op_threads<T>(threads: usize, f: impl FnOnce() -> T) -> T {
    let prev = CURRENT_THREADS.with(|c| c.replace(threads.max(1)));
    let out = f();
    CURRENT_THREADS.with(|c| c.set(prev));
    out
}

/// The calling thread's parallelism row threshold.
pub fn par_row_threshold() -> usize {
    ROW_THRESHOLD.with(|c| c.get())
}

/// Set the calling thread's parallelism row threshold (clamped to ≥ 1
/// so empty inputs never take the parallel path).
pub fn set_par_row_threshold(rows: usize) {
    ROW_THRESHOLD.with(|c| c.set(rows.max(1)));
}

/// Run `f` under a temporary parallelism row threshold, restoring the
/// previous threshold afterwards — how benches/tests force the parallel
/// path on small inputs.
pub fn with_par_row_threshold<T>(rows: usize, f: impl FnOnce() -> T) -> T {
    let prev = ROW_THRESHOLD.with(|c| c.replace(rows.max(1)));
    let out = f();
    ROW_THRESHOLD.with(|c| c.set(prev));
    out
}

/// The calling thread's streaming-ingest chunk size in bytes.
pub fn ingest_chunk_bytes() -> usize {
    CHUNK_BYTES.with(|c| c.get())
}

/// Set the calling thread's streaming-ingest chunk size (clamped to
/// ≥ 1 byte).
pub fn set_ingest_chunk_bytes(bytes: usize) {
    CHUNK_BYTES.with(|c| c.set(bytes.max(1)));
}

/// Run `f` under a temporary streaming-ingest chunk size, restoring the
/// previous value afterwards — how tests force many chunk seams on tiny
/// inputs.
pub fn with_ingest_chunk_bytes<T>(bytes: usize, f: impl FnOnce() -> T) -> T {
    let prev = CHUNK_BYTES.with(|c| c.replace(bytes.max(1)));
    let out = f();
    CHUNK_BYTES.with(|c| c.set(prev));
    out
}

/// Resolve a configured ingest chunk size: `0` = the process default
/// (env-overridable via `INGEST_CHUNK_BYTES`), anything else passes
/// through.
pub fn resolve_ingest_chunk_bytes(configured: usize) -> usize {
    if configured > 0 {
        configured
    } else {
        default_ingest_chunk_bytes()
    }
}

/// Whether the calling thread's distributed CSV ingest takes the
/// single-pass byte-range path (see
/// [`crate::dist::read_csv_partition`]).
pub fn ingest_single_pass() -> bool {
    SINGLE_PASS.with(|c| c.get())
}

/// Set the calling thread's single-pass-ingest toggle.
pub fn set_ingest_single_pass(on: bool) {
    SINGLE_PASS.with(|c| c.set(on));
}

/// Run `f` with single-pass distributed ingest forced on or off,
/// restoring the previous setting afterwards.
pub fn with_ingest_single_pass<T>(on: bool, f: impl FnOnce() -> T) -> T {
    let prev = SINGLE_PASS.with(|c| c.replace(on));
    let out = f();
    SINGLE_PASS.with(|c| c.set(prev));
    out
}

/// Resolve a configured single-pass toggle: `None` = the process
/// default (env-overridable via `INGEST_SINGLE_PASS`), `Some` passes
/// through.
pub fn resolve_ingest_single_pass(configured: Option<bool>) -> bool {
    configured.unwrap_or_else(default_ingest_single_pass)
}

/// Whether cross-rank work stealing is on for the calling thread's
/// cluster (rank threads mirror the resolved `[exec] work_steal` knob
/// here; see [`WORK_STEAL`]).
pub fn work_steal() -> bool {
    STEAL.with(|c| c.get())
}

/// Set the calling thread's work-stealing mirror (done by
/// `dist::Cluster::run` for rank threads; informational elsewhere).
pub fn set_work_steal(on: bool) {
    STEAL.with(|c| c.set(on));
}

/// Run `f` with the work-stealing mirror forced on or off, restoring
/// the previous setting afterwards.
pub fn with_work_steal<T>(on: bool, f: impl FnOnce() -> T) -> T {
    let prev = STEAL.with(|c| c.replace(on));
    let out = f();
    STEAL.with(|c| c.set(prev));
    out
}

/// Resolve a configured work-steal toggle: `None` = the process
/// default (env-overridable via `WORK_STEAL`), `Some` passes through.
pub fn resolve_work_steal(configured: Option<bool>) -> bool {
    configured.unwrap_or_else(default_work_steal)
}

/// Whether the calling thread's pipelines run fused segments (see
/// [`PIPELINE_FUSE`]).
pub fn pipeline_fuse() -> bool {
    FUSE.with(|c| c.get())
}

/// Set the calling thread's fused-pipeline toggle (done by
/// `dist::Cluster::run` for rank threads and by the CLI for local
/// commands).
pub fn set_pipeline_fuse(on: bool) {
    FUSE.with(|c| c.set(on));
}

/// Run `f` with fused pipeline execution forced on or off, restoring
/// the previous setting afterwards — how the equivalence matrix and
/// the fused-vs-materialized bench arm flip executors in-process.
pub fn with_pipeline_fuse<T>(on: bool, f: impl FnOnce() -> T) -> T {
    let prev = FUSE.with(|c| c.replace(on));
    let out = f();
    FUSE.with(|c| c.set(prev));
    out
}

/// Resolve a configured fused-pipeline toggle: `None` = the process
/// default (env-overridable via `PIPELINE_FUSE`), `Some` passes
/// through.
pub fn resolve_pipeline_fuse(configured: Option<bool>) -> bool {
    configured.unwrap_or_else(default_pipeline_fuse)
}

/// Whether the calling thread's RYF writes emit the encoded `RYF2`
/// format (see [`RYF_ENCODING`]).
pub fn ryf_encoding() -> bool {
    RYF_ENC.with(|c| c.get())
}

/// Set the calling thread's encoded-RYF-writes toggle (done by
/// `dist::Cluster::run` for rank threads and by the CLI for local
/// commands).
pub fn set_ryf_encoding(on: bool) {
    RYF_ENC.with(|c| c.set(on));
}

/// Run `f` with encoded RYF writes forced on or off, restoring the
/// previous setting afterwards — how the equivalence matrix and the
/// scan-selectivity bench write raw-oracle and encoded files from one
/// process.
pub fn with_ryf_encoding<T>(on: bool, f: impl FnOnce() -> T) -> T {
    let prev = RYF_ENC.with(|c| c.replace(on));
    let out = f();
    RYF_ENC.with(|c| c.set(prev));
    out
}

/// Resolve a configured encoded-RYF toggle: `None` = the process
/// default (env-overridable via `RYF_ENCODING`), `Some` passes
/// through.
pub fn resolve_ryf_encoding(configured: Option<bool>) -> bool {
    configured.unwrap_or_else(default_ryf_encoding)
}

/// The effective budget for an `nrows`-row kernel: the thread-local
/// budget, degraded to serial below the thread's row threshold.
pub fn parallelism_for(nrows: usize) -> ExecContext {
    if nrows < par_row_threshold() {
        ExecContext::serial()
    } else {
        current()
    }
}

/// Whether a morsel fan-out on the calling thread can use more than
/// one worker: either the thread's own budget is parallel, or its
/// installed pool is steal-linked to sibling rank pools (so even a
/// serial-budget rank's queued morsels can run on idle sibling
/// workers — execution decoupled from static rank ownership).
pub(crate) fn morsel_parallel(exec: ExecContext) -> bool {
    exec.is_parallel() || current_pool_stealable()
}

/// Split width for kernels that carve one batch of near-equal parts
/// (select's predicate pass and index build, bitmap gathers, hash-build
/// partitioning): the thread budget, widened to the steal group's pool
/// count when the calling thread's executor is steal-linked. An
/// `intra_op_threads = 1` rank in a linked group then produces one part
/// per group pool instead of a single serial slab, so idle sibling
/// workers can claim a share. Part counts never change kernel results
/// (parts are concatenated or prefix-summed in order), so this is
/// purely a scheduling width.
pub(crate) fn split_width(exec: ExecContext) -> usize {
    exec.threads().max(current_pool_steal_group())
}

/// Resolve a configured knob value: `0` = auto (available cores divided
/// evenly over `world` rank threads, so the fabric's rank threads and
/// the morsel workers together never oversubscribe the machine — the
/// `INTRA_OP_THREADS` default-budget override deliberately does *not*
/// apply here, or a leaked env var could break that bound).
pub fn resolve_intra_op_threads(configured: usize, world: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    (cores / world.max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_budget_matches_env() {
        // Serial unless the CI matrix exports INTRA_OP_THREADS.
        assert_eq!(current().threads(), default_intra_op_threads());
        assert_eq!(
            current().is_parallel(),
            default_intra_op_threads() > 1
        );
    }

    #[test]
    fn scoped_budget_restores() {
        let inner = with_intra_op_threads(4, || current().threads());
        assert_eq!(inner, 4);
        assert_eq!(current().threads(), default_intra_op_threads());
    }

    #[test]
    fn zero_clamps_to_one() {
        set_intra_op_threads(0);
        assert_eq!(current().threads(), 1);
        set_intra_op_threads(default_intra_op_threads());
    }

    #[test]
    fn threshold_degrades_small_inputs() {
        with_intra_op_threads(8, || {
            assert!(!parallelism_for(10).is_parallel());
            assert!(parallelism_for(par_row_threshold()).is_parallel());
        });
    }

    #[test]
    fn threshold_knob_scopes_and_restores() {
        let prev = par_row_threshold();
        with_intra_op_threads(4, || {
            with_par_row_threshold(8, || {
                assert_eq!(par_row_threshold(), 8);
                assert!(parallelism_for(8).is_parallel());
                assert!(!parallelism_for(7).is_parallel());
            });
            assert_eq!(par_row_threshold(), prev);
        });
        // Zero clamps so empty inputs stay serial.
        with_par_row_threshold(0, || {
            assert!(!parallelism_for(0).is_parallel());
        });
    }

    #[test]
    fn ingest_chunk_knob_scopes_and_restores() {
        let prev = ingest_chunk_bytes();
        with_ingest_chunk_bytes(64, || {
            assert_eq!(ingest_chunk_bytes(), 64);
            // Zero clamps so the scanner always makes progress.
            with_ingest_chunk_bytes(0, || {
                assert_eq!(ingest_chunk_bytes(), 1);
            });
        });
        assert_eq!(ingest_chunk_bytes(), prev);
        // 0 = the process default; explicit values pass through.
        assert_eq!(
            resolve_ingest_chunk_bytes(0),
            default_ingest_chunk_bytes()
        );
        assert_eq!(resolve_ingest_chunk_bytes(123), 123);
    }

    #[test]
    fn single_pass_knob_scopes_and_restores() {
        let prev = ingest_single_pass();
        with_ingest_single_pass(!prev, || {
            assert_eq!(ingest_single_pass(), !prev);
        });
        assert_eq!(ingest_single_pass(), prev);
        // None = the process default; Some passes through.
        assert_eq!(
            resolve_ingest_single_pass(None),
            default_ingest_single_pass()
        );
        assert!(resolve_ingest_single_pass(Some(true)));
        assert!(!resolve_ingest_single_pass(Some(false)));
    }

    #[test]
    fn work_steal_knob_scopes_and_restores() {
        let prev = work_steal();
        with_work_steal(!prev, || {
            assert_eq!(work_steal(), !prev);
        });
        assert_eq!(work_steal(), prev);
        // None = the process default; Some passes through.
        assert_eq!(resolve_work_steal(None), default_work_steal());
        assert!(resolve_work_steal(Some(true)));
        assert!(!resolve_work_steal(Some(false)));
        // A thread with no steal-linked pool never routes serial-budget
        // work to the pool, whatever the mirror says.
        with_work_steal(true, || {
            assert!(!morsel_parallel(ExecContext::serial()));
            assert!(morsel_parallel(ExecContext::new(2)));
        });
    }

    #[test]
    fn pipeline_fuse_knob_scopes_and_restores() {
        let prev = pipeline_fuse();
        with_pipeline_fuse(!prev, || {
            assert_eq!(pipeline_fuse(), !prev);
        });
        assert_eq!(pipeline_fuse(), prev);
        // None = the process default; Some passes through.
        assert_eq!(resolve_pipeline_fuse(None), default_pipeline_fuse());
        assert!(resolve_pipeline_fuse(Some(true)));
        assert!(!resolve_pipeline_fuse(Some(false)));
    }

    #[test]
    fn ryf_encoding_knob_scopes_and_restores() {
        let prev = ryf_encoding();
        with_ryf_encoding(!prev, || {
            assert_eq!(ryf_encoding(), !prev);
        });
        assert_eq!(ryf_encoding(), prev);
        // None = the process default; Some passes through.
        assert_eq!(resolve_ryf_encoding(None), default_ryf_encoding());
        assert!(resolve_ryf_encoding(Some(true)));
        assert!(!resolve_ryf_encoding(Some(false)));
    }

    #[test]
    fn scan_counters_accumulate_and_drain() {
        // Start from a clean slate (other tests on this thread may
        // have scanned).
        let _ = take_scan_stats();
        let one = ScanCounters {
            groups_total: 4,
            groups_skipped: 3,
            decoded_bytes: 100,
            decoded_bytes_avoided: 300,
            pruned_columns: 2,
        };
        note_scan(&one);
        note_scan(&one);
        let drained = take_scan_stats();
        assert_eq!(drained.groups_total, 8);
        assert_eq!(drained.groups_skipped, 6);
        assert_eq!(drained.decoded_bytes, 200);
        assert_eq!(drained.decoded_bytes_avoided, 600);
        assert_eq!(drained.pruned_columns, 4);
        // Drained means drained.
        assert_eq!(take_scan_stats(), ScanCounters::new());
    }

    #[test]
    fn split_width_matches_budget_off_a_steal_group() {
        // A thread with no steal-linked pool splits by its own budget;
        // the steal-group widening is covered from `dist` (where linked
        // pools exist).
        with_intra_op_threads(3, || {
            assert_eq!(split_width(current()), 3);
        });
        with_intra_op_threads(1, || {
            assert_eq!(split_width(current()), 1);
        });
    }

    #[test]
    fn fault_knobs_resolve() {
        // None = the process default; Some passes through.
        assert_eq!(resolve_fault_plan(None), default_fault_plan());
        assert_eq!(resolve_fault_plan(Some("error@1:2")), "error@1:2");
        assert_eq!(
            resolve_collective_timeout_ms(None),
            default_collective_timeout_ms()
        );
        assert_eq!(resolve_collective_timeout_ms(Some(250)), 250);
        assert_eq!(resolve_collective_timeout_ms(Some(0)), 0);
    }

    #[test]
    fn auto_resolution_divides_cores() {
        let one_rank = resolve_intra_op_threads(0, 1);
        assert!(one_rank >= 1);
        // Explicit values pass through; huge worlds degrade to serial
        // (the INTRA_OP_THREADS default never bypasses the division).
        assert_eq!(resolve_intra_op_threads(3, 128), 3);
        assert_eq!(resolve_intra_op_threads(0, 100_000), 1);
    }

    #[test]
    fn worker_threads_default_serial() {
        // Nested kernels inside a pool worker must not multiply.
        with_intra_op_threads(4, || {
            let budgets = map_parallel(vec![(); 3], |_| current().threads());
            assert_eq!(budgets, vec![1, 1, 1]);
        });
    }

    #[test]
    fn back_to_back_operators_reuse_pool_threads() {
        // The ROADMAP pool-respawn fix, observed through the public
        // scoped API: two consecutive parallel operators on this thread
        // leave the thread-generation counter unchanged.
        with_intra_op_threads(3, || {
            let exec = current();
            let a = for_each_morsel(1 << 18, exec, |m| m.len());
            let gen = current_pool_spawned_threads();
            assert!(gen >= 2, "first parallel op must spawn workers");
            let b = for_each_morsel(1 << 18, exec, |m| m.len());
            assert_eq!(current_pool_spawned_threads(), gen);
            assert_eq!(a, b);
            let _ = run_partitions(3, |p| p);
            assert_eq!(current_pool_spawned_threads(), gen);
        });
    }
}
