//! Drivers that regenerate the paper's evaluation figures (§V). Shared
//! by the `cargo bench` targets and the `rylon bench` CLI subcommand so
//! both produce identical tables.
//!
//! All scaling runs use the **sim fabric** (DESIGN.md §3): per-rank
//! compute is measured thread-CPU work, communication is the calibrated
//! α-β model, and the reported time is the BSP makespan. On the paper's
//! own testbed these would be wall-clock MPI runs; on this single-core
//! box the simulator is what preserves the scaling *shape*.

use crate::baselines::{
    DaskSimEngine, JoinEngine, ModinSimEngine, RylonEngine, SparkSimEngine,
};
use crate::bench_harness::{measure_with, BenchOpts, Report};
use crate::binding::{kwargs, DynTable};
use crate::dist::{Cluster, DistConfig};
use crate::error::Result;
use crate::io::datagen::{gen_partition, gen_table, DataGenSpec};
use crate::net::CostModel;
use crate::ops::join::{join, JoinAlgo, JoinOptions};
use crate::runtime::{HashKernel, Runtime};

/// Engine registry for the comparison figures.
pub fn engine_by_name(name: &str) -> Option<Box<dyn JoinEngine>> {
    match name {
        "rylon" => Some(Box::new(RylonEngine)),
        "spark_sim" => Some(Box::new(SparkSimEngine)),
        "dask_sim" => Some(Box::new(DaskSimEngine)),
        "modin_sim" => Some(Box::new(ModinSimEngine)),
        _ => None,
    }
}

/// One simulated distributed join: returns the makespan in seconds.
pub fn sim_join_makespan(
    engine: &dyn JoinEngine,
    total_rows: usize,
    world: usize,
    cost: CostModel,
    chunk_rows: usize,
) -> Result<f64> {
    let mut cfg = DistConfig::sim(world, cost);
    cfg.shuffle_chunk_rows = chunk_rows;
    let cluster = Cluster::new(cfg)?;
    let opts = JoinOptions::inner("id", "id");
    cluster.run(|ctx| {
        let l = gen_partition(
            &DataGenSpec::paper_scaling(total_rows, 0xA),
            ctx.rank,
            ctx.size,
        )?;
        let r = gen_partition(
            &DataGenSpec::paper_scaling(total_rows, 0xB),
            ctx.rank,
            ctx.size,
        )?;
        engine.dist_join(ctx, &l, &r, &opts)
    })?;
    Ok(cluster.makespan().unwrap_or(0.0))
}

/// Fig 10 — strong scaling of the distributed inner join: fixed total
/// work (paper: 200M rows/relation), parallelism swept 1→160, four
/// engines.
pub fn fig10(
    total_rows: usize,
    worlds: &[usize],
    engines: &[&str],
    opts: BenchOpts,
    cost: CostModel,
) -> Result<Report> {
    let mut report = Report::new(&format!(
        "Fig 10: strong scaling, inner join, {total_rows} rows/relation (simulated makespan)"
    ));
    for name in engines {
        let engine = engine_by_name(name)
            .ok_or_else(|| crate::RylonError::invalid(format!("engine {name}")))?;
        for &w in worlds {
            let stats = measure_with(opts, || {
                sim_join_makespan(engine.as_ref(), total_rows, w, cost, 1 << 16)
                    .expect("sim join")
            });
            report.add_with(
                name,
                w as f64,
                stats.median,
                vec![("min".to_string(), stats.min)],
            );
        }
    }
    Ok(report)
}

/// Fig 11 — larger loads: fixed parallelism (paper: 200), total work
/// swept 200M → 10B rows, rylon vs spark_sim; the paper's claim is the
/// time *ratio* grows from ~2.1× to ~4.5×.
pub fn fig11(
    rows_sweep: &[usize],
    world: usize,
    opts: BenchOpts,
    cost: CostModel,
) -> Result<Report> {
    let mut report = Report::new(&format!(
        "Fig 11: rylon vs spark_sim, {world} ranks (simulated makespan)"
    ));
    for &rows in rows_sweep {
        let ry = measure_with(opts, || {
            sim_join_makespan(&RylonEngine, rows, world, cost, 1 << 16)
                .expect("rylon join")
        });
        let sp = measure_with(opts, || {
            sim_join_makespan(&SparkSimEngine, rows, world, cost, 1 << 16)
                .expect("spark join")
        });
        let ratio = sp.median / ry.median.max(1e-12);
        report.add_with(
            "rylon",
            rows as f64,
            ry.median,
            vec![("ratio_spark_over_rylon".to_string(), ratio)],
        );
        report.add("spark_sim", rows as f64, sp.median);
    }
    Ok(report)
}

/// Fig 12 — binding overhead: the identical inner join (sort) driven
/// through (a) the typed core API, (b) the dynamic binding layer, and
/// (c) with the hash hot-spot crossing into the PJRT artifact. The
/// paper's claim: the thin-binding curves coincide.
///
/// `workers` scales the per-worker slice of the fixed total (the paper
/// plots 200M rows at 1..160 workers; per-worker time is what each arm
/// measures).
pub fn fig12(
    total_rows: usize,
    workers: &[usize],
    runtime: Option<&Runtime>,
    opts: BenchOpts,
) -> Result<Report> {
    let mut report = Report::new(&format!(
        "Fig 12: binding overhead, inner join (sort), {total_rows} rows total"
    ));
    for &w in workers {
        let rows = (total_rows / w).max(1);
        let left = gen_table(&DataGenSpec::paper_scaling(rows, 0xC))?;
        let right = gen_table(&DataGenSpec::paper_scaling(rows, 0xD))?;
        let jopts = JoinOptions::inner("id", "id").with_algo(JoinAlgo::Sort);

        // (a) typed core API.
        let core = measure_with(opts, || {
            let t = std::time::Instant::now();
            let out = join(&left, &right, &jopts).expect("join");
            std::hint::black_box(out.num_rows());
            t.elapsed().as_secs_f64()
        });
        report.add("core", w as f64, core.median);

        // (b) dynamic binding layer (string dispatch + kwarg marshal).
        let dl = DynTable::wrap(left.clone());
        let dr = DynTable::wrap(right.clone());
        let binding = measure_with(opts, || {
            let t = std::time::Instant::now();
            let out = dl
                .call2(
                    "join",
                    &dr,
                    &kwargs(&[
                        ("on", "id".into()),
                        ("how", "inner".into()),
                        ("algorithm", "sort".into()),
                    ]),
                )
                .expect("dyn join");
            std::hint::black_box(out.table().num_rows());
            t.elapsed().as_secs_f64()
        });
        report.add("binding", w as f64, binding.median);

        // (c) PJRT artifact path for the partition hot-spot + core join
        // (the "foreign runtime" arm; native-hash fallback if artifacts
        // are absent, flagged in the label).
        let label = match runtime {
            Some(_) => "pjrt",
            None => "pjrt(native-fallback)",
        };
        let keys = left.column_by_name("id")?.i64_values().to_vec();
        let pjrt = measure_with(opts, || {
            let t = std::time::Instant::now();
            let nparts = 16usize;
            let (pids, hist) = match runtime {
                Some(rt) => {
                    let k = HashKernel::new(rt, nparts);
                    k.run(&keys).expect("hash kernel")
                }
                None => HashKernel::native(nparts).run(&keys).expect("hash"),
            };
            std::hint::black_box((pids.len(), hist.len()));
            let out = join(&left, &right, &jopts).expect("join");
            std::hint::black_box(out.num_rows());
            t.elapsed().as_secs_f64()
        });
        report.add(label, w as f64, pjrt.median);
    }
    Ok(report)
}

/// Ablation: hash vs sort join algorithms on the local path.
pub fn ablation_join_algo(rows_sweep: &[usize], opts: BenchOpts) -> Result<Report> {
    let mut report =
        Report::new("Ablation: local join algorithm (hash vs sort)");
    for &rows in rows_sweep {
        let left = gen_table(&DataGenSpec::paper_scaling(rows, 1))?;
        let right = gen_table(&DataGenSpec::paper_scaling(rows, 2))?;
        for (name, algo) in
            [("sort", JoinAlgo::Sort), ("hash", JoinAlgo::Hash)]
        {
            let jopts = JoinOptions::inner("id", "id").with_algo(algo);
            let stats = measure_with(opts, || {
                let t = std::time::Instant::now();
                let out = join(&left, &right, &jopts).expect("join");
                std::hint::black_box(out.num_rows());
                t.elapsed().as_secs_f64()
            });
            report.add(name, rows as f64, stats.median);
        }
    }
    Ok(report)
}

/// Ablation: fabric cost-model sweep — demonstrates the comm-bound
/// plateau moving with α (the paper's §V-1 explanation).
pub fn ablation_fabric(
    total_rows: usize,
    worlds: &[usize],
    alphas: &[f64],
    opts: BenchOpts,
) -> Result<Report> {
    let mut report = Report::new(
        "Ablation: scaling plateau vs network latency α (rylon join)",
    );
    for &alpha in alphas {
        let cost = CostModel {
            alpha,
            ..CostModel::default()
        };
        let label = format!("alpha={alpha:.0e}");
        for &w in worlds {
            let stats = measure_with(opts, || {
                sim_join_makespan(&RylonEngine, total_rows, w, cost, 1 << 16)
                    .expect("sim join")
            });
            report.add(&label, w as f64, stats.median);
        }
    }
    Ok(report)
}

/// Ablation: shuffle chunk size (streaming vs buffered AllToAll).
pub fn ablation_chunk(
    total_rows: usize,
    world: usize,
    chunks: &[usize],
    opts: BenchOpts,
) -> Result<Report> {
    let mut report =
        Report::new("Ablation: shuffle chunk rows (backpressure knob)");
    for &chunk in chunks {
        let stats = measure_with(opts, || {
            sim_join_makespan(
                &RylonEngine,
                total_rows,
                world,
                CostModel::default(),
                chunk,
            )
            .expect("sim join")
        });
        report.add("rylon", chunk as f64, stats.median);
    }
    Ok(report)
}

/// Ablation: dist_groupby shuffle-then-aggregate vs local pre-aggregate.
pub fn ablation_groupby(
    total_rows: usize,
    world: usize,
    ngroups: u64,
    opts: BenchOpts,
) -> Result<Report> {
    use crate::dist::{dist_groupby, dist_groupby_preagg};
    use crate::ops::groupby::{Agg, GroupByOptions};
    let mut report = Report::new(&format!(
        "Ablation: dist groupby strategies, {ngroups} groups, {world} ranks"
    ));
    for (name, preagg) in [("shuffle-all", false), ("pre-agg", true)] {
        let stats = measure_with(opts, || {
            let cluster =
                Cluster::new(DistConfig::sim(world, CostModel::default()))
                    .expect("cluster");
            cluster
                .run(|ctx| {
                    let part = gen_partition(
                        &DataGenSpec {
                            rows: total_rows,
                            payload_cols: 1,
                            key_dist:
                                crate::io::datagen::KeyDist::Uniform {
                                    domain: ngroups,
                                },
                            seed: 5,
                        },
                        ctx.rank,
                        ctx.size,
                    )?;
                    let gopts = GroupByOptions::new(
                        &["id"],
                        vec![Agg::sum("d0"), Agg::count("d0")],
                    );
                    let out = if preagg {
                        dist_groupby_preagg(ctx, &part, &gopts)?
                    } else {
                        dist_groupby(ctx, &part, &gopts)?
                    };
                    Ok(out.num_rows())
                })
                .expect("groupby");
            cluster.makespan().unwrap_or(0.0)
        });
        report.add(name, ngroups as f64, stats.median);
    }
    Ok(report)
}

/// Sanity helper shared by tests: a quick correctness probe that the
/// figure workloads produce non-trivial joins.
pub fn probe_join_rows(total_rows: usize) -> Result<usize> {
    let l = gen_table(&DataGenSpec::paper_scaling(total_rows, 0xA))?;
    let r = gen_table(&DataGenSpec::paper_scaling(total_rows, 0xB))?;
    Ok(join(&l, &r, &JoinOptions::inner("id", "id"))?.num_rows())
}

#[cfg(test)]
mod tests {
    use super::*;

    const FAST: BenchOpts = BenchOpts {
        warmup_iters: 0,
        samples: 1,
    };

    #[test]
    fn fig10_small_produces_all_series() {
        let r = fig10(
            2000,
            &[1, 2, 4],
            &["rylon", "spark_sim"],
            FAST,
            CostModel::default(),
        )
        .unwrap();
        assert_eq!(r.samples.len(), 6);
        assert!(r.render().contains("rylon"));
    }

    #[test]
    fn fig11_reports_ratio() {
        let r = fig11(&[1000, 4000], 2, FAST, CostModel::default()).unwrap();
        let with_ratio = r
            .samples
            .iter()
            .find(|s| !s.extra.is_empty())
            .expect("ratio sample");
        assert!(with_ratio.extra[0].1 > 0.0);
    }

    #[test]
    fn fig12_three_arms() {
        let r = fig12(4000, &[1, 2], None, FAST).unwrap();
        let labels: std::collections::HashSet<_> =
            r.samples.iter().map(|s| s.label.as_str()).collect();
        assert!(labels.contains("core"));
        assert!(labels.contains("binding"));
        assert!(labels.len() == 3);
    }

    #[test]
    fn ablations_run() {
        assert!(ablation_join_algo(&[2000], FAST).unwrap().samples.len() == 2);
        assert!(
            ablation_chunk(2000, 2, &[64, 65536], FAST)
                .unwrap()
                .samples
                .len()
                == 2
        );
        assert!(
            ablation_groupby(2000, 2, 50, FAST).unwrap().samples.len() == 2
        );
    }

    #[test]
    fn probe_join_is_nontrivial() {
        let n = probe_join_rows(4000).unwrap();
        assert!(n > 500, "join too small: {n}");
    }
}
