//! Criterion-lite benchmark harness (criterion is not in the offline
//! registry): warmup + N samples, median/mean/p95, paper-style table
//! printer and JSON export. Every `rust/benches/*` target uses this.

use std::time::Instant;

use crate::util::fmt::human_duration;
use crate::util::json::Json;

/// One measured series point.
#[derive(Debug, Clone)]
pub struct Sample {
    pub label: String,
    /// x-axis value (parallelism, rows, …).
    pub x: f64,
    /// seconds per iteration (median unless noted).
    pub seconds: f64,
    /// extra metadata columns (e.g. "speedup", "bytes").
    pub extra: Vec<(String, f64)>,
}

/// Measurement options.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub samples: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup_iters: 1,
            samples: 3,
        }
    }
}

/// Statistics over the collected samples.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
}

/// Run `f` under warmup + sampling, timing each call with the wall
/// clock; the closure may instead return its own metric (e.g. the sim
/// fabric's makespan) — see [`measure_with`].
pub fn measure<F: FnMut()>(opts: BenchOpts, mut f: F) -> Stats {
    measure_with(opts, move || {
        let t = Instant::now();
        f();
        t.elapsed().as_secs_f64()
    })
}

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`). `None` off Linux or when procfs is absent —
/// benches report 0 in that case rather than failing.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 =
                rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Reset the kernel's peak-RSS watermark (write `5` to
/// `/proc/self/clear_refs`), so a bench can attribute a peak to one
/// phase instead of the process lifetime. Returns `false` where
/// unsupported (peaks are then cumulative — still an upper bound).
pub fn reset_peak_rss() -> bool {
    std::fs::write("/proc/self/clear_refs", b"5").is_ok()
}

/// Like [`measure`], but the closure reports its own seconds (used for
/// simulated-makespan benches where wall time is meaningless).
pub fn measure_with<F: FnMut() -> f64>(opts: BenchOpts, mut f: F) -> Stats {
    for _ in 0..opts.warmup_iters {
        let _ = f();
    }
    let mut xs: Vec<f64> = (0..opts.samples.max(1)).map(|_| f()).collect();
    xs.sort_by(|a, b| a.total_cmp(b));
    let n = xs.len();
    Stats {
        median: xs[n / 2],
        mean: xs.iter().sum::<f64>() / n as f64,
        min: xs[0],
        max: xs[n - 1],
    }
}

/// Collects series and renders the paper-style output.
#[derive(Debug, Default)]
pub struct Report {
    pub title: String,
    pub samples: Vec<Sample>,
}

impl Report {
    pub fn new(title: &str) -> Report {
        Report {
            title: title.to_string(),
            samples: Vec::new(),
        }
    }

    pub fn add(&mut self, label: &str, x: f64, seconds: f64) {
        self.samples.push(Sample {
            label: label.to_string(),
            x,
            seconds,
            extra: Vec::new(),
        });
    }

    pub fn add_with(
        &mut self,
        label: &str,
        x: f64,
        seconds: f64,
        extra: Vec<(String, f64)>,
    ) {
        self.samples.push(Sample {
            label: label.to_string(),
            x,
            seconds,
            extra,
        });
    }

    /// Distinct series labels in first-seen order.
    fn labels(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for s in &self.samples {
            if !out.contains(&s.label) {
                out.push(s.label.clone());
            }
        }
        out
    }

    /// Render an aligned grid: rows = x values, columns = series.
    pub fn render(&self) -> String {
        let labels = self.labels();
        let mut xs: Vec<f64> = self.samples.iter().map(|s| s.x).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        xs.dedup();
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&format!("{:>12}", "x"));
        for l in &labels {
            out.push_str(&format!("  {l:>14}"));
        }
        out.push('\n');
        for &x in &xs {
            out.push_str(&format!("{x:>12}"));
            for l in &labels {
                let v = self
                    .samples
                    .iter()
                    .find(|s| s.x == x && &s.label == l)
                    .map(|s| s.seconds);
                match v {
                    Some(v) => out.push_str(&format!(
                        "  {:>14}",
                        human_duration(std::time::Duration::from_secs_f64(
                            v.max(0.0)
                        ))
                    )),
                    None => out.push_str(&format!("  {:>14}", "-")),
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "samples",
                Json::Arr(
                    self.samples
                        .iter()
                        .map(|s| {
                            let mut pairs = vec![
                                ("label", Json::str(s.label.clone())),
                                ("x", Json::num(s.x)),
                                ("seconds", Json::num(s.seconds)),
                            ];
                            for (k, v) in &s.extra {
                                pairs.push((k.as_str(), Json::num(*v)));
                            }
                            Json::obj(pairs)
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write the JSON next to the text output (under `bench_out/`).
    pub fn save(&self, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("bench_out")?;
        std::fs::write(
            format!("bench_out/{name}.json"),
            self.to_json().to_string(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_sane_stats() {
        let stats = measure(
            BenchOpts {
                warmup_iters: 1,
                samples: 5,
            },
            || {
                std::hint::black_box((0..10_000).sum::<u64>());
            },
        );
        assert!(stats.min <= stats.median && stats.median <= stats.max);
        assert!(stats.mean > 0.0);
    }

    #[test]
    fn measure_with_custom_metric() {
        let mut i = 0.0;
        let stats = measure_with(
            BenchOpts {
                warmup_iters: 0,
                samples: 3,
            },
            || {
                i += 1.0;
                i
            },
        );
        assert_eq!(stats.median, 2.0);
        assert_eq!(stats.max, 3.0);
    }

    #[test]
    fn report_renders_grid_and_json() {
        let mut r = Report::new("fig-test");
        r.add("rylon", 1.0, 0.5);
        r.add("spark", 1.0, 1.0);
        r.add("rylon", 2.0, 0.25);
        let text = r.render();
        assert!(text.contains("fig-test"));
        assert!(text.contains("rylon"));
        assert!(text.contains("spark"));
        let j = r.to_json().to_string();
        assert!(j.contains("\"seconds\""));
    }
}
pub mod figures;
pub mod recipe;
