//! Recipe-driven bench runner: YAML-subset recipes describe a dataset
//! shape (rows, payload columns, key distribution), a scan scenario,
//! and a thread/world/selectivity matrix; the runner generates the
//! dataset, writes it as both encoded (`RYF2`) and raw (`RYF1`) files,
//! and times the pushed-down scan over each. Every case cross-checks
//! the encoded result against the raw-format oracle bit-identically
//! and errors on any divergence, so `rylon bench run-all` doubles as a
//! correctness gate (the CI bench-recipe smoke leg). One summary JSON
//! per recipe lands under `bench/results/`.
//!
//! The recipe grammar is a deliberately tiny YAML subset — `key:
//! value` lines, `#` comments, and inline `[a, b, c]` lists; no
//! nesting — because the offline registry has no YAML crate.

use std::path::Path;

use crate::dist::{Cluster, DistConfig};
use crate::error::{Result, RylonError};
use crate::exec::ScanCounters;
use crate::io::datagen::{gen_table, DataGenSpec, KeyDist};
use crate::io::ryf::write_ryf;
use crate::pipeline::{Env, Pipeline};
use crate::table::Table;
use crate::util::json::Json;

use super::{measure, BenchOpts};

/// One parsed bench recipe.
#[derive(Debug, Clone)]
pub struct Recipe {
    /// Recipe name (also the summary file stem).
    pub name: String,
    /// Fact-table rows.
    pub rows: usize,
    /// f64 payload columns beside the `id` key.
    pub payload_cols: usize,
    /// Rows per RYF row group.
    pub group_rows: usize,
    /// Key distribution: `seq`, `uniform`, or `zipf`.
    pub dist: String,
    /// `scan` (predicate only) or `scan_project` (predicate plus a
    /// projection to `id`, exercising column pruning).
    pub scenario: String,
    /// Predicate selectivities to sweep, each in `(0, 1]`.
    pub selectivities: Vec<f64>,
    /// Per-rank morsel worker counts to sweep.
    pub threads: Vec<usize>,
    /// World sizes (rank counts) to sweep.
    pub worlds: Vec<usize>,
    /// Datagen seed.
    pub seed: u64,
}

fn parse_usize(v: &str, lineno: usize) -> Result<usize> {
    v.parse().map_err(|_| {
        RylonError::parse(format!(
            "recipe line {lineno}: bad integer {v:?}"
        ))
    })
}

fn parse_f64(v: &str, lineno: usize) -> Result<f64> {
    v.parse().map_err(|_| {
        RylonError::parse(format!("recipe line {lineno}: bad number {v:?}"))
    })
}

/// Parse an inline `[a, b, c]` list with the given element parser.
fn parse_list<T>(
    v: &str,
    lineno: usize,
    elem: impl Fn(&str, usize) -> Result<T>,
) -> Result<Vec<T>> {
    let inner = v
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| {
            RylonError::parse(format!(
                "recipe line {lineno}: expected [a, b, …], got {v:?}"
            ))
        })?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(elem(part, lineno)?);
    }
    if out.is_empty() {
        return Err(RylonError::parse(format!(
            "recipe line {lineno}: empty list"
        )));
    }
    Ok(out)
}

impl Recipe {
    /// Parse recipe text. Unknown keys are errors (fail closed), so a
    /// typo'd knob can't silently fall back to a default.
    pub fn parse(text: &str) -> Result<Recipe> {
        let mut r = Recipe {
            name: String::new(),
            rows: 0,
            payload_cols: 2,
            group_rows: 4096,
            dist: "seq".to_string(),
            scenario: "scan".to_string(),
            selectivities: vec![0.01, 1.0],
            threads: vec![1],
            worlds: vec![1],
            seed: 42,
        };
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = match raw.find('#') {
                Some(h) => &raw[..h],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once(':').ok_or_else(|| {
                RylonError::parse(format!(
                    "recipe line {lineno}: expected key: value"
                ))
            })?;
            let (k, v) = (k.trim(), v.trim());
            match k {
                "name" => r.name = v.to_string(),
                "rows" => r.rows = parse_usize(v, lineno)?,
                "payload_cols" => {
                    r.payload_cols = parse_usize(v, lineno)?
                }
                "group_rows" => r.group_rows = parse_usize(v, lineno)?,
                "seed" => r.seed = parse_usize(v, lineno)? as u64,
                "dist" => r.dist = v.to_string(),
                "scenario" => r.scenario = v.to_string(),
                "selectivities" => {
                    r.selectivities = parse_list(v, lineno, parse_f64)?
                }
                "threads" => {
                    r.threads = parse_list(v, lineno, parse_usize)?
                }
                "worlds" => {
                    r.worlds = parse_list(v, lineno, parse_usize)?
                }
                other => {
                    return Err(RylonError::parse(format!(
                        "recipe line {lineno}: unknown key '{other}'"
                    )))
                }
            }
        }
        r.validate()?;
        Ok(r)
    }

    fn validate(&self) -> Result<()> {
        let bad = |msg: String| Err(RylonError::invalid(msg));
        if self.name.is_empty()
            || !self
                .name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || "_-".contains(c))
        {
            return bad(format!(
                "recipe needs a [A-Za-z0-9_-] name, got '{}'",
                self.name
            ));
        }
        if self.rows == 0 || self.group_rows == 0 {
            return bad(format!(
                "recipe {}: rows and group_rows must be ≥ 1",
                self.name
            ));
        }
        if !matches!(self.dist.as_str(), "seq" | "uniform" | "zipf") {
            return bad(format!(
                "recipe {}: dist '{}' (seq|uniform|zipf)",
                self.name, self.dist
            ));
        }
        if !matches!(self.scenario.as_str(), "scan" | "scan_project") {
            return bad(format!(
                "recipe {}: scenario '{}' (scan|scan_project)",
                self.name, self.scenario
            ));
        }
        if self
            .selectivities
            .iter()
            .any(|&s| !(s > 0.0 && s <= 1.0))
        {
            return bad(format!(
                "recipe {}: selectivities must be in (0, 1]",
                self.name
            ));
        }
        if self.worlds.iter().chain(&self.threads).any(|&n| n == 0) {
            return bad(format!(
                "recipe {}: worlds and threads must be ≥ 1",
                self.name
            ));
        }
        Ok(())
    }

    fn key_dist(&self) -> KeyDist {
        let domain = (self.rows as u64 * 2).max(1);
        match self.dist.as_str() {
            "uniform" => KeyDist::Uniform { domain },
            "zipf" => KeyDist::Zipf { domain, s: 1.1 },
            _ => KeyDist::Sequential,
        }
    }

    /// Upper end of the `id` key domain (exclusive), used to turn a
    /// selectivity into an `id < cutoff` predicate.
    fn key_domain(&self) -> u64 {
        match self.dist.as_str() {
            "seq" => self.rows as u64,
            _ => (self.rows as u64 * 2).max(1),
        }
    }

    fn pipeline(&self, selectivity: f64) -> Result<Pipeline> {
        let cutoff = ((self.key_domain() as f64 * selectivity).ceil()
            as u64)
            .max(1);
        let p = Pipeline::new().select(&format!("id < {cutoff}"))?;
        Ok(match self.scenario.as_str() {
            "scan_project" => p.project(&["id"]),
            _ => p,
        })
    }
}

/// One (world, threads, selectivity) measurement.
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// Rank count.
    pub world: usize,
    /// Morsel workers per rank.
    pub threads: usize,
    /// Swept predicate selectivity.
    pub selectivity: f64,
    /// Median seconds over the encoded (`RYF2`) file.
    pub seconds_encoded: f64,
    /// Median seconds over the raw (`RYF1`) oracle file.
    pub seconds_raw: f64,
    /// Rows surviving the scan + predicate (identical either way).
    pub rows_out: u64,
    /// Scan-pushdown counters from one encoded run.
    pub counters: ScanCounters,
}

/// A recipe's measured matrix, renderable and saveable as JSON.
#[derive(Debug, Clone)]
pub struct RecipeSummary {
    /// The recipe's name.
    pub name: String,
    /// The recipe's fact-table rows.
    pub rows: usize,
    /// Scenario the cases ran.
    pub scenario: String,
    /// One entry per matrix point.
    pub cases: Vec<CaseResult>,
}

impl RecipeSummary {
    /// Aligned text table for the CLI.
    pub fn render(&self) -> String {
        let mut out = format!(
            "== recipe {} ({} rows, {}) ==\n\
             {:>6} {:>4} {:>7} {:>10} {:>10} {:>8} {:>14}\n",
            self.name,
            self.rows,
            self.scenario,
            "world",
            "thr",
            "sel",
            "enc(s)",
            "raw(s)",
            "speedup",
            "skipped/total",
        );
        for c in &self.cases {
            let speedup = if c.seconds_encoded > 0.0 {
                c.seconds_raw / c.seconds_encoded
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:>6} {:>4} {:>7.3} {:>10.6} {:>10.6} {:>7.2}x \
                 {:>7}/{}\n",
                c.world,
                c.threads,
                c.selectivity,
                c.seconds_encoded,
                c.seconds_raw,
                speedup,
                c.counters.groups_skipped,
                c.counters.groups_total,
            ));
        }
        out
    }

    /// The summary as JSON (what `save` writes).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("recipe", Json::str(self.name.clone())),
            ("rows", Json::num(self.rows as f64)),
            ("scenario", Json::str(self.scenario.clone())),
            (
                "cases",
                Json::Arr(
                    self.cases
                        .iter()
                        .map(|c| {
                            let speedup = if c.seconds_encoded > 0.0 {
                                c.seconds_raw / c.seconds_encoded
                            } else {
                                0.0
                            };
                            Json::obj(vec![
                                ("world", Json::num(c.world as f64)),
                                ("threads", Json::num(c.threads as f64)),
                                ("selectivity", Json::num(c.selectivity)),
                                (
                                    "seconds_encoded",
                                    Json::num(c.seconds_encoded),
                                ),
                                ("seconds_raw", Json::num(c.seconds_raw)),
                                (
                                    "speedup_encoded_vs_raw",
                                    Json::num(speedup),
                                ),
                                ("rows_out", Json::num(c.rows_out as f64)),
                                (
                                    "groups_total",
                                    Json::num(
                                        c.counters.groups_total as f64,
                                    ),
                                ),
                                (
                                    "groups_skipped",
                                    Json::num(
                                        c.counters.groups_skipped as f64,
                                    ),
                                ),
                                (
                                    "decoded_bytes",
                                    Json::num(
                                        c.counters.decoded_bytes as f64,
                                    ),
                                ),
                                (
                                    "decoded_bytes_avoided",
                                    Json::num(
                                        c.counters.decoded_bytes_avoided
                                            as f64,
                                    ),
                                ),
                                (
                                    "pruned_columns",
                                    Json::num(
                                        c.counters.pruned_columns as f64,
                                    ),
                                ),
                                (
                                    "bit_identical",
                                    // Divergence errors the run, so a
                                    // written summary always passed.
                                    Json::Bool(true),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `<dir>/<name>.json`.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        std::fs::create_dir_all(&dir)?;
        std::fs::write(
            dir.as_ref().join(format!("{}.json", self.name)),
            self.to_json().to_string(),
        )?;
        Ok(())
    }
}

fn counters_delta(after: &ScanCounters, before: &ScanCounters) -> ScanCounters {
    ScanCounters {
        groups_total: after.groups_total - before.groups_total,
        groups_skipped: after.groups_skipped - before.groups_skipped,
        decoded_bytes: after.decoded_bytes - before.decoded_bytes,
        decoded_bytes_avoided: after.decoded_bytes_avoided
            - before.decoded_bytes_avoided,
        pruned_columns: after.pruned_columns - before.pruned_columns,
    }
}

/// One full distributed scan of `path` through `pipe`, gathered in
/// rank order.
fn run_scan(
    cluster: &Cluster,
    pipe: &Pipeline,
    path: &Path,
) -> Result<Vec<Table>> {
    cluster.run(|ctx| {
        let (out, _) = pipe.run_ryf_dist(ctx, path, &Env::new())?;
        Ok(out)
    })
}

/// Run one recipe: generate the dataset, write the encoded and raw
/// files, and measure every (world, threads, selectivity) point —
/// erroring if any encoded result diverges from the raw oracle.
pub fn run_recipe(recipe: &Recipe, samples: usize) -> Result<RecipeSummary> {
    let table = gen_table(&DataGenSpec {
        rows: recipe.rows,
        payload_cols: recipe.payload_cols,
        key_dist: recipe.key_dist(),
        seed: recipe.seed,
    })?;
    let tmp = std::env::temp_dir();
    let pid = std::process::id();
    let enc = tmp.join(format!("rylon_recipe_{}_{pid}_enc.ryf", recipe.name));
    let raw = tmp.join(format!("rylon_recipe_{}_{pid}_raw.ryf", recipe.name));
    crate::exec::with_ryf_encoding(true, || {
        write_ryf(&table, &enc, recipe.group_rows)
    })?;
    crate::exec::with_ryf_encoding(false, || {
        write_ryf(&table, &raw, recipe.group_rows)
    })?;
    drop(table);
    let result = run_cases(recipe, samples, &enc, &raw);
    std::fs::remove_file(&enc).ok();
    std::fs::remove_file(&raw).ok();
    result
}

fn run_cases(
    recipe: &Recipe,
    samples: usize,
    enc: &Path,
    raw: &Path,
) -> Result<RecipeSummary> {
    let opts = BenchOpts {
        // The oracle cross-check below already warmed both files.
        warmup_iters: 0,
        samples: samples.max(1),
    };
    let mut cases = Vec::new();
    for &world in &recipe.worlds {
        for &threads in &recipe.threads {
            let cluster = Cluster::new(
                DistConfig::threads(world)
                    .with_intra_op_threads(threads),
            )?;
            for &sel in &recipe.selectivities {
                let pipe = recipe.pipeline(sel)?;
                // Correctness gate: the encoded scan must reproduce
                // the raw oracle bit-identically, rank by rank.
                let before = cluster.scan_stats();
                let enc_out = run_scan(&cluster, &pipe, enc)?;
                let counters =
                    counters_delta(&cluster.scan_stats(), &before);
                let raw_out = run_scan(&cluster, &pipe, raw)?;
                if enc_out != raw_out {
                    return Err(RylonError::invalid(format!(
                        "recipe {}: encoded scan diverged from the raw \
                         oracle at world={world} threads={threads} \
                         selectivity={sel}",
                        recipe.name
                    )));
                }
                let rows_out: u64 =
                    enc_out.iter().map(|t| t.num_rows() as u64).sum();
                drop(enc_out);
                drop(raw_out);
                // `measure` can't propagate a Result out of its
                // closure; park the first error and rethrow after.
                let mut err: Option<RylonError> = None;
                let enc_stats = measure(opts, || {
                    if err.is_some() {
                        return;
                    }
                    if let Err(e) = run_scan(&cluster, &pipe, enc) {
                        err = Some(e);
                    }
                });
                let raw_stats = measure(opts, || {
                    if err.is_some() {
                        return;
                    }
                    if let Err(e) = run_scan(&cluster, &pipe, raw) {
                        err = Some(e);
                    }
                });
                if let Some(e) = err {
                    return Err(e);
                }
                cases.push(CaseResult {
                    world,
                    threads,
                    selectivity: sel,
                    seconds_encoded: enc_stats.median,
                    seconds_raw: raw_stats.median,
                    rows_out,
                    counters,
                });
            }
        }
    }
    Ok(RecipeSummary {
        name: recipe.name.clone(),
        rows: recipe.rows,
        scenario: recipe.scenario.clone(),
        cases,
    })
}

/// Run every `*.yaml`/`*.yml` recipe in `recipes_dir` (or just the one
/// whose file stem is `only`), writing one summary JSON per recipe
/// under `out_dir`. Recipes run in file-name order.
pub fn run_all(
    recipes_dir: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    samples: usize,
    only: Option<&str>,
) -> Result<Vec<RecipeSummary>> {
    let dir = recipes_dir.as_ref();
    let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            matches!(
                p.extension().and_then(|e| e.to_str()),
                Some("yaml") | Some("yml")
            )
        })
        .collect();
    paths.sort();
    let mut summaries = Vec::new();
    for path in &paths {
        if let Some(name) = only {
            let stem = path.file_stem().and_then(|s| s.to_str());
            if stem != Some(name) {
                continue;
            }
        }
        let recipe = Recipe::parse(&std::fs::read_to_string(path)?)?;
        let summary = run_recipe(&recipe, samples)?;
        summary.save(&out_dir)?;
        summaries.push(summary);
    }
    if summaries.is_empty() {
        return Err(RylonError::invalid(match only {
            Some(name) => {
                format!("recipe '{name}' not found in {}", dir.display())
            }
            None => format!("no recipes found in {}", dir.display()),
        }));
    }
    Ok(summaries)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# tiny sweep
name: unit_scan
rows: 400
payload_cols: 1
group_rows: 50
dist: seq
scenario: scan
selectivities: [0.5, 1.0]
threads: [1]
worlds: [1, 2]
";

    #[test]
    fn parse_recipe_and_defaults() {
        let r = Recipe::parse(SAMPLE).unwrap();
        assert_eq!(r.name, "unit_scan");
        assert_eq!(r.rows, 400);
        assert_eq!(r.group_rows, 50);
        assert_eq!(r.selectivities, vec![0.5, 1.0]);
        assert_eq!(r.worlds, vec![1, 2]);
        assert_eq!(r.seed, 42, "untouched keys keep defaults");
        assert_eq!(r.scenario, "scan");
    }

    #[test]
    fn parse_rejects_bad_recipes() {
        // Unknown key fails closed.
        assert!(Recipe::parse("name: a\nrows: 10\ntypo: 1").is_err());
        // Missing name / rows.
        assert!(Recipe::parse("rows: 10").is_err());
        assert!(Recipe::parse("name: a").is_err());
        // Out-of-range selectivity, bad scenario, bad dist, bad list.
        assert!(Recipe::parse(
            "name: a\nrows: 10\nselectivities: [0.0]"
        )
        .is_err());
        assert!(Recipe::parse("name: a\nrows: 10\nscenario: x").is_err());
        assert!(Recipe::parse("name: a\nrows: 10\ndist: x").is_err());
        assert!(Recipe::parse("name: a\nrows: 10\nworlds: 3").is_err());
        assert!(Recipe::parse("name: a\nrows: 10\nworlds: [0]").is_err());
    }

    #[test]
    fn recipe_runs_prune_and_match_oracle() {
        let mut r = Recipe::parse(SAMPLE).unwrap();
        r.name = "unit_scan_run".to_string();
        let summary = run_recipe(&r, 1).unwrap();
        assert_eq!(summary.cases.len(), 4, "2 worlds × 1 thread × 2 sel");
        for c in &summary.cases {
            assert_eq!(c.counters.groups_total, 8);
            if c.selectivity < 1.0 {
                // seq keys + id < 200 ⇒ half the groups zone-map out.
                assert_eq!(c.counters.groups_skipped, 4);
                assert_eq!(c.rows_out, 200);
            } else {
                assert_eq!(c.counters.groups_skipped, 0);
                assert_eq!(c.rows_out, 400);
            }
        }
        let text = summary.render();
        assert!(text.contains("unit_scan_run"));
        let json = summary.to_json().to_string();
        let back = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(
            back.get("recipe").unwrap().as_str().unwrap(),
            "unit_scan_run"
        );
        assert_eq!(
            back.get("cases").unwrap().as_arr().unwrap().len(),
            4
        );
    }

    #[test]
    fn run_all_reads_dir_and_writes_summaries() {
        let dir = std::env::temp_dir().join(format!(
            "rylon_recipes_{}",
            std::process::id()
        ));
        let out = dir.join("results");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("a_unit.yaml"),
            SAMPLE.replace("unit_scan", "a_unit"),
        )
        .unwrap();
        let summaries = run_all(&dir, &out, 1, None).unwrap();
        assert_eq!(summaries.len(), 1);
        assert!(out.join("a_unit.json").is_file());
        // Filter by name; unknown names error.
        assert!(run_all(&dir, &out, 1, Some("a_unit")).is_ok());
        assert!(run_all(&dir, &out, 1, Some("nope")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
