//! Lightweight metrics: scoped timers, counters, and per-phase
//! compute/comm breakdowns emitted as JSON by the CLI and benches.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Wall-clock scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn seconds(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulating phase breakdown (e.g. partition / shuffle / local-op).
#[derive(Debug, Default, Clone)]
pub struct Phases {
    phases: BTreeMap<String, f64>,
    counters: BTreeMap<String, u64>,
}

impl Phases {
    pub fn new() -> Phases {
        Phases::default()
    }

    /// Time a closure under a named phase.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add_seconds(phase, t.seconds());
        out
    }

    pub fn add_seconds(&mut self, phase: &str, secs: f64) {
        *self.phases.entry(phase.to_string()).or_insert(0.0) += secs;
    }

    pub fn count(&mut self, counter: &str, n: u64) {
        *self.counters.entry(counter.to_string()).or_insert(0) += n;
    }

    pub fn seconds(&self, phase: &str) -> f64 {
        self.phases.get(phase).copied().unwrap_or(0.0)
    }

    pub fn counter(&self, counter: &str) -> u64 {
        self.counters.get(counter).copied().unwrap_or(0)
    }

    pub fn total_seconds(&self) -> f64 {
        self.phases.values().sum()
    }

    /// Merge another breakdown (e.g. fold per-rank phases).
    pub fn merge(&mut self, other: &Phases) {
        for (k, v) in &other.phases {
            self.add_seconds(k, *v);
        }
        for (k, v) in &other.counters {
            self.count(k, *v);
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        for (k, v) in &self.phases {
            pairs.push((k.as_str(), Json::num(*v)));
        }
        for (k, v) in &self.counters {
            pairs.push((k.as_str(), Json::num(*v as f64)));
        }
        Json::obj(pairs)
    }
}

/// Fault-domain counters (`docs/FAULTS.md`): how many collectives the
/// cluster aborted and how many faults the injection plan fired.
/// Snapshot via `Cluster::fault_stats`; counters are cumulative for
/// the cluster's lifetime (clearing a fault does not reset them).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FaultStats {
    /// Collectives aborted out-of-band: rank aborts (errors/panics
    /// delivered to parked peers), collective timeouts, rendezvous
    /// corruption.
    pub aborted_collectives: u64,
    /// Faults fired by the configured `[exec] fault_plan` (0 when no
    /// plan is active).
    pub injected_faults: u64,
}

impl FaultStats {
    /// Fold these counters into a [`Phases`] breakdown (the JSON the
    /// CLI and benches emit).
    pub fn record(&self, phases: &mut Phases) {
        phases.count("aborted_collectives", self.aborted_collectives);
        phases.count("injected_faults", self.injected_faults);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "aborted_collectives",
                Json::num(self.aborted_collectives as f64),
            ),
            ("injected_faults", Json::num(self.injected_faults as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.seconds() >= 0.004);
    }

    #[test]
    fn phases_accumulate_and_merge() {
        let mut p = Phases::new();
        let out = p.time("sort", || 42);
        assert_eq!(out, 42);
        p.add_seconds("sort", 1.0);
        p.add_seconds("shuffle", 0.5);
        p.count("bytes", 100);
        let mut q = Phases::new();
        q.add_seconds("sort", 2.0);
        q.count("bytes", 20);
        p.merge(&q);
        assert!(p.seconds("sort") >= 3.0);
        assert_eq!(p.counter("bytes"), 120);
        assert!(p.total_seconds() >= 3.5);
        let j = p.to_json().to_string();
        assert!(j.contains("shuffle"));
        assert!(j.contains("bytes"));
    }

    #[test]
    fn fault_stats_fold_and_serialize() {
        let s = FaultStats {
            aborted_collectives: 2,
            injected_faults: 1,
        };
        let mut p = Phases::new();
        s.record(&mut p);
        assert_eq!(p.counter("aborted_collectives"), 2);
        assert_eq!(p.counter("injected_faults"), 1);
        let j = s.to_json().to_string();
        assert!(j.contains("aborted_collectives"));
        assert!(j.contains("injected_faults"));
    }
}
