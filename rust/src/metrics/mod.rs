//! Lightweight metrics: scoped timers, counters, and per-phase
//! compute/comm breakdowns emitted as JSON by the CLI and benches.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Wall-clock scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn seconds(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulating phase breakdown (e.g. partition / shuffle / local-op).
#[derive(Debug, Default, Clone)]
pub struct Phases {
    phases: BTreeMap<String, f64>,
    counters: BTreeMap<String, u64>,
}

impl Phases {
    pub fn new() -> Phases {
        Phases::default()
    }

    /// Time a closure under a named phase.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add_seconds(phase, t.seconds());
        out
    }

    pub fn add_seconds(&mut self, phase: &str, secs: f64) {
        *self.phases.entry(phase.to_string()).or_insert(0.0) += secs;
    }

    pub fn count(&mut self, counter: &str, n: u64) {
        *self.counters.entry(counter.to_string()).or_insert(0) += n;
    }

    pub fn seconds(&self, phase: &str) -> f64 {
        self.phases.get(phase).copied().unwrap_or(0.0)
    }

    pub fn counter(&self, counter: &str) -> u64 {
        self.counters.get(counter).copied().unwrap_or(0)
    }

    pub fn total_seconds(&self) -> f64 {
        self.phases.values().sum()
    }

    /// Merge another breakdown (e.g. fold per-rank phases).
    pub fn merge(&mut self, other: &Phases) {
        for (k, v) in &other.phases {
            self.add_seconds(k, *v);
        }
        for (k, v) in &other.counters {
            self.count(k, *v);
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        for (k, v) in &self.phases {
            pairs.push((k.as_str(), Json::num(*v)));
        }
        for (k, v) in &self.counters {
            pairs.push((k.as_str(), Json::num(*v as f64)));
        }
        Json::obj(pairs)
    }
}

/// Per-stage attribution for a fused pipeline segment
/// (`docs/PIPELINE.md`): when select→project→join-probe→partial-agg
/// run as one pass per morsel, each worker charges the seconds and
/// output rows of every fused stage to that stage's slot, and the
/// per-morsel clocks fold back into the segment clock in morsel order.
/// `commit` then books the totals into a [`Phases`] breakdown under
/// the same phase names the operator-at-a-time path uses, so fusion
/// never loses the per-stage timing surface.
#[derive(Debug, Clone)]
pub struct StageClock {
    names: Vec<String>,
    secs: Vec<f64>,
    rows: Vec<u64>,
}

impl StageClock {
    /// One slot per fused stage, labelled with the stage's phase name
    /// (names may repeat, e.g. two selects in one segment).
    pub fn new(names: Vec<String>) -> StageClock {
        let n = names.len();
        StageClock {
            names,
            secs: vec![0.0; n],
            rows: vec![0; n],
        }
    }

    /// Number of stage slots.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// Whether the clock has no stage slots.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Charge wall-clock seconds to stage slot `stage`.
    pub fn add_seconds(&mut self, stage: usize, secs: f64) {
        self.secs[stage] += secs;
    }

    /// Charge output rows to stage slot `stage`.
    pub fn add_rows(&mut self, stage: usize, n: u64) {
        self.rows[stage] += n;
    }

    /// Fold another clock's charges into this one slot-by-slot (the
    /// per-morsel clocks folding into the segment clock). Slot counts
    /// must match; fold order does not change the row totals, and the
    /// second totals are only reported, never compared bit-for-bit.
    pub fn absorb(&mut self, other: &StageClock) {
        debug_assert_eq!(self.names.len(), other.names.len());
        for (s, o) in self.secs.iter_mut().zip(&other.secs) {
            *s += o;
        }
        for (r, o) in self.rows.iter_mut().zip(&other.rows) {
            *r += o;
        }
    }

    /// Book the totals into a [`Phases`] breakdown: each slot's seconds
    /// under its phase name, and every slot's rows under the shared
    /// `rows_out` counter — the same accounting the operator-at-a-time
    /// path produces one stage at a time.
    pub fn commit(self, phases: &mut Phases) {
        for ((name, secs), rows) in
            self.names.iter().zip(&self.secs).zip(&self.rows)
        {
            phases.add_seconds(name, *secs);
            phases.count("rows_out", *rows);
        }
    }
}

/// Fault-domain counters (`docs/FAULTS.md`): how many collectives the
/// cluster aborted and how many faults the injection plan fired.
/// Snapshot via `Cluster::fault_stats`; counters are cumulative for
/// the cluster's lifetime (clearing a fault does not reset them).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FaultStats {
    /// Collectives aborted out-of-band: rank aborts (errors/panics
    /// delivered to parked peers), collective timeouts, rendezvous
    /// corruption.
    pub aborted_collectives: u64,
    /// Faults fired by the configured `[exec] fault_plan` (0 when no
    /// plan is active).
    pub injected_faults: u64,
}

impl FaultStats {
    /// Fold these counters into a [`Phases`] breakdown (the JSON the
    /// CLI and benches emit).
    pub fn record(&self, phases: &mut Phases) {
        phases.count("aborted_collectives", self.aborted_collectives);
        phases.count("injected_faults", self.injected_faults);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "aborted_collectives",
                Json::num(self.aborted_collectives as f64),
            ),
            ("injected_faults", Json::num(self.injected_faults as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.seconds() >= 0.004);
    }

    #[test]
    fn phases_accumulate_and_merge() {
        let mut p = Phases::new();
        let out = p.time("sort", || 42);
        assert_eq!(out, 42);
        p.add_seconds("sort", 1.0);
        p.add_seconds("shuffle", 0.5);
        p.count("bytes", 100);
        let mut q = Phases::new();
        q.add_seconds("sort", 2.0);
        q.count("bytes", 20);
        p.merge(&q);
        assert!(p.seconds("sort") >= 3.0);
        assert_eq!(p.counter("bytes"), 120);
        assert!(p.total_seconds() >= 3.5);
        let j = p.to_json().to_string();
        assert!(j.contains("shuffle"));
        assert!(j.contains("bytes"));
    }

    #[test]
    fn stage_clock_absorbs_and_commits() {
        let mut seg =
            StageClock::new(vec!["select".into(), "join".into(), "select".into()]);
        assert_eq!(seg.len(), 3);
        assert!(!seg.is_empty());
        let mut morsel = StageClock::new(vec![
            "select".into(),
            "join".into(),
            "select".into(),
        ]);
        morsel.add_seconds(0, 0.25);
        morsel.add_rows(0, 10);
        morsel.add_seconds(1, 1.0);
        morsel.add_rows(1, 30);
        morsel.add_seconds(2, 0.5);
        morsel.add_rows(2, 7);
        seg.absorb(&morsel);
        seg.absorb(&morsel);
        let mut p = Phases::new();
        seg.commit(&mut p);
        // The two select slots pool under one phase name.
        assert!((p.seconds("select") - 1.5).abs() < 1e-12);
        assert!((p.seconds("join") - 2.0).abs() < 1e-12);
        assert_eq!(p.counter("rows_out"), 2 * (10 + 30 + 7));
    }

    #[test]
    fn fault_stats_fold_and_serialize() {
        let s = FaultStats {
            aborted_collectives: 2,
            injected_faults: 1,
        };
        let mut p = Phases::new();
        s.record(&mut p);
        assert_eq!(p.counter("aborted_collectives"), 2);
        assert_eq!(p.counter("injected_faults"), 1);
        let j = s.to_json().to_string();
        assert!(j.contains("aborted_collectives"));
        assert!(j.contains("injected_faults"));
    }
}
