//! Deterministic PRNGs: SplitMix64 (seeding / hashing sibling) and
//! xoshiro256** (bulk generation for the synthetic data generators).
//!
//! Both follow the published reference implementations (Steele et al.
//! 2014; Blackman & Vigna 2018) so streams are stable across releases —
//! the benchmark workloads in EXPERIMENTS.md are reproducible bit-for-bit.

/// SplitMix64 generator (also used to seed [`Xoshiro256`]).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Xoshiro256 {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift; slight modulo
    /// bias is irrelevant for workload generation but we reject anyway).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Widening multiply keeps this branch-light on the hot path.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (paired draws discarded for
    /// simplicity; datagen is not a hot path).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` via rejection
    /// inversion (Hörmann & Derflinger) — used for skewed join keys.
    pub fn next_zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.next_below(n);
        }
        // Simple inversion on the harmonic CDF for modest n; cached
        // normalisation would be faster but datagen is off the hot path.
        let hn: f64 = 1.0 - (n as f64).powf(1.0 - s);
        loop {
            let u = self.next_f64();
            let x = ((1.0 - u * hn).powf(1.0 / (1.0 - s))).floor();
            if x >= 1.0 && x <= n as f64 {
                return x as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_reference_vector() {
        // First outputs for seed 0 — published reference values; the L1
        // Pallas kernel's finalizer must agree (see python tests).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn xoshiro_deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut r = Xoshiro256::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Xoshiro256::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Xoshiro256::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Xoshiro256::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = Xoshiro256::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Xoshiro256::new(11);
        let n = 1000u64;
        let mut counts = vec![0usize; n as usize];
        for _ in 0..50_000 {
            let v = r.next_zipf(n, 1.1);
            assert!(v < n);
            counts[v as usize] += 1;
        }
        // Rank 0 must dominate the tail decisively.
        assert!(counts[0] > 20 * counts[500].max(1));
    }

    #[test]
    fn zipf_zero_exponent_uniform() {
        let mut r = Xoshiro256::new(5);
        for _ in 0..1000 {
            assert!(r.next_zipf(10, 0.0) < 10);
        }
    }
}
