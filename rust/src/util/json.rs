//! Minimal JSON: a writer for metrics/bench output plus a small
//! recursive-descent parser (enough to read the artifact manifest emitted
//! by `python/compile/aot.py`). `serde_json` is not available offline.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are sorted (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Render to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- accessors used by the manifest reader --------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            s.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            map.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let j = Json::obj(vec![
            ("name", Json::str("fig10")),
            ("rows", Json::num(2_000_000.0)),
            ("ok", Json::Bool(true)),
            ("series", Json::Arr(vec![Json::num(1.5), Json::num(2.0)])),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
            "format": "hlo-text",
            "artifacts": [
                {"name": "hash_partition_n16384_p4", "n": 16384,
                 "nparts": 4, "inputs": [{"dtype": "u64", "shape": [16384]}]}
            ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "hlo-text");
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("n").unwrap().as_usize().unwrap(), 16384);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\c\ndA");
        let s = Json::str("x\"y\nz").to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str().unwrap(), "x\"y\nz");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
    }
}
