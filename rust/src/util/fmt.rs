//! Human-readable formatting for metrics and bench output.

use std::time::Duration;

/// `1536` → `"1.5 KiB"`, etc. Binary units, one decimal.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if bytes < 1024 {
        return format!("{bytes} B");
    }
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.1} {}", UNITS[u])
}

/// Duration in the most natural unit: ns / µs / ms / s.
pub fn human_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Thousands separators for row counts: `1234567` → `"1,234,567"`.
pub fn human_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(human_bytes(17), "17 B");
        assert_eq!(human_bytes(1536), "1.5 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
        assert_eq!(human_bytes(5 * 1024 * 1024 * 1024), "5.0 GiB");
    }

    #[test]
    fn duration_units() {
        assert_eq!(human_duration(Duration::from_nanos(500)), "500 ns");
        assert_eq!(human_duration(Duration::from_micros(1500)), "1.50 ms");
        assert_eq!(human_duration(Duration::from_secs(2)), "2.000 s");
    }

    #[test]
    fn counts() {
        assert_eq!(human_count(0), "0");
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(1000), "1,000");
        assert_eq!(human_count(1_234_567), "1,234,567");
    }
}
