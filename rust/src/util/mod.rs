//! Small self-contained utilities (the offline registry has no `rand`,
//! `serde_json` or `humansize`, so these are built in-tree and tested).

pub mod rng;
pub mod fmt;
pub mod json;

pub use fmt::{human_bytes, human_duration};
pub use rng::{SplitMix64, Xoshiro256};
