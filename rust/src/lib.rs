//! # Rylon — HPC data engineering with a distributed table abstraction
//!
//! Rylon is a reproduction of *"Data Engineering for HPC with Python"*
//! (Abeykoon et al., CS.DC 2020 — the Cylon/PyCylon paper) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: an Arrow-like columnar
//!   [`table::Table`], the six relational-algebra operators of the paper's
//!   Table I ([`ops`]), an MPI-like communicator with a non-blocking
//!   AllToAll shuffle ([`net`]), and data-parallel distributed operators
//!   ([`dist`]). Execution is two-level: one thread per rank (paper
//!   §III-B) × a morsel-driven intra-rank worker pool ([`exec`]) that
//!   fans the local kernels out across cores, bit-identically to the
//!   serial path (`DistConfig::intra_op_threads`, 1 = paper behaviour).
//! * **L2/L1 (build time)** — JAX graphs calling Pallas kernels for the
//!   numeric hot-spots (hash-partition, table→tensor featurize), AOT
//!   lowered to HLO text and executed from Rust through PJRT
//!   ([`runtime`]). Python never runs on the data path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use rylon::prelude::*;
//!
//! let left = read_csv("left.csv", &CsvOptions::default()).unwrap();
//! let right = read_csv("right.csv", &CsvOptions::default()).unwrap();
//! let joined = join(&left, &right, &JoinOptions::inner("id", "id")).unwrap();
//! println!("{}", joined.pretty(5));
//! ```
//!
//! Distributed execution mirrors the PyCylon API: the same operator names
//! with a `dist_` prefix, run inside a [`dist::Cluster`] whose ranks talk
//! through a pluggable [`net::Fabric`] (threads + channels for real
//! concurrency, or the calibrated BSP simulator used for the paper's
//! scaling figures — see DESIGN.md §3). Distributed CSV ingest
//! ([`dist::read_csv_partition`]) is single-pass by default: each rank
//! reads only its byte range, once, and rank seams are spliced through
//! a summary exchange.
//!
//! Longer-form docs live in `docs/`: `ARCHITECTURE.md` (the two-level
//! execution model), `CONFIG.md` (every `[exec]` knob), and
//! `INGEST.md` (the streaming + distributed ingest pipeline).

pub mod error;
pub mod util;
pub mod conf;
pub mod types;
pub mod buffer;
pub mod column;
pub mod table;
pub mod io;
pub mod exec;
pub mod compute;
pub mod ops;
pub mod net;
pub mod dist;
pub mod pipeline;
pub mod sql;
pub mod runtime;
pub mod binding;
pub mod baselines;
pub mod metrics;
pub mod bench_harness;

pub use error::{Result, RylonError};

/// Convenience re-exports covering the public API surface.
pub mod prelude {
    pub use crate::column::Column;
    pub use crate::dist::{Cluster, DistConfig};
    pub use crate::error::{Result, RylonError};
    pub use crate::io::csv::{read_csv, write_csv, CsvOptions};
    pub use crate::io::datagen::{gen_table, DataGenSpec};
    pub use crate::ops::groupby::{groupby, Agg, GroupByOptions};
    pub use crate::ops::join::{join, JoinAlgo, JoinOptions, JoinType};
    pub use crate::ops::orderby::{orderby, SortKey, SortOrder};
    pub use crate::ops::project::project;
    pub use crate::ops::select::select;
    pub use crate::ops::set_ops::{difference, intersect, union};
    pub use crate::table::Table;
    pub use crate::types::{DataType, Field, Schema, Value};
}

/// Crate version string (mirrored into metrics output and the CLI).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
