//! Logical types: [`DataType`], [`Field`], [`Schema`], and the boxed
//! [`Value`] cell used by row-oriented paths (the binding layer and the
//! baseline row engine — the columnar hot path never boxes).

mod schema;
mod value;

pub use schema::{Field, Schema};
pub use value::Value;

/// The physical/logical type of a column. Deliberately the small set the
/// paper's workloads need (Arrow-style: 64-bit ints, doubles, UTF-8,
/// bools); widening the enum is additive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataType {
    Int64,
    Float64,
    Utf8,
    Bool,
}

impl DataType {
    /// Parse from the names used in configs and CSV schema strings.
    pub fn parse(s: &str) -> Option<DataType> {
        match s {
            "i64" | "int64" | "int" => Some(DataType::Int64),
            "f64" | "float64" | "double" | "float" => Some(DataType::Float64),
            "str" | "utf8" | "string" => Some(DataType::Utf8),
            "bool" | "boolean" => Some(DataType::Bool),
            _ => None,
        }
    }

    /// Canonical name (inverse of [`DataType::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            DataType::Int64 => "i64",
            DataType::Float64 => "f64",
            DataType::Utf8 => "str",
            DataType::Bool => "bool",
        }
    }

    /// Fixed width in bytes of the value buffer element, if fixed-width.
    pub fn fixed_width(&self) -> Option<usize> {
        match self {
            DataType::Int64 => Some(8),
            DataType::Float64 => Some(8),
            DataType::Bool => Some(1),
            DataType::Utf8 => None,
        }
    }

    /// Whether the type supports ordering comparisons (all current types do;
    /// kept explicit so adding e.g. a binary blob type stays honest).
    pub fn is_orderable(&self) -> bool {
        true
    }

    pub fn is_numeric(&self) -> bool {
        matches!(self, DataType::Int64 | DataType::Float64)
    }
}

impl std::fmt::Display for DataType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for dt in [
            DataType::Int64,
            DataType::Float64,
            DataType::Utf8,
            DataType::Bool,
        ] {
            assert_eq!(DataType::parse(dt.name()), Some(dt));
        }
        assert_eq!(DataType::parse("int"), Some(DataType::Int64));
        assert_eq!(DataType::parse("double"), Some(DataType::Float64));
        assert_eq!(DataType::parse("nope"), None);
    }

    #[test]
    fn widths() {
        assert_eq!(DataType::Int64.fixed_width(), Some(8));
        assert_eq!(DataType::Utf8.fixed_width(), None);
        assert!(DataType::Int64.is_numeric());
        assert!(!DataType::Utf8.is_numeric());
    }
}
