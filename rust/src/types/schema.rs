//! [`Field`] (name + type + nullability) and [`Schema`] (ordered fields
//! with O(1) name lookup).

use std::collections::HashMap;
use std::sync::Arc;

use crate::error::{Result, RylonError};
use crate::types::DataType;

/// One column's metadata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Field {
    pub name: String,
    pub dtype: DataType,
    pub nullable: bool,
}

impl Field {
    pub fn new(name: impl Into<String>, dtype: DataType) -> Field {
        Field {
            name: name.into(),
            dtype,
            nullable: true,
        }
    }

    pub fn required(name: impl Into<String>, dtype: DataType) -> Field {
        Field {
            name: name.into(),
            dtype,
            nullable: false,
        }
    }
}

/// An ordered list of fields. Cheap to clone (used on every table op);
/// the name index is behind an `Arc` and rebuilt only on construction.
#[derive(Debug, Clone)]
pub struct Schema {
    fields: Arc<Vec<Field>>,
    index: Arc<HashMap<String, usize>>,
}

impl PartialEq for Schema {
    fn eq(&self, other: &Self) -> bool {
        self.fields == other.fields
    }
}
impl Eq for Schema {}

impl Schema {
    pub fn new(fields: Vec<Field>) -> Schema {
        let index = fields
            .iter()
            .enumerate()
            .map(|(i, f)| (f.name.clone(), i))
            .collect();
        Schema {
            fields: Arc::new(fields),
            index: Arc::new(index),
        }
    }

    /// Parse `"id:i64,price:f64,name:str"` — the CLI/config schema syntax.
    pub fn parse(spec: &str) -> Result<Schema> {
        let mut fields = Vec::new();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (name, ty) = part.split_once(':').ok_or_else(|| {
                RylonError::parse(format!("bad field spec '{part}' (want name:type)"))
            })?;
            let dtype = DataType::parse(ty.trim()).ok_or_else(|| {
                RylonError::parse(format!("unknown type '{ty}' in '{part}'"))
            })?;
            fields.push(Field::new(name.trim(), dtype));
        }
        if fields.is_empty() {
            return Err(RylonError::parse("empty schema spec"));
        }
        Ok(Schema::new(fields))
    }

    pub fn fields(&self) -> &[Field] {
        &self.fields
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn field(&self, i: usize) -> &Field {
        &self.fields[i]
    }

    /// Index of a column by name.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.index
            .get(name)
            .copied()
            .ok_or_else(|| RylonError::ColumnNotFound(name.to_string()))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    /// Schema equality up to column names (for set operators: the paper's
    /// union/intersect/difference require equal arity and types, §Table I).
    pub fn types_match(&self, other: &Schema) -> bool {
        self.len() == other.len()
            && self
                .fields
                .iter()
                .zip(other.fields.iter())
                .all(|(a, b)| a.dtype == b.dtype)
    }

    /// New schema with a subset of columns (project).
    pub fn project(&self, indices: &[usize]) -> Schema {
        Schema::new(indices.iter().map(|&i| self.fields[i].clone()).collect())
    }

    /// Concatenate two schemas, disambiguating duplicate names with a
    /// suffix (join output convention, mirroring Cylon's `_right`).
    pub fn join(&self, right: &Schema, suffix: &str) -> Schema {
        let mut fields: Vec<Field> = self.fields.as_ref().clone();
        for f in right.fields.iter() {
            let name = if self.contains(&f.name) {
                format!("{}{}", f.name, suffix)
            } else {
                f.name.clone()
            };
            fields.push(Field {
                name,
                dtype: f.dtype,
                nullable: f.nullable,
            });
        }
        Schema::new(fields)
    }
}

impl std::fmt::Display for Schema {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, fld) in self.fields.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{}:{}", fld.name, fld.dtype)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_lookup() {
        let s = Schema::parse("id:i64, price:f64,name:str,ok:bool").unwrap();
        assert_eq!(s.len(), 4);
        assert_eq!(s.index_of("price").unwrap(), 1);
        assert_eq!(s.field(2).dtype, DataType::Utf8);
        assert!(s.index_of("missing").is_err());
        assert!(Schema::parse("").is_err());
        assert!(Schema::parse("id").is_err());
        assert!(Schema::parse("id:what").is_err());
    }

    #[test]
    fn types_match_ignores_names() {
        let a = Schema::parse("x:i64,y:f64").unwrap();
        let b = Schema::parse("p:i64,q:f64").unwrap();
        let c = Schema::parse("p:i64,q:str").unwrap();
        assert!(a.types_match(&b));
        assert!(!a.types_match(&c));
    }

    #[test]
    fn join_suffixes_duplicates() {
        let a = Schema::parse("id:i64,v:f64").unwrap();
        let b = Schema::parse("id:i64,w:f64").unwrap();
        let j = a.join(&b, "_r");
        assert_eq!(
            j.fields().iter().map(|f| f.name.as_str()).collect::<Vec<_>>(),
            vec!["id", "v", "id_r", "w"]
        );
    }

    #[test]
    fn project_keeps_order() {
        let s = Schema::parse("a:i64,b:f64,c:str").unwrap();
        let p = s.project(&[2, 0]);
        assert_eq!(p.field(0).name, "c");
        assert_eq!(p.field(1).name, "a");
    }
}
