//! [`Value`] — a boxed cell. Used only off the hot path: the dynamic
//! binding layer (Fig 12 arm b), the baseline row engine (the executed
//! stand-in for Python-level kernels), row debugging and pretty-printing.
//! The columnar operators never materialise `Value`s.

use std::cmp::Ordering;

use crate::types::DataType;

/// One dynamically-typed cell.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Int64(i64),
    Float64(f64),
    Utf8(String),
    Bool(bool),
}

impl Value {
    pub fn dtype(&self) -> Option<DataType> {
        match self {
            Value::Null => None,
            Value::Int64(_) => Some(DataType::Int64),
            Value::Float64(_) => Some(DataType::Float64),
            Value::Utf8(_) => Some(DataType::Utf8),
            Value::Bool(_) => Some(DataType::Bool),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int64(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float64(v) => Some(*v),
            Value::Int64(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Utf8(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Total order used by the row engine's sort: nulls first, then by
    /// type-specific order; f64 uses `total_cmp` (NaN greatest).
    pub fn total_cmp(&self, other: &Value) -> Ordering {
        use Value::*;
        match (self, other) {
            (Null, Null) => Ordering::Equal,
            (Null, _) => Ordering::Less,
            (_, Null) => Ordering::Greater,
            (Int64(a), Int64(b)) => a.cmp(b),
            (Float64(a), Float64(b)) => a.total_cmp(b),
            (Int64(a), Float64(b)) => (*a as f64).total_cmp(b),
            (Float64(a), Int64(b)) => a.total_cmp(&(*b as f64)),
            (Utf8(a), Utf8(b)) => a.cmp(b),
            (Bool(a), Bool(b)) => a.cmp(b),
            // Cross-type: order by a fixed type rank so sorts are total.
            (a, b) => type_rank(a).cmp(&type_rank(b)),
        }
    }

    /// Render for CSV output / pretty printing (empty string for null —
    /// the CSV writer's null convention).
    pub fn render(&self) -> String {
        match self {
            Value::Null => String::new(),
            Value::Int64(v) => v.to_string(),
            Value::Float64(v) => format_f64(*v),
            Value::Utf8(s) => s.clone(),
            Value::Bool(b) => b.to_string(),
        }
    }
}

fn type_rank(v: &Value) -> u8 {
    match v {
        Value::Null => 0,
        Value::Bool(_) => 1,
        Value::Int64(_) => 2,
        Value::Float64(_) => 3,
        Value::Utf8(_) => 4,
    }
}

/// Shortest round-trip-safe float rendering (Rust's `{}` is already
/// shortest-repr; this just pins the behaviour behind a name).
pub fn format_f64(v: f64) -> String {
    format!("{v}")
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int64(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Float64(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Utf8(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Utf8(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_and_coercion() {
        assert_eq!(Value::Int64(3).as_f64(), Some(3.0));
        assert_eq!(Value::Float64(2.5).as_i64(), None);
        assert_eq!(Value::from("hi").as_str(), Some("hi"));
        assert!(Value::Null.is_null());
        assert_eq!(Value::Null.dtype(), None);
    }

    #[test]
    fn ordering_nulls_first() {
        let mut vs = vec![
            Value::Int64(2),
            Value::Null,
            Value::Int64(-1),
            Value::Null,
        ];
        vs.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(
            vs,
            vec![Value::Null, Value::Null, Value::Int64(-1), Value::Int64(2)]
        );
    }

    #[test]
    fn float_total_order_handles_nan() {
        let a = Value::Float64(f64::NAN);
        let b = Value::Float64(1.0);
        assert_eq!(a.total_cmp(&b), Ordering::Greater);
        assert_eq!(a.total_cmp(&a), Ordering::Equal);
    }

    #[test]
    fn render_roundtrip() {
        assert_eq!(Value::Int64(-7).render(), "-7");
        assert_eq!(Value::Float64(1.5).render(), "1.5");
        assert_eq!(Value::Null.render(), "");
        assert_eq!(Value::Bool(true).render(), "true");
    }

    #[test]
    fn mixed_numeric_compare() {
        assert_eq!(
            Value::Int64(2).total_cmp(&Value::Float64(2.5)),
            Ordering::Less
        );
        assert_eq!(
            Value::Float64(3.0).total_cmp(&Value::Int64(3)),
            Ordering::Equal
        );
    }
}
