//! Configuration: a TOML-subset parser (flat `[section]`s with string /
//! number / bool values — the offline registry has no `toml` crate) and
//! the typed [`RylonConfig`] the CLI and launcher consume.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Result, RylonError};
use crate::net::CostModel;

/// One parsed scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl ConfValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            ConfValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ConfValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            ConfValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: `section.key` → value (top-level keys use section "").
#[derive(Debug, Default, Clone)]
pub struct ConfFile {
    values: BTreeMap<String, ConfValue>,
}

impl ConfFile {
    /// Parse TOML-subset text: comments (`#`), `[section]`, `key = value`
    /// with quoted strings, numbers, booleans.
    pub fn parse(text: &str) -> Result<ConfFile> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                // Only strip comments outside quotes (cheap check: no
                // quote after the hash).
                Some(i) if !raw[..i].contains('"') => &raw[..i],
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line
                .strip_prefix('[')
                .and_then(|s| s.strip_suffix(']'))
            {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                RylonError::parse(format!(
                    "config line {}: expected key = value",
                    lineno + 1
                ))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, Self::parse_value(v.trim(), lineno + 1)?);
        }
        Ok(ConfFile { values })
    }

    fn parse_value(s: &str, lineno: usize) -> Result<ConfValue> {
        if let Some(q) = s
            .strip_prefix('"')
            .and_then(|x| x.strip_suffix('"'))
        {
            return Ok(ConfValue::Str(q.to_string()));
        }
        match s {
            "true" => return Ok(ConfValue::Bool(true)),
            "false" => return Ok(ConfValue::Bool(false)),
            _ => {}
        }
        s.parse::<f64>().map(ConfValue::Num).map_err(|_| {
            RylonError::parse(format!(
                "config line {lineno}: bad value {s:?} (quote strings)"
            ))
        })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<ConfFile> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&ConfValue> {
        self.values.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

/// Tri-state boolean knob: absent key = `None` (defer to the process
/// default); bools and numeric 0/1 both accepted.
fn opt_bool(f: &ConfFile, key: &str) -> Option<bool> {
    f.get(key).and_then(|v| match v {
        ConfValue::Bool(b) => Some(*b),
        ConfValue::Num(n) => Some(*n != 0.0),
        ConfValue::Str(_) => None,
    })
}

/// Typed top-level configuration for the `rylon` launcher.
#[derive(Debug, Clone)]
pub struct RylonConfig {
    /// World size (ranks).
    pub world: usize,
    /// `"threads"`, `"sim"`, or `"tcp"` (`[cluster] fabric`; default
    /// [`crate::exec::FABRIC`], overridable via the `RYLON_FABRIC` env
    /// var). `"tcp"` runs one OS process per rank, meeting at
    /// [`RylonConfig::rendezvous`] (`docs/NET.md`).
    pub fabric: String,
    /// TCP rendezvous address, `host:port` (`[cluster] rendezvous`;
    /// default [`crate::exec::RENDEZVOUS`], overridable via the
    /// `RYLON_RENDEZVOUS` env var). Rank 0 listens there; every other
    /// rank dials it. Ignored by the in-process fabrics.
    pub rendezvous: String,
    pub shuffle_chunk_rows: usize,
    /// Morsel workers per rank for the local compute kernels
    /// (`[exec] intra_op_threads`). `0` = auto: available cores /
    /// world, so rank threads × workers never oversubscribe. `1` =
    /// single-threaded ranks (the paper's §III-B model).
    pub intra_op_threads: usize,
    /// Rows below which kernels keep the serial path
    /// (`[exec] par_row_threshold`) — lower it to force the parallel
    /// paths on small inputs (benches/tests).
    pub par_row_threshold: usize,
    /// Streaming-ingest chunk size in bytes
    /// (`[exec] ingest_chunk_bytes`). `0` = the process default
    /// ([`crate::exec::INGEST_CHUNK_BYTES`], overridable via the
    /// `INGEST_CHUNK_BYTES` env var). The streaming CSV readers (and
    /// the two-pass distributed fallback) hold O(chunk) raw text; the
    /// single-pass distributed scheme holds each rank's own byte range
    /// instead.
    pub ingest_chunk_bytes: usize,
    /// Single-pass distributed CSV ingest
    /// (`[exec] ingest_single_pass`). `None` (key absent) = the
    /// process default ([`crate::exec::INGEST_SINGLE_PASS`],
    /// overridable via the `INGEST_SINGLE_PASS` env var); `false`
    /// forces the two-pass count-then-parse fallback.
    pub ingest_single_pass: Option<bool>,
    /// Cross-rank work stealing (`[exec] work_steal`). `None` (key
    /// absent) = the process default ([`crate::exec::WORK_STEAL`],
    /// overridable via the `WORK_STEAL` env var); `false` keeps the
    /// isolated per-rank worker pools.
    pub work_steal: Option<bool>,
    /// Fused pipeline execution (`[exec] pipeline_fuse`). `None` (key
    /// absent) = the process default ([`crate::exec::PIPELINE_FUSE`],
    /// overridable via the `PIPELINE_FUSE` env var); `false` forces
    /// the operator-at-a-time executor (the CI oracle) that
    /// materializes a full `Table` between every pipeline stage.
    pub pipeline_fuse: Option<bool>,
    /// Encoded RYF row groups (`[exec] ryf_encoding`). `None` (key
    /// absent) = the process default ([`crate::exec::RYF_ENCODING`],
    /// overridable via the `RYF_ENCODING` env var); `false` makes
    /// [`crate::io::ryf::RyfWriter`] emit the raw RYF1 format (the CI
    /// oracle) instead of encoded RYF2 groups with zone maps.
    pub ryf_encoding: Option<bool>,
    /// Deterministic fault-injection plan (`[exec] fault_plan`;
    /// grammar in [`crate::net::faulty::FaultPlan`], e.g.
    /// `"error@1:2, panic@0:0"`). `None` (key absent) = the process
    /// default (empty unless the `FAULT_PLAN` env var is set); `""`
    /// explicitly disables injection.
    pub fault_plan: Option<String>,
    /// Collective timeout in milliseconds
    /// (`[exec] collective_timeout_ms`). `None` (key absent) = the
    /// process default (0 unless the `COLLECTIVE_TIMEOUT_MS` env var
    /// is set); `0` explicitly disables the timeout.
    pub collective_timeout_ms: Option<u64>,
    /// Per-rank memory budget in bytes for the spilling operators
    /// (`[exec] memory_budget_bytes`). `0` = the process default
    /// ([`crate::exec::MEMORY_BUDGET_BYTES`], overridable via the
    /// `MEMORY_BUDGET_BYTES` env var), which is itself unbounded by
    /// default: join/sort/groupby keep today's in-memory paths.
    pub memory_budget_bytes: usize,
    pub cost: CostModel,
    /// Directory holding AOT artifacts + manifest.json.
    pub artifacts_dir: String,
}

impl Default for RylonConfig {
    fn default() -> Self {
        RylonConfig {
            world: 4,
            fabric: crate::exec::default_fabric().to_string(),
            rendezvous: crate::exec::default_rendezvous().to_string(),
            shuffle_chunk_rows: 1 << 16,
            intra_op_threads: 0,
            par_row_threshold: crate::exec::PAR_ROW_THRESHOLD,
            ingest_chunk_bytes: 0,
            ingest_single_pass: None,
            work_steal: None,
            pipeline_fuse: None,
            ryf_encoding: None,
            fault_plan: None,
            collective_timeout_ms: None,
            memory_budget_bytes: 0,
            cost: CostModel::default(),
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl RylonConfig {
    /// Read from a parsed file; missing keys keep defaults.
    pub fn from_file(f: &ConfFile) -> RylonConfig {
        let d = RylonConfig::default();
        let dc = CostModel::default();
        RylonConfig {
            world: f.usize_or("cluster.world", d.world),
            fabric: f.str_or("cluster.fabric", &d.fabric),
            rendezvous: f.str_or("cluster.rendezvous", &d.rendezvous),
            shuffle_chunk_rows: f
                .usize_or("shuffle.chunk_rows", d.shuffle_chunk_rows),
            intra_op_threads: f
                .usize_or("exec.intra_op_threads", d.intra_op_threads),
            par_row_threshold: f
                .usize_or("exec.par_row_threshold", d.par_row_threshold),
            ingest_chunk_bytes: f
                .usize_or("exec.ingest_chunk_bytes", d.ingest_chunk_bytes),
            // Accept 0/1 as well as true/false — every neighbouring
            // [exec] knob is numeric, and the env vars take 0/1 too.
            ingest_single_pass: opt_bool(f, "exec.ingest_single_pass"),
            work_steal: opt_bool(f, "exec.work_steal"),
            pipeline_fuse: opt_bool(f, "exec.pipeline_fuse"),
            ryf_encoding: opt_bool(f, "exec.ryf_encoding"),
            fault_plan: f
                .get("exec.fault_plan")
                .and_then(|v| v.as_str())
                .map(String::from),
            collective_timeout_ms: f
                .get("exec.collective_timeout_ms")
                .and_then(|v| v.as_f64())
                .map(|n| n as u64),
            memory_budget_bytes: f
                .usize_or("exec.memory_budget_bytes", d.memory_budget_bytes),
            cost: CostModel {
                alpha: f.f64_or("cost.alpha", dc.alpha),
                beta: f.f64_or("cost.beta", dc.beta),
                ranks_per_node: f
                    .usize_or("cost.ranks_per_node", dc.ranks_per_node),
                beta_local: f.f64_or("cost.beta_local", dc.beta_local),
            },
            artifacts_dir: f.str_or("runtime.artifacts_dir", &d.artifacts_dir),
        }
    }

    pub fn load(path: impl AsRef<Path>) -> Result<RylonConfig> {
        Ok(Self::from_file(&ConfFile::load(path)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# rylon config
[cluster]
world = 16
fabric = "sim"

[shuffle]
chunk_rows = 4096

[exec]
intra_op_threads = 2
par_row_threshold = 512
ingest_chunk_bytes = 65536
ingest_single_pass = false
work_steal = false
pipeline_fuse = false
ryf_encoding = false
fault_plan = "error@1:2"
collective_timeout_ms = 30000
memory_budget_bytes = 1048576

[cost]
alpha = 1e-5
ranks_per_node = 8
"#;

    #[test]
    fn parse_sections_and_types() {
        let f = ConfFile::parse(SAMPLE).unwrap();
        assert_eq!(f.get("cluster.world").unwrap().as_usize(), Some(16));
        assert_eq!(
            f.get("cluster.fabric").unwrap().as_str(),
            Some("sim")
        );
        assert_eq!(f.get("cost.alpha").unwrap().as_f64(), Some(1e-5));
        assert!(f.get("nope").is_none());
    }

    #[test]
    fn typed_config_with_defaults() {
        let c =
            RylonConfig::from_file(&ConfFile::parse(SAMPLE).unwrap());
        assert_eq!(c.world, 16);
        assert_eq!(c.fabric, "sim");
        assert_eq!(c.shuffle_chunk_rows, 4096);
        assert_eq!(c.intra_op_threads, 2);
        assert_eq!(c.par_row_threshold, 512);
        assert_eq!(c.ingest_chunk_bytes, 65536);
        assert_eq!(c.ingest_single_pass, Some(false));
        assert_eq!(c.work_steal, Some(false));
        assert_eq!(c.pipeline_fuse, Some(false));
        assert_eq!(c.ryf_encoding, Some(false));
        assert_eq!(c.fault_plan.as_deref(), Some("error@1:2"));
        assert_eq!(c.collective_timeout_ms, Some(30000));
        assert_eq!(c.memory_budget_bytes, 1 << 20);
        // Keys absent = defer to the process defaults.
        let empty = RylonConfig::from_file(&ConfFile::parse("").unwrap());
        assert_eq!(empty.ingest_single_pass, None);
        assert_eq!(empty.work_steal, None);
        assert_eq!(empty.pipeline_fuse, None);
        assert_eq!(empty.ryf_encoding, None);
        assert_eq!(empty.fault_plan, None);
        assert_eq!(empty.collective_timeout_ms, None);
        assert_eq!(empty.memory_budget_bytes, 0);
        // Numeric 0/1 spellings work like the env vars'.
        let num = ConfFile::parse(
            "[exec]\ningest_single_pass = 1\nwork_steal = 1\n\
             pipeline_fuse = 0\nryf_encoding = 1",
        )
        .unwrap();
        let num = RylonConfig::from_file(&num);
        assert_eq!(num.ingest_single_pass, Some(true));
        assert_eq!(num.work_steal, Some(true));
        assert_eq!(num.pipeline_fuse, Some(false));
        assert_eq!(num.ryf_encoding, Some(true));
        assert_eq!(c.cost.alpha, 1e-5);
        assert_eq!(c.cost.ranks_per_node, 8);
        // Untouched keys keep defaults.
        assert_eq!(c.artifacts_dir, "artifacts");
        assert_eq!(c.cost.beta, CostModel::default().beta);
        assert_eq!(c.rendezvous, crate::exec::default_rendezvous());
    }

    #[test]
    fn tcp_fabric_keys() {
        let f = ConfFile::parse(
            "[cluster]\nfabric = \"tcp\"\n\
             rendezvous = \"10.0.0.7:4040\"",
        )
        .unwrap();
        let c = RylonConfig::from_file(&f);
        assert_eq!(c.fabric, "tcp");
        assert_eq!(c.rendezvous, "10.0.0.7:4040");
    }

    #[test]
    fn bad_lines_error() {
        assert!(ConfFile::parse("just words").is_err());
        assert!(ConfFile::parse("k = unquoted_string").is_err());
    }

    #[test]
    fn bools_and_comments() {
        let f =
            ConfFile::parse("flag = true # trailing\nother = false").unwrap();
        assert_eq!(f.bool_or("flag", false), true);
        assert_eq!(f.bool_or("other", true), false);
        assert_eq!(f.bool_or("missing", true), true);
    }
}
