//! RYF — "rylon file", a minimal columnar container (the role Parquet
//! plays in the paper's future-work list, §VIII: "we will be integrating
//! HDF5 and Parquet data loading"). Row-grouped so distributed readers
//! can fetch disjoint groups per rank without touching the rest of the
//! file:
//!
//! ```text
//! "RYF1" | u32 n_groups
//! group 0 bytes (net::wire format) | group 1 bytes | …
//! footer: n_groups × (u64 offset, u64 len, u64 rows) | u64 footer_off
//! ```
//!
//! Both directions stream: [`RyfWriter`] appends row groups
//! incrementally (the CSV→RYF conversion never holds the whole
//! table), and readers fetch groups independently — whole-file
//! ([`read_ryf`]), per-rank ([`read_ryf_partition`]), or one group at
//! a time ([`read_ryf_group`], which the CLI's RYF→CSV conversion
//! walks so the egress side is bounded-memory too).

#![warn(missing_docs)]

use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::error::{Result, RylonError};
use crate::exec;
use crate::net::wire::{deserialize_table, serialize_table};
use crate::table::Table;

const MAGIC: &[u8; 4] = b"RYF1";

/// One row group's footer entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupMeta {
    /// Byte offset of the group's serialized table in the file.
    pub offset: u64,
    /// Serialized length in bytes.
    pub len: u64,
    /// Row count of the group.
    pub rows: u64,
}

/// Incremental RYF writer: append row groups one at a time, then
/// `finish()` to write the footer (the group count in the header is
/// back-patched). Lets a bounded-memory producer — e.g. the streaming
/// CSV reader's chunk tables — convert to RYF without ever holding the
/// whole table.
pub struct RyfWriter {
    f: std::fs::File,
    metas: Vec<GroupMeta>,
    offset: u64,
}

impl RyfWriter {
    /// Create the file and write the (to-be-patched) header.
    pub fn create(path: impl AsRef<Path>) -> Result<RyfWriter> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(MAGIC)?;
        // Placeholder group count, patched in `finish`.
        f.write_all(&0u32.to_le_bytes())?;
        Ok(RyfWriter {
            f,
            metas: Vec::new(),
            offset: (MAGIC.len() + 4) as u64,
        })
    }

    /// Append one table as one row group (the caller controls group
    /// sizing by how it slices).
    pub fn append(&mut self, group: &Table) -> Result<()> {
        let bytes = serialize_table(group);
        self.f.write_all(&bytes)?;
        self.metas.push(GroupMeta {
            offset: self.offset,
            len: bytes.len() as u64,
            rows: group.num_rows() as u64,
        });
        self.offset += bytes.len() as u64;
        Ok(())
    }

    /// Row groups appended so far.
    pub fn groups(&self) -> usize {
        self.metas.len()
    }

    /// Write the footer, patch the header's group count, and flush.
    /// Returns the group count. At least one group must have been
    /// appended (append an empty table for a schema-only file).
    pub fn finish(mut self) -> Result<usize> {
        if self.metas.is_empty() {
            return Err(RylonError::invalid(
                "ryf: no groups appended (append an empty table for a \
                 schema-only file)",
            ));
        }
        let footer_off = self.offset;
        for m in &self.metas {
            self.f.write_all(&m.offset.to_le_bytes())?;
            self.f.write_all(&m.len.to_le_bytes())?;
            self.f.write_all(&m.rows.to_le_bytes())?;
        }
        self.f.write_all(&footer_off.to_le_bytes())?;
        self.f.seek(SeekFrom::Start(MAGIC.len() as u64))?;
        self.f
            .write_all(&(self.metas.len() as u32).to_le_bytes())?;
        self.f.flush()?;
        Ok(self.metas.len())
    }
}

/// Write `table` as an RYF file with row groups of `group_rows` rows.
pub fn write_ryf(
    table: &Table,
    path: impl AsRef<Path>,
    group_rows: usize,
) -> Result<()> {
    if group_rows == 0 {
        return Err(RylonError::invalid("group_rows must be >= 1"));
    }
    let n_groups = if table.num_rows() == 0 {
        1
    } else {
        table.num_rows().div_ceil(group_rows)
    };
    let mut w = RyfWriter::create(path)?;
    for g in 0..n_groups {
        w.append(&table.slice(g * group_rows, group_rows))?;
    }
    w.finish()?;
    Ok(())
}

/// Open an RYF file: returns the group index (footer).
pub fn read_ryf_footer(path: impl AsRef<Path>) -> Result<Vec<GroupMeta>> {
    let mut f = std::fs::File::open(path)?;
    let mut head = [0u8; 8];
    f.read_exact(&mut head).map_err(|_| {
        RylonError::parse("ryf: file too small for header")
    })?;
    if &head[..4] != MAGIC {
        return Err(RylonError::parse("ryf: bad magic"));
    }
    let n_groups = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
    f.seek(SeekFrom::End(-8))?;
    let mut tail = [0u8; 8];
    f.read_exact(&mut tail)?;
    let footer_off = u64::from_le_bytes(tail);
    f.seek(SeekFrom::Start(footer_off))?;
    let mut metas = Vec::with_capacity(n_groups);
    let mut entry = [0u8; 24];
    for _ in 0..n_groups {
        f.read_exact(&mut entry).map_err(|_| {
            RylonError::parse("ryf: truncated footer")
        })?;
        metas.push(GroupMeta {
            offset: u64::from_le_bytes(entry[0..8].try_into().unwrap()),
            len: u64::from_le_bytes(entry[8..16].try_into().unwrap()),
            rows: u64::from_le_bytes(entry[16..24].try_into().unwrap()),
        });
    }
    Ok(metas)
}

/// Read one row group.
pub fn read_ryf_group(
    path: impl AsRef<Path>,
    meta: &GroupMeta,
) -> Result<Table> {
    let mut f = std::fs::File::open(path)?;
    f.seek(SeekFrom::Start(meta.offset))?;
    let mut buf = vec![0u8; meta.len as usize];
    f.read_exact(&mut buf).map_err(|_| {
        RylonError::parse("ryf: truncated row group")
    })?;
    deserialize_table(&buf)
}

/// Fetch and deserialise `metas` row groups under the calling thread's
/// intra-op budget (each worker opens its own file handle; groups come
/// back in `metas` order, so the concatenated result is bit-identical
/// to a serial read at any thread count).
fn read_groups_parallel(
    path: &Path,
    metas: &[GroupMeta],
) -> Result<Vec<Table>> {
    let total_rows: u64 = metas.iter().map(|m| m.rows).sum();
    let exec = exec::parallelism_for(total_rows as usize);
    if !exec.is_parallel() || metas.len() <= 1 {
        return metas.iter().map(|m| read_ryf_group(path, m)).collect();
    }
    let chunks = exec::split_even(metas.len(), exec.threads());
    let parts: Vec<Result<Vec<Table>>> = exec::map_parallel(chunks, |c| {
        metas[c.range()]
            .iter()
            .map(|m| read_ryf_group(path, m))
            .collect()
    });
    let mut out = Vec::with_capacity(metas.len());
    for p in parts {
        out.extend(p?);
    }
    Ok(out)
}

/// Read the whole file (row groups fetched morsel-parallel).
pub fn read_ryf(path: impl AsRef<Path>) -> Result<Table> {
    let metas = read_ryf_footer(&path)?;
    let parts = read_groups_parallel(path.as_ref(), &metas)?;
    let schema = parts
        .first()
        .map(|t| t.schema().clone())
        .ok_or_else(|| RylonError::parse("ryf: no groups"))?;
    Table::concat_all(&schema, &parts)
}

/// Read this rank's share of row groups (block distribution over
/// groups, fetched morsel-parallel) — the distributed ingest path.
pub fn read_ryf_partition(
    path: impl AsRef<Path>,
    rank: usize,
    world: usize,
) -> Result<Table> {
    if world == 0 || rank >= world {
        return Err(RylonError::invalid("bad rank/world"));
    }
    let metas = read_ryf_footer(&path)?;
    let mine: Vec<GroupMeta> = metas
        .iter()
        .enumerate()
        .filter(|(g, _)| g % world == rank)
        .map(|(_, m)| *m)
        .collect();
    let parts = read_groups_parallel(path.as_ref(), &mine)?;
    let schema = match parts.first() {
        Some(t) => t.schema().clone(),
        None => {
            // This rank owns no groups: read the first group only for
            // its schema (an empty result still needs one).
            let first = metas
                .first()
                .ok_or_else(|| RylonError::parse("ryf: empty file"))?;
            read_ryf_group(&path, first)?.schema().clone()
        }
    };
    Table::concat_all(&schema, &parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    fn t(n: usize) -> Table {
        Table::from_columns(vec![
            ("id", Column::from_i64((0..n as i64).collect())),
            (
                "s",
                Column::from_opt_str(
                    &(0..n)
                        .map(|i| {
                            if i % 7 == 0 {
                                None
                            } else {
                                Some(format!("row{i}"))
                            }
                        })
                        .collect::<Vec<_>>(),
                ),
            ),
        ])
        .unwrap()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rylon_ryf_{name}"))
    }

    #[test]
    fn roundtrip_multiple_groups() {
        let path = tmp("rt");
        let table = t(1000);
        write_ryf(&table, &path, 128).unwrap();
        let metas = read_ryf_footer(&path).unwrap();
        assert_eq!(metas.len(), 8); // ceil(1000/128)
        assert_eq!(metas.iter().map(|m| m.rows).sum::<u64>(), 1000);
        let back = read_ryf(&path).unwrap();
        assert_eq!(back, table);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn single_group_and_empty() {
        let path = tmp("small");
        write_ryf(&t(3), &path, 1000).unwrap();
        assert_eq!(read_ryf(&path).unwrap().num_rows(), 3);
        let empty = Table::empty(t(1).schema().clone());
        write_ryf(&empty, &path, 10).unwrap();
        assert_eq!(read_ryf(&path).unwrap().num_rows(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn partitioned_read_covers_all_groups() {
        let path = tmp("part");
        let table = t(500);
        write_ryf(&table, &path, 64).unwrap();
        let world = 3;
        let mut total = 0;
        let mut ids = Vec::new();
        for r in 0..world {
            let p = read_ryf_partition(&path, r, world).unwrap();
            total += p.num_rows();
            ids.extend(p.column(0).i64_values().to_vec());
        }
        assert_eq!(total, 500);
        ids.sort();
        assert_eq!(ids, (0..500).collect::<Vec<_>>());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn group_reads_are_independent() {
        let path = tmp("grp");
        write_ryf(&t(100), &path, 30).unwrap();
        let metas = read_ryf_footer(&path).unwrap();
        let g2 = read_ryf_group(&path, &metas[2]).unwrap();
        assert_eq!(g2.column(0).i64_values()[0], 60);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parallel_read_is_bit_identical() {
        let path = tmp("par");
        let table = t(5000);
        write_ryf(&table, &path, 256).unwrap(); // 20 groups
        let serial =
            crate::exec::with_intra_op_threads(1, || read_ryf(&path).unwrap());
        assert_eq!(serial, table);
        let part_serial = crate::exec::with_intra_op_threads(1, || {
            read_ryf_partition(&path, 1, 3).unwrap()
        });
        for threads in [2, 4, 8] {
            crate::exec::with_intra_op_threads(threads, || {
                crate::exec::with_par_row_threshold(1, || {
                    assert_eq!(
                        read_ryf(&path).unwrap(),
                        serial,
                        "ryf read diverged at {threads} threads"
                    );
                    assert_eq!(
                        read_ryf_partition(&path, 1, 3).unwrap(),
                        part_serial,
                        "ryf partition read diverged at {threads} threads"
                    );
                })
            });
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn incremental_writer_matches_bulk_writer() {
        // Appending slices one group at a time (the streaming-convert
        // path) must produce a file the readers see identically to the
        // bulk writer's.
        let path = tmp("inc");
        let bulk_path = tmp("inc_bulk");
        let table = t(350);
        let mut w = RyfWriter::create(&path).unwrap();
        for g in 0..(350usize.div_ceil(100)) {
            w.append(&table.slice(g * 100, 100)).unwrap();
        }
        assert_eq!(w.groups(), 4);
        assert_eq!(w.finish().unwrap(), 4);
        write_ryf(&table, &bulk_path, 100).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            std::fs::read(&bulk_path).unwrap(),
            "incremental and bulk writers must emit identical bytes"
        );
        assert_eq!(read_ryf(&path).unwrap(), table);
        // Zero appends is an error, not a corrupt file.
        assert!(RyfWriter::create(&path).unwrap().finish().is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&bulk_path).ok();
    }

    #[test]
    fn corruption_detected() {
        let path = tmp("bad");
        write_ryf(&t(10), &path, 5).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_ryf_footer(&path).is_err());
        std::fs::write(&path, b"tiny").unwrap();
        assert!(read_ryf_footer(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_args() {
        let path = tmp("args");
        assert!(write_ryf(&t(5), &path, 0).is_err());
        write_ryf(&t(5), &path, 2).unwrap();
        assert!(read_ryf_partition(&path, 3, 3).is_err());
        std::fs::remove_file(&path).ok();
    }
}
