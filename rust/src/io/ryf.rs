//! RYF — "rylon file", a minimal columnar container (the role Parquet
//! plays in the paper's future-work list, §VIII: "we will be integrating
//! HDF5 and Parquet data loading"). Row-grouped so distributed readers
//! can fetch disjoint groups per rank without touching the rest of the
//! file. Two on-disk formats share the container layout (header, group
//! bytes, footer, trailing footer offset); the `[exec] ryf_encoding`
//! knob picks which one [`RyfWriter`] emits, and every reader accepts
//! both:
//!
//! ```text
//! raw (the bit-identity oracle, RYF_ENCODING=0):
//!   "RYF1" | u32 n_groups
//!   group bytes (net::wire format) …
//!   footer: n_groups × (u64 offset, u64 len, u64 rows) | u64 footer_off
//!
//! encoded (per-group encodings + zone maps, the default):
//!   "RYF2" | u32 n_groups
//!   group bytes (io::encode format) …
//!   footer: u32 ncols | ncols × (u8 dtype | u16 name_len | name)
//!           n_groups × (u64 offset, u64 len, u64 rows)
//!           n_groups × ncols zone-map stats (io::encode layout)
//!   u64 footer_off
//! ```
//!
//! Both directions stream: [`RyfWriter`] appends row groups
//! incrementally (the CSV→RYF conversion never holds the whole
//! table), and readers fetch groups independently — whole-file
//! ([`read_ryf`]), per-rank ([`read_ryf_partition`]), or one group at
//! a time ([`read_ryf_group`], which the CLI's RYF→CSV conversion
//! walks so the egress side is bounded-memory too).
//!
//! [`scan_ryf`] / [`scan_ryf_partition`] are the pushdown-aware entry
//! points: given [`ScanOptions`] carrying a pipeline's leading
//! predicate and live column set, an encoded file's zone maps skip
//! whole groups without decoding them and non-projected column
//! payloads are never gathered (`docs/STORAGE.md`). The pruned result
//! is bit-identical to reading everything and filtering, and the
//! pushdown counters land in [`exec::take_scan_stats`].

#![warn(missing_docs)]

use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

use crate::buffer::Bitmap;
use crate::column::{Column, PrimitiveColumn, StringColumn};
use crate::error::{Result, RylonError};
use crate::exec;
use crate::io::encode::{self, ColumnStats};
use crate::net::wire::{self, deserialize_table, serialize_table, Reader};
use crate::ops::select::Predicate;
use crate::table::Table;
use crate::types::{DataType, Field, Schema};

const MAGIC: &[u8; 4] = b"RYF1";
const MAGIC2: &[u8; 4] = b"RYF2";

/// One row group's footer entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupMeta {
    /// Byte offset of the group's serialized table in the file.
    pub offset: u64,
    /// Serialized length in bytes.
    pub len: u64,
    /// Row count of the group.
    pub rows: u64,
}

/// Incremental RYF writer: append row groups one at a time, then
/// `finish()` to write the footer (the group count in the header is
/// back-patched). Lets a bounded-memory producer — e.g. the streaming
/// CSV reader's chunk tables — convert to RYF without ever holding the
/// whole table. The file format (raw `RYF1` vs encoded `RYF2`) is
/// fixed at `create` time from the calling thread's
/// [`exec::ryf_encoding`] setting; the encoded writer additionally
/// accumulates per-group zone-map statistics for the footer.
pub struct RyfWriter {
    f: std::fs::File,
    metas: Vec<GroupMeta>,
    offset: u64,
    encoded: bool,
    schema: Option<Schema>,
    stats: Vec<Vec<ColumnStats>>,
}

impl RyfWriter {
    /// Create the file and write the (to-be-patched) header.
    pub fn create(path: impl AsRef<Path>) -> Result<RyfWriter> {
        let encoded = exec::ryf_encoding();
        let mut f = std::fs::File::create(path)?;
        f.write_all(if encoded { MAGIC2 } else { MAGIC })?;
        // Placeholder group count, patched in `finish`.
        f.write_all(&0u32.to_le_bytes())?;
        Ok(RyfWriter {
            f,
            metas: Vec::new(),
            offset: (MAGIC.len() + 4) as u64,
            encoded,
            schema: None,
            stats: Vec::new(),
        })
    }

    /// Append one table as one row group (the caller controls group
    /// sizing by how it slices). In encoded mode every group must
    /// share the first group's schema — the footer stores it once.
    pub fn append(&mut self, group: &Table) -> Result<()> {
        let bytes = if self.encoded {
            match &self.schema {
                None => self.schema = Some(group.schema().clone()),
                Some(s) => {
                    if s != group.schema() {
                        return Err(RylonError::schema(
                            "ryf: appended group schema differs from \
                             the first group's",
                        ));
                    }
                }
            }
            self.stats.push(
                (0..group.num_columns())
                    .map(|i| encode::column_stats(group.column(i)))
                    .collect(),
            );
            encode::encode_group(group)
        } else {
            serialize_table(group)
        };
        self.f.write_all(&bytes)?;
        self.metas.push(GroupMeta {
            offset: self.offset,
            len: bytes.len() as u64,
            rows: group.num_rows() as u64,
        });
        self.offset += bytes.len() as u64;
        Ok(())
    }

    /// Row groups appended so far.
    pub fn groups(&self) -> usize {
        self.metas.len()
    }

    /// Write the footer, patch the header's group count, and flush.
    /// Returns the group count. At least one group must have been
    /// appended (append an empty table for a schema-only file).
    pub fn finish(mut self) -> Result<usize> {
        if self.metas.is_empty() {
            return Err(RylonError::invalid(
                "ryf: no groups appended (append an empty table for a \
                 schema-only file)",
            ));
        }
        let footer_off = self.offset;
        let mut foot: Vec<u8> = Vec::new();
        if self.encoded {
            let schema =
                self.schema.as_ref().expect("groups imply a schema");
            foot.extend_from_slice(
                &(schema.len() as u32).to_le_bytes(),
            );
            for f in schema.fields() {
                foot.push(wire::dtype_tag(f.dtype));
                foot.extend_from_slice(
                    &(f.name.len() as u16).to_le_bytes(),
                );
                foot.extend_from_slice(f.name.as_bytes());
            }
        }
        for m in &self.metas {
            foot.extend_from_slice(&m.offset.to_le_bytes());
            foot.extend_from_slice(&m.len.to_le_bytes());
            foot.extend_from_slice(&m.rows.to_le_bytes());
        }
        if self.encoded {
            let schema =
                self.schema.as_ref().expect("groups imply a schema");
            for gstats in &self.stats {
                for (f, s) in schema.fields().iter().zip(gstats) {
                    encode::write_stats(&mut foot, f.dtype, s);
                }
            }
        }
        self.f.write_all(&foot)?;
        self.f.write_all(&footer_off.to_le_bytes())?;
        self.f.seek(SeekFrom::Start(MAGIC.len() as u64))?;
        self.f
            .write_all(&(self.metas.len() as u32).to_le_bytes())?;
        self.f.flush()?;
        Ok(self.metas.len())
    }
}

/// Write `table` as an RYF file with row groups of `group_rows` rows.
pub fn write_ryf(
    table: &Table,
    path: impl AsRef<Path>,
    group_rows: usize,
) -> Result<()> {
    if group_rows == 0 {
        return Err(RylonError::invalid("group_rows must be >= 1"));
    }
    let n_groups = if table.num_rows() == 0 {
        1
    } else {
        table.num_rows().div_ceil(group_rows)
    };
    let mut w = RyfWriter::create(path)?;
    for g in 0..n_groups {
        w.append(&table.slice(g * group_rows, group_rows))?;
    }
    w.finish()?;
    Ok(())
}

/// Everything a scan learns from an RYF footer without touching group
/// bytes: the group index and — for encoded files — the schema and
/// per-group zone-map statistics that drive pruning.
#[derive(Debug, Clone)]
pub struct RyfIndex {
    /// `true` for the encoded `RYF2` format.
    pub encoded: bool,
    /// One entry per row group, in file order.
    pub metas: Vec<GroupMeta>,
    /// The file schema (encoded files only; raw files reveal it by
    /// decoding a group).
    pub schema: Option<Schema>,
    /// `stats[g][c]` = zone map of column `c` in group `g` (encoded
    /// files only).
    pub stats: Vec<Vec<ColumnStats>>,
}

fn read_metas(r: &mut Reader, n: usize) -> Result<Vec<GroupMeta>> {
    r.check_count(n, 24, "ryf footer entries")?;
    let mut metas = Vec::with_capacity(n);
    for _ in 0..n {
        metas.push(GroupMeta {
            offset: r.u64()?,
            len: r.u64()?,
            rows: r.u64()?,
        });
    }
    Ok(metas)
}

/// Open an RYF file and parse its footer into an index. Accepts both
/// formats; fails closed on any structural inconsistency.
pub fn read_ryf_index(path: impl AsRef<Path>) -> Result<RyfIndex> {
    let mut f = std::fs::File::open(path)?;
    let mut head = [0u8; 8];
    f.read_exact(&mut head).map_err(|_| {
        RylonError::parse("ryf: file too small for header")
    })?;
    let encoded = if head[..4] == *MAGIC {
        false
    } else if head[..4] == *MAGIC2 {
        true
    } else {
        return Err(RylonError::parse("ryf: bad magic"));
    };
    let n_groups =
        u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
    let file_len = f.metadata()?.len();
    if file_len < 16 {
        return Err(RylonError::parse("ryf: file too small for footer"));
    }
    f.seek(SeekFrom::End(-8))?;
    let mut tail = [0u8; 8];
    f.read_exact(&mut tail)?;
    let footer_off = u64::from_le_bytes(tail);
    if footer_off < 8 || footer_off > file_len - 8 {
        return Err(RylonError::parse("ryf: bad footer offset"));
    }
    let mut foot = vec![0u8; (file_len - 8 - footer_off) as usize];
    f.seek(SeekFrom::Start(footer_off))?;
    f.read_exact(&mut foot)
        .map_err(|_| RylonError::parse("ryf: truncated footer"))?;
    let mut r = Reader::new(&foot);
    let index = if !encoded {
        RyfIndex {
            encoded,
            metas: read_metas(&mut r, n_groups)?,
            schema: None,
            stats: Vec::new(),
        }
    } else {
        let ncols = r.u32()? as usize;
        r.check_count(ncols, 3, "ryf schema fields")?;
        let mut fields = Vec::with_capacity(ncols);
        for _ in 0..ncols {
            let dtype = wire::tag_dtype(r.u8()?)?;
            let name_len = r.u16()? as usize;
            let name = std::str::from_utf8(r.bytes(name_len)?)
                .map_err(|_| {
                    RylonError::parse("ryf: field name is not utf-8")
                })?;
            fields.push(Field::new(name, dtype));
        }
        let schema = Schema::new(fields);
        let metas = read_metas(&mut r, n_groups)?;
        let mut stats = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            stats.push(
                (0..ncols)
                    .map(|c| {
                        encode::read_stats(&mut r, schema.field(c).dtype)
                    })
                    .collect::<Result<Vec<_>>>()?,
            );
        }
        RyfIndex {
            encoded,
            metas,
            schema: Some(schema),
            stats,
        }
    };
    if r.remaining() != 0 {
        return Err(RylonError::parse("ryf: trailing footer bytes"));
    }
    // Every group extent must land between the header and the footer —
    // a lying `len` would otherwise size the group-read buffer.
    for m in &index.metas {
        let end = m.offset.checked_add(m.len);
        if m.offset < 8 || end.map_or(true, |e| e > footer_off) {
            return Err(RylonError::parse(
                "ryf: group extent out of bounds",
            ));
        }
    }
    Ok(index)
}

/// Open an RYF file: returns the group index (footer).
pub fn read_ryf_footer(path: impl AsRef<Path>) -> Result<Vec<GroupMeta>> {
    Ok(read_ryf_index(path)?.metas)
}

fn read_group_bytes(path: &Path, meta: &GroupMeta) -> Result<Vec<u8>> {
    let mut f = std::fs::File::open(path)?;
    f.seek(SeekFrom::Start(meta.offset))?;
    let mut buf = vec![0u8; meta.len as usize];
    f.read_exact(&mut buf).map_err(|_| {
        RylonError::parse("ryf: truncated row group")
    })?;
    Ok(buf)
}

fn group_is_encoded(buf: &[u8]) -> bool {
    buf.len() >= 4 && buf[..4] == encode::GROUP_MAGIC.to_le_bytes()[..]
}

/// Read one row group (either format — the group bytes carry their own
/// magic).
pub fn read_ryf_group(
    path: impl AsRef<Path>,
    meta: &GroupMeta,
) -> Result<Table> {
    let buf = read_group_bytes(path.as_ref(), meta)?;
    if group_is_encoded(&buf) {
        Ok(encode::decode_group(&buf, None)?.0)
    } else {
        deserialize_table(&buf)
    }
}

/// Fetch and deserialise `metas` row groups under the calling thread's
/// intra-op budget (each worker opens its own file handle; groups come
/// back in `metas` order, so the concatenated result is bit-identical
/// to a serial read at any thread count).
fn read_groups_parallel(
    path: &Path,
    metas: &[GroupMeta],
) -> Result<Vec<Table>> {
    let total_rows: u64 = metas.iter().map(|m| m.rows).sum();
    let exec = exec::parallelism_for(total_rows as usize);
    if !exec.is_parallel() || metas.len() <= 1 {
        return metas.iter().map(|m| read_ryf_group(path, m)).collect();
    }
    let chunks = exec::split_even(metas.len(), exec.threads());
    let parts: Vec<Result<Vec<Table>>> = exec::map_parallel(chunks, |c| {
        metas[c.range()]
            .iter()
            .map(|m| read_ryf_group(path, m))
            .collect()
    });
    let mut out = Vec::with_capacity(metas.len());
    for p in parts {
        out.extend(p?);
    }
    Ok(out)
}

/// Read the whole file (row groups fetched morsel-parallel).
pub fn read_ryf(path: impl AsRef<Path>) -> Result<Table> {
    let metas = read_ryf_footer(&path)?;
    let parts = read_groups_parallel(path.as_ref(), &metas)?;
    let schema = parts
        .first()
        .map(|t| t.schema().clone())
        .ok_or_else(|| RylonError::parse("ryf: no groups"))?;
    Table::concat_all(&schema, &parts)
}

/// Read this rank's share of row groups (block distribution over
/// groups, fetched morsel-parallel) — the distributed ingest path.
pub fn read_ryf_partition(
    path: impl AsRef<Path>,
    rank: usize,
    world: usize,
) -> Result<Table> {
    if world == 0 || rank >= world {
        return Err(RylonError::invalid("bad rank/world"));
    }
    let metas = read_ryf_footer(&path)?;
    let mine: Vec<GroupMeta> = metas
        .iter()
        .enumerate()
        .filter(|(g, _)| g % world == rank)
        .map(|(_, m)| *m)
        .collect();
    let parts = read_groups_parallel(path.as_ref(), &mine)?;
    let schema = match parts.first() {
        Some(t) => t.schema().clone(),
        None => {
            // This rank owns no groups: read the first group only for
            // its schema (an empty result still needs one).
            let first = metas
                .first()
                .ok_or_else(|| RylonError::parse("ryf: empty file"))?;
            read_ryf_group(&path, first)?.schema().clone()
        }
    };
    Table::concat_all(&schema, &parts)
}

// ---- pushdown scan -------------------------------------------------------

/// Pushed-down scan parameters (built by the pipeline from its fused
/// leading stages).
#[derive(Debug, Clone, Default)]
pub struct ScanOptions {
    /// The pipeline's leading predicate conjunction. Encoded groups
    /// whose zone maps prove no row can match are skipped without
    /// decoding. The predicate is *not* applied to surviving rows —
    /// the pipeline's own select stage still runs, so a predicate the
    /// row evaluator would reject (unknown column, type mismatch)
    /// errors identically with or without pushdown.
    pub predicate: Option<Predicate>,
    /// The pipeline's live column set; column payloads not named here
    /// are never decoded or gathered. Names missing from the file are
    /// ignored (the pipeline surfaces the identical unknown-column
    /// error either way).
    pub projection: Option<Vec<String>>,
}

/// Scan the whole file with predicate/projection pushdown. On raw
/// files this degrades to a plain (projected) read — zone maps only
/// exist in encoded footers. Pushdown counters accumulate on the
/// calling thread ([`exec::take_scan_stats`]).
pub fn scan_ryf(
    path: impl AsRef<Path>,
    opts: &ScanOptions,
) -> Result<Table> {
    let index = read_ryf_index(&path)?;
    let owned: Vec<usize> = (0..index.metas.len()).collect();
    scan_groups(path.as_ref(), &index, &owned, opts)
}

/// Scan this rank's share of row groups (block distribution over
/// groups, like [`read_ryf_partition`]) with pushdown.
pub fn scan_ryf_partition(
    path: impl AsRef<Path>,
    rank: usize,
    world: usize,
    opts: &ScanOptions,
) -> Result<Table> {
    if world == 0 || rank >= world {
        return Err(RylonError::invalid("bad rank/world"));
    }
    let index = read_ryf_index(&path)?;
    let owned: Vec<usize> = (0..index.metas.len())
        .filter(|g| g % world == rank)
        .collect();
    scan_groups(path.as_ref(), &index, &owned, opts)
}

fn scan_groups(
    path: &Path,
    index: &RyfIndex,
    owned: &[usize],
    opts: &ScanOptions,
) -> Result<Table> {
    let mut counters = exec::ScanCounters::new();
    counters.groups_total = owned.len() as u64;
    let proj = opts.projection.as_deref();
    let mut survivors: Vec<GroupMeta> = Vec::with_capacity(owned.len());
    for &g in owned {
        let m = index.metas[g];
        let skip = match (&opts.predicate, &index.schema) {
            (Some(p), Some(schema)) => {
                !encode::group_may_match(p, schema, &index.stats[g], m.rows)
            }
            _ => false,
        };
        if skip {
            counters.groups_skipped += 1;
            counters.decoded_bytes_avoided += m.len;
        } else {
            survivors.push(m);
        }
    }
    let decoded = scan_groups_parallel(path, &survivors, proj)?;
    let mut parts = Vec::with_capacity(decoded.len());
    for (t, c) in decoded {
        counters.add(&c);
        parts.push(t);
    }
    let schema = match (&index.schema, parts.first()) {
        (Some(s), _) => project_schema(s, proj),
        (None, Some(t)) => t.schema().clone(),
        (None, None) => {
            // Raw file whose groups all belong to other ranks: probe
            // the first group for its schema (nothing lands in the
            // result, so the probe is not counted).
            let first = index
                .metas
                .first()
                .ok_or_else(|| RylonError::parse("ryf: empty file"))?;
            project_schema(read_ryf_group(path, first)?.schema(), proj)
        }
    };
    let out = Table::concat_all(&schema, &parts)?;
    let out = if index.encoded {
        restore_validity(out, index, owned)?
    } else {
        out
    };
    exec::note_scan(&counters);
    Ok(out)
}

fn scan_groups_parallel(
    path: &Path,
    metas: &[GroupMeta],
    proj: Option<&[String]>,
) -> Result<Vec<(Table, exec::ScanCounters)>> {
    let total_rows: u64 = metas.iter().map(|m| m.rows).sum();
    let exec = exec::parallelism_for(total_rows as usize);
    if !exec.is_parallel() || metas.len() <= 1 {
        return metas
            .iter()
            .map(|m| scan_one_group(path, m, proj))
            .collect();
    }
    let chunks = exec::split_even(metas.len(), exec.threads());
    let parts: Vec<Result<Vec<(Table, exec::ScanCounters)>>> =
        exec::map_parallel(chunks, |c| {
            metas[c.range()]
                .iter()
                .map(|m| scan_one_group(path, m, proj))
                .collect()
        });
    let mut out = Vec::with_capacity(metas.len());
    for p in parts {
        out.extend(p?);
    }
    Ok(out)
}

fn scan_one_group(
    path: &Path,
    meta: &GroupMeta,
    proj: Option<&[String]>,
) -> Result<(Table, exec::ScanCounters)> {
    let buf = read_group_bytes(path, meta)?;
    let mut c = exec::ScanCounters::new();
    if group_is_encoded(&buf) {
        let (t, pruning) = encode::decode_group(&buf, proj)?;
        c.decoded_bytes = meta.len.saturating_sub(pruning.avoided_bytes);
        c.decoded_bytes_avoided = pruning.avoided_bytes;
        c.pruned_columns = pruning.pruned_columns;
        Ok((t, c))
    } else {
        // Raw groups decode whole; the projection only drops the
        // materialised columns afterwards (zero-copy).
        let t = deserialize_table(&buf)?;
        c.decoded_bytes = meta.len;
        let t = match proj {
            Some(names) => project_table(&t, names),
            None => t,
        };
        Ok((t, c))
    }
}

/// Keep the named columns in file order (zero-copy Arc reuse).
/// Unknown names are ignored — the pipeline's own stages surface the
/// identical unknown-column error whether or not the scan pruned.
fn project_table(t: &Table, names: &[String]) -> Table {
    let keep: Vec<usize> = (0..t.num_columns())
        .filter(|&i| {
            names.iter().any(|n| n == &t.schema().field(i).name)
        })
        .collect();
    if keep.len() == t.num_columns() {
        return t.clone();
    }
    let schema = t.schema().project(&keep);
    let cols = keep.iter().map(|&i| t.column_arc(i)).collect();
    Table::from_parts(schema, cols, t.num_rows())
}

fn project_schema(schema: &Schema, proj: Option<&[String]>) -> Schema {
    match proj {
        None => schema.clone(),
        Some(names) => Schema::new(
            schema
                .fields()
                .iter()
                .filter(|f| names.iter().any(|n| n == &f.name))
                .cloned()
                .collect(),
        ),
    }
}

/// Whether one encoded group decodes column `dtype` with a validity
/// bitmap attached. Primitives round-trip through the wire
/// normalisation (all-valid bitmaps are dropped), so only a group with
/// nulls carries one; string columns keep theirs exactly as written.
fn group_col_has_validity(dtype: DataType, s: &ColumnStats) -> bool {
    match dtype {
        DataType::Int64 | DataType::Float64 | DataType::Bool => {
            s.null_count > 0
        }
        DataType::Utf8 => s.has_validity,
    }
}

/// Match the raw path's validity representation after pruning.
/// `Table::concat` promotes a column to `Some` validity when any
/// concatenated part carries one, so a scan that pruned the only
/// null-carrying groups would come back `None` where the raw oracle
/// (which decodes every group) says `Some(all ones)` — a downstream
/// gather preserves that difference and breaks bit-identity. The
/// footer stats record each group's nullability, so wrap an all-ones
/// bitmap wherever the full owned set would have promoted. (Groups
/// with zero rows never participate in `concat_all` and are ignored.)
fn restore_validity(
    out: Table,
    index: &RyfIndex,
    owned: &[usize],
) -> Result<Table> {
    let file_schema = match &index.schema {
        Some(s) => s,
        None => return Ok(out),
    };
    let n = out.num_rows();
    let mut cols: Vec<Arc<Column>> =
        Vec::with_capacity(out.num_columns());
    for (i, f) in out.schema().fields().iter().enumerate() {
        let col = out.column(i);
        let fi = file_schema.index_of(&f.name)?;
        let expected = owned.iter().any(|&g| {
            index.metas[g].rows > 0
                && group_col_has_validity(f.dtype, &index.stats[g][fi])
        });
        if expected && col.validity().is_none() {
            cols.push(Arc::new(with_ones_validity(col, n)));
        } else {
            cols.push(out.column_arc(i));
        }
    }
    Ok(Table::from_parts(out.schema().clone(), cols, n))
}

fn with_ones_validity(col: &Column, n: usize) -> Column {
    let ones = Some(Bitmap::ones(n));
    match col {
        Column::Int64(c) => Column::Int64(PrimitiveColumn {
            values: c.values().to_vec(),
            validity: ones,
        }),
        Column::Float64(c) => Column::Float64(PrimitiveColumn {
            values: c.values().to_vec(),
            validity: ones,
        }),
        Column::Bool(c) => Column::Bool(PrimitiveColumn {
            values: c.values().to_vec(),
            validity: ones,
        }),
        Column::Utf8(c) => Column::Utf8(StringColumn::from_parts(
            c.offsets().to_vec(),
            c.bytes().to_vec(),
            ones,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::ops::select::select;

    fn t(n: usize) -> Table {
        Table::from_columns(vec![
            ("id", Column::from_i64((0..n as i64).collect())),
            (
                "s",
                Column::from_opt_str(
                    &(0..n)
                        .map(|i| {
                            if i % 7 == 0 {
                                None
                            } else {
                                Some(format!("row{i}"))
                            }
                        })
                        .collect::<Vec<_>>(),
                ),
            ),
        ])
        .unwrap()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rylon_ryf_{name}"))
    }

    #[test]
    fn roundtrip_multiple_groups() {
        let path = tmp("rt");
        let table = t(1000);
        write_ryf(&table, &path, 128).unwrap();
        let metas = read_ryf_footer(&path).unwrap();
        assert_eq!(metas.len(), 8); // ceil(1000/128)
        assert_eq!(metas.iter().map(|m| m.rows).sum::<u64>(), 1000);
        let back = read_ryf(&path).unwrap();
        assert_eq!(back, table);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn single_group_and_empty() {
        let path = tmp("small");
        write_ryf(&t(3), &path, 1000).unwrap();
        assert_eq!(read_ryf(&path).unwrap().num_rows(), 3);
        let empty = Table::empty(t(1).schema().clone());
        write_ryf(&empty, &path, 10).unwrap();
        assert_eq!(read_ryf(&path).unwrap().num_rows(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn partitioned_read_covers_all_groups() {
        let path = tmp("part");
        let table = t(500);
        write_ryf(&table, &path, 64).unwrap();
        let world = 3;
        let mut total = 0;
        let mut ids = Vec::new();
        for r in 0..world {
            let p = read_ryf_partition(&path, r, world).unwrap();
            total += p.num_rows();
            ids.extend(p.column(0).i64_values().to_vec());
        }
        assert_eq!(total, 500);
        ids.sort();
        assert_eq!(ids, (0..500).collect::<Vec<_>>());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn group_reads_are_independent() {
        let path = tmp("grp");
        write_ryf(&t(100), &path, 30).unwrap();
        let metas = read_ryf_footer(&path).unwrap();
        let g2 = read_ryf_group(&path, &metas[2]).unwrap();
        assert_eq!(g2.column(0).i64_values()[0], 60);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parallel_read_is_bit_identical() {
        let path = tmp("par");
        let table = t(5000);
        write_ryf(&table, &path, 256).unwrap(); // 20 groups
        let serial =
            crate::exec::with_intra_op_threads(1, || read_ryf(&path).unwrap());
        assert_eq!(serial, table);
        let part_serial = crate::exec::with_intra_op_threads(1, || {
            read_ryf_partition(&path, 1, 3).unwrap()
        });
        for threads in [2, 4, 8] {
            crate::exec::with_intra_op_threads(threads, || {
                crate::exec::with_par_row_threshold(1, || {
                    assert_eq!(
                        read_ryf(&path).unwrap(),
                        serial,
                        "ryf read diverged at {threads} threads"
                    );
                    assert_eq!(
                        read_ryf_partition(&path, 1, 3).unwrap(),
                        part_serial,
                        "ryf partition read diverged at {threads} threads"
                    );
                })
            });
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn incremental_writer_matches_bulk_writer() {
        // Appending slices one group at a time (the streaming-convert
        // path) must produce a file the readers see identically to the
        // bulk writer's.
        let path = tmp("inc");
        let bulk_path = tmp("inc_bulk");
        let table = t(350);
        let mut w = RyfWriter::create(&path).unwrap();
        for g in 0..(350usize.div_ceil(100)) {
            w.append(&table.slice(g * 100, 100)).unwrap();
        }
        assert_eq!(w.groups(), 4);
        assert_eq!(w.finish().unwrap(), 4);
        write_ryf(&table, &bulk_path, 100).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            std::fs::read(&bulk_path).unwrap(),
            "incremental and bulk writers must emit identical bytes"
        );
        assert_eq!(read_ryf(&path).unwrap(), table);
        // Zero appends is an error, not a corrupt file.
        assert!(RyfWriter::create(&path).unwrap().finish().is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&bulk_path).ok();
    }

    #[test]
    fn corruption_detected() {
        let path = tmp("bad");
        write_ryf(&t(10), &path, 5).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_ryf_footer(&path).is_err());
        std::fs::write(&path, b"tiny").unwrap();
        assert!(read_ryf_footer(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_args() {
        let path = tmp("args");
        assert!(write_ryf(&t(5), &path, 0).is_err());
        write_ryf(&t(5), &path, 2).unwrap();
        assert!(read_ryf_partition(&path, 3, 3).is_err());
        let opts = ScanOptions::default();
        assert!(scan_ryf_partition(&path, 3, 3, &opts).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn knob_selects_format_and_both_roundtrip() {
        let table = t(400);
        let raw = tmp("fmt_raw");
        let enc = tmp("fmt_enc");
        exec::with_ryf_encoding(false, || write_ryf(&table, &raw, 64))
            .unwrap();
        exec::with_ryf_encoding(true, || write_ryf(&table, &enc, 64))
            .unwrap();
        assert_eq!(&std::fs::read(&raw).unwrap()[..4], b"RYF1");
        assert_eq!(&std::fs::read(&enc).unwrap()[..4], b"RYF2");
        assert_eq!(read_ryf(&raw).unwrap(), table);
        assert_eq!(read_ryf(&enc).unwrap(), table);
        for rank in 0..3 {
            assert_eq!(
                read_ryf_partition(&enc, rank, 3).unwrap(),
                read_ryf_partition(&raw, rank, 3).unwrap(),
                "partition {rank} diverged between formats"
            );
        }
        let idx = read_ryf_index(&enc).unwrap();
        assert!(idx.encoded);
        assert_eq!(idx.schema.as_ref().unwrap(), table.schema());
        assert_eq!(idx.metas.len(), 7); // ceil(400/64)
        assert_eq!(idx.stats.len(), idx.metas.len());
        let idx = read_ryf_index(&raw).unwrap();
        assert!(!idx.encoded);
        assert!(idx.schema.is_none() && idx.stats.is_empty());
        std::fs::remove_file(&raw).ok();
        std::fs::remove_file(&enc).ok();
    }

    #[test]
    fn scan_prunes_groups_and_counts() {
        let path = tmp("scan_prune");
        let table = t(1000);
        exec::with_ryf_encoding(true, || write_ryf(&table, &path, 100))
            .unwrap();
        let pred = Predicate::parse("id < 100").unwrap();
        let opts = ScanOptions {
            predicate: Some(pred.clone()),
            projection: None,
        };
        let _ = exec::take_scan_stats();
        let got = scan_ryf(&path, &opts).unwrap();
        let c = exec::take_scan_stats();
        assert_eq!(c.groups_total, 10);
        assert_eq!(c.groups_skipped, 9, "only group 0 can match id<100");
        assert!(c.decoded_bytes_avoided > 0);
        assert!(c.decoded_bytes > 0);
        assert_eq!(got.num_rows(), 100);
        // The scan's survivors, filtered, are bit-identical to the
        // unpruned read, filtered.
        assert_eq!(
            select(&got, &pred).unwrap(),
            select(&read_ryf(&path).unwrap(), &pred).unwrap()
        );
        // Raw files have no zone maps: same result, nothing skipped.
        let raw = tmp("scan_prune_raw");
        exec::with_ryf_encoding(false, || write_ryf(&table, &raw, 100))
            .unwrap();
        let all = scan_ryf(&raw, &opts).unwrap();
        let c = exec::take_scan_stats();
        assert_eq!(c.groups_skipped, 0);
        assert_eq!(
            select(&got, &pred).unwrap(),
            select(&all, &pred).unwrap(),
            "encoded scan must match the raw oracle after filtering"
        );
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&raw).ok();
    }

    #[test]
    fn scan_projection_prunes_column_payloads() {
        let path = tmp("scan_proj");
        let table = t(600);
        exec::with_ryf_encoding(true, || write_ryf(&table, &path, 100))
            .unwrap();
        let opts = ScanOptions {
            predicate: None,
            projection: Some(vec!["id".to_string()]),
        };
        let _ = exec::take_scan_stats();
        let got = scan_ryf(&path, &opts).unwrap();
        let c = exec::take_scan_stats();
        assert_eq!(c.pruned_columns, 6, "one string column × 6 groups");
        assert!(c.decoded_bytes_avoided > 0);
        assert_eq!(got.num_columns(), 1);
        assert_eq!(got.schema().field(0).name, "id");
        assert_eq!(got.column(0), &*t(600).column_arc(0));
        // Raw oracle: same table, columns dropped after decode.
        let raw = tmp("scan_proj_raw");
        exec::with_ryf_encoding(false, || write_ryf(&table, &raw, 100))
            .unwrap();
        assert_eq!(scan_ryf(&raw, &opts).unwrap(), got);
        // Unknown projected names are ignored, not an error.
        let opts = ScanOptions {
            predicate: None,
            projection: Some(vec!["nope".to_string()]),
        };
        assert_eq!(scan_ryf(&path, &opts).unwrap().num_columns(), 0);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&raw).ok();
    }

    #[test]
    fn scan_restores_validity_when_null_groups_are_pruned() {
        // Nulls live only in the high-id groups; pruning them away
        // must not change the surviving columns' validity
        // representation vs the raw oracle (concat promotes validity
        // from *any* group, including pruned ones).
        let n = 300;
        let table = Table::from_columns(vec![
            ("id", Column::from_i64((0..n as i64).collect())),
            (
                "v",
                Column::from_opt_i64(
                    (0..n as i64)
                        .map(|i| if i < 200 { Some(i * 3) } else { None })
                        .collect(),
                ),
            ),
            (
                "s",
                Column::from_opt_str(
                    &(0..n)
                        .map(|i| {
                            if i < 200 {
                                Some(format!("tag{i}"))
                            } else {
                                None
                            }
                        })
                        .collect::<Vec<_>>(),
                ),
            ),
        ])
        .unwrap();
        let enc = tmp("scan_val_enc");
        let raw = tmp("scan_val_raw");
        exec::with_ryf_encoding(true, || write_ryf(&table, &enc, 100))
            .unwrap();
        exec::with_ryf_encoding(false, || write_ryf(&table, &raw, 100))
            .unwrap();
        let pred = Predicate::parse("id < 100").unwrap();
        let opts = ScanOptions {
            predicate: Some(pred.clone()),
            projection: None,
        };
        let _ = exec::take_scan_stats();
        let pruned = scan_ryf(&enc, &opts).unwrap();
        let c = exec::take_scan_stats();
        assert_eq!(c.groups_skipped, 2, "groups 1 and 2 are dead");
        // Group 0 is null-free, but the raw path still carries a
        // validity bitmap (promoted from the null groups).
        assert!(pruned.column(1).validity().is_some());
        assert_eq!(
            select(&pruned, &pred).unwrap(),
            select(&scan_ryf(&raw, &opts).unwrap(), &pred).unwrap(),
            "validity representation must survive pruning"
        );
        std::fs::remove_file(&enc).ok();
        std::fs::remove_file(&raw).ok();
    }

    #[test]
    fn scan_partition_matches_raw_oracle() {
        let table = t(900);
        let enc = tmp("scan_part_enc");
        let raw = tmp("scan_part_raw");
        exec::with_ryf_encoding(true, || write_ryf(&table, &enc, 64))
            .unwrap();
        exec::with_ryf_encoding(false, || write_ryf(&table, &raw, 64))
            .unwrap();
        let pred = Predicate::parse("id >= 256 and id < 512").unwrap();
        let opts = ScanOptions {
            predicate: Some(pred.clone()),
            projection: None,
        };
        for world in [1, 2, 3] {
            for rank in 0..world {
                let e =
                    scan_ryf_partition(&enc, rank, world, &opts).unwrap();
                let r =
                    scan_ryf_partition(&raw, rank, world, &opts).unwrap();
                assert_eq!(
                    select(&e, &pred).unwrap(),
                    select(&r, &pred).unwrap(),
                    "rank {rank}/{world} diverged from the raw oracle"
                );
            }
        }
        let _ = exec::take_scan_stats();
        std::fs::remove_file(&enc).ok();
        std::fs::remove_file(&raw).ok();
    }

    #[test]
    fn ryf2_footer_corruption_fails_closed() {
        let path = tmp("bad2");
        let table = t(50);
        exec::with_ryf_encoding(true, || write_ryf(&table, &path, 10))
            .unwrap();
        let good = std::fs::read(&path).unwrap();
        let n = good.len();
        let footer_off =
            u64::from_le_bytes(good[n - 8..].try_into().unwrap()) as usize;

        // Footer offset pointing nowhere.
        for bad_off in [u64::MAX, 0u64, (n as u64) - 7] {
            let mut bad = good.clone();
            bad[n - 8..].copy_from_slice(&bad_off.to_le_bytes());
            std::fs::write(&path, &bad).unwrap();
            assert!(
                read_ryf_index(&path).is_err(),
                "footer offset {bad_off} must be rejected"
            );
        }
        // Header group count inflated past the footer.
        let mut bad = good.clone();
        bad[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bad).unwrap();
        assert!(read_ryf_index(&path).is_err());
        // Invalid dtype tag in the footer schema block.
        let mut bad = good.clone();
        bad[footer_off + 4] ^= 0x77;
        std::fs::write(&path, &bad).unwrap();
        assert!(read_ryf_index(&path).is_err());
        // Trailing garbage between the stats and the footer offset.
        let mut bad = good[..n - 8].to_vec();
        bad.push(0);
        bad.extend_from_slice(&(footer_off as u64).to_le_bytes());
        std::fs::write(&path, &bad).unwrap();
        assert!(read_ryf_index(&path).is_err());
        // Pristine bytes still parse.
        std::fs::write(&path, &good).unwrap();
        assert_eq!(read_ryf(&path).unwrap(), table);
        std::fs::remove_file(&path).ok();
    }
}
