//! Data ingress/egress: CSV (own parser — the paper's experiments load
//! four-column CSVs) and deterministic synthetic generators matching the
//! paper's workload shapes (§V "Dataset Formats").

pub mod csv;
pub mod datagen;
pub mod encode;
pub mod ryf;
