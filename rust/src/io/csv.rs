//! CSV reader/writer. RFC-4180-style quoting (double-quote fields,
//! doubled quotes inside — including quoted newlines), optional header,
//! explicit or inferred schema. Empty cells are nulls.
//!
//! Reading is a **two-pass morsel-parallel parse** (cf. "High
//! Performance Data Engineering Everywhere", Widanage et al. 2020,
//! which makes parallel table ingest a first-class kernel): a
//! quote-aware newline scan splits the buffer into row-aligned byte
//! ranges, worker threads parse runs of whole records into per-chunk
//! [`ColumnBuilder`]s under the calling thread's intra-op budget, and
//! the chunks concatenate in file order — so the parsed table is
//! bit-identical to a serial parse (including schema inference from the
//! first `infer_rows` records) at any thread count.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::column::ColumnBuilder;
use crate::error::{Result, RylonError};
use crate::exec;
use crate::table::Table;
use crate::types::{DataType, Field, Schema};

/// CSV read/write options.
#[derive(Debug, Clone)]
pub struct CsvOptions {
    pub delimiter: char,
    /// First row is a header (read: column names; write: emit header).
    pub has_header: bool,
    /// Explicit schema; when `None` the reader infers types from the
    /// first `infer_rows` records (i64 ⊂ f64 ⊂ str; bool literal set).
    pub schema: Option<Schema>,
    pub infer_rows: usize,
}

impl Default for CsvOptions {
    fn default() -> Self {
        CsvOptions {
            delimiter: ',',
            has_header: true,
            schema: None,
            infer_rows: 128,
        }
    }
}

impl CsvOptions {
    pub fn with_schema(mut self, schema: Schema) -> CsvOptions {
        self.schema = Some(schema);
        self
    }

    pub fn no_header(mut self) -> CsvOptions {
        self.has_header = false;
        self
    }
}

/// Split one CSV record honouring quotes. Returns the cells.
fn split_record(line: &str, delim: char) -> Result<Vec<String>> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else if c == '"' {
            in_quotes = true;
        } else if c == delim {
            cells.push(std::mem::take(&mut cur));
        } else {
            cur.push(c);
        }
    }
    if in_quotes {
        // An unterminated quote swallows everything to EOF in the
        // boundary scan, so the offending "record" can be near
        // file-sized — bound the excerpt in the message.
        let excerpt: String = line.chars().take(80).collect();
        return Err(RylonError::parse(format!(
            "unterminated quote in record starting: {excerpt:?}"
        )));
    }
    cells.push(cur);
    Ok(cells)
}

fn infer_dtype(samples: &[&str]) -> DataType {
    let non_empty: Vec<&&str> =
        samples.iter().filter(|s| !s.is_empty()).collect();
    if non_empty.is_empty() {
        return DataType::Utf8;
    }
    if non_empty
        .iter()
        .all(|s| s.trim().parse::<i64>().is_ok())
    {
        return DataType::Int64;
    }
    if non_empty
        .iter()
        .all(|s| s.trim().parse::<f64>().is_ok())
    {
        return DataType::Float64;
    }
    if non_empty.iter().all(|s| {
        matches!(s.trim(), "true" | "false" | "True" | "False")
    }) {
        return DataType::Bool;
    }
    DataType::Utf8
}

/// Pass 1: byte ranges of the records in `buf`. A newline splits
/// records only outside a **quoted field** (so quoted fields may
/// contain newlines); one trailing `\r` per record is stripped; empty
/// lines are skipped. A quoted field opens only at field start (RFC
/// 4180) and `""` inside it is an escaped quote — a stray quote
/// mid-field never swallows newlines, so malformed rows still fail
/// fast in `split_record` instead of silently merging. Quote and
/// newline are ASCII (and a multi-byte delimiter is matched by its
/// full encoding), so the byte scan is UTF-8 safe.
fn scan_records(buf: &str, delim: char) -> Vec<(usize, usize)> {
    let bytes = buf.as_bytes();
    let mut dbuf = [0u8; 4];
    let d = delim.encode_utf8(&mut dbuf).as_bytes();
    let mut out = Vec::new();
    let mut start = 0usize;
    let mut in_quotes = false;
    let mut at_field_start = true;
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        if in_quotes {
            if b == b'"' {
                if bytes.get(i + 1) == Some(&b'"') {
                    i += 2; // escaped quote, stay quoted
                    continue;
                }
                in_quotes = false; // field continues unquoted
            }
            i += 1;
            continue;
        }
        if b == b'"' && at_field_start {
            in_quotes = true;
            at_field_start = false;
            i += 1;
            continue;
        }
        if b == b'\n' {
            push_record_range(&mut out, bytes, start, i);
            start = i + 1;
            at_field_start = true;
            i += 1;
            continue;
        }
        if b == d[0] && bytes[i..].starts_with(d) {
            at_field_start = true;
            i += d.len();
            continue;
        }
        at_field_start = false;
        i += 1;
    }
    // An unterminated quote runs to EOF; `split_record` rejects it.
    push_record_range(&mut out, bytes, start, bytes.len());
    out
}

fn push_record_range(
    out: &mut Vec<(usize, usize)>,
    bytes: &[u8],
    start: usize,
    mut end: usize,
) {
    if end > start && bytes[end - 1] == b'\r' {
        end -= 1;
    }
    if end > start {
        out.push((start, end));
    }
}

/// Pass 2 worker: parse a run of whole records into columns.
/// `first_record` is the chunk's absolute record index (for error
/// messages that match a serial parse).
fn parse_records(
    buf: &str,
    ranges: &[(usize, usize)],
    schema: &Schema,
    first_record: usize,
    delim: char,
) -> Result<Table> {
    let mut builders: Vec<ColumnBuilder> = schema
        .fields()
        .iter()
        .map(|f| ColumnBuilder::new(f.dtype, ranges.len()))
        .collect();
    for (k, &(s, e)) in ranges.iter().enumerate() {
        let rec = split_record(&buf[s..e], delim)?;
        if rec.len() != schema.len() {
            return Err(RylonError::parse(format!(
                "record {} has {} cells, schema has {}",
                first_record + k + 1,
                rec.len(),
                schema.len()
            )));
        }
        for (b, cell) in builders.iter_mut().zip(&rec) {
            b.push_parse(cell)?;
        }
    }
    Table::try_new(
        schema.clone(),
        builders.into_iter().map(|b| b.finish()).collect(),
    )
}

/// Read a CSV from any reader.
pub fn read_csv_from<R: Read>(reader: R, opts: &CsvOptions) -> Result<Table> {
    let mut buf = String::new();
    BufReader::new(reader).read_to_string(&mut buf)?;
    read_csv_str(&buf, opts)
}

/// Parse CSV text already in memory — the core two-pass reader (see the
/// module docs). Parallel under the calling thread's intra-op budget;
/// bit-identical to a serial parse at any thread count.
pub fn read_csv_str(buf: &str, opts: &CsvOptions) -> Result<Table> {
    let ranges = scan_records(buf, opts.delimiter);
    let has_header = opts.has_header && !ranges.is_empty();
    let header: Option<Vec<String>> = if has_header {
        let (s, e) = ranges[0];
        Some(split_record(&buf[s..e], opts.delimiter)?)
    } else {
        None
    };
    // Data records: everything past the header row (slice, no shift).
    let records = &ranges[has_header as usize..];

    // Establish the schema (inference samples the first `infer_rows`
    // records, exactly like the serial reader).
    let schema = match &opts.schema {
        Some(s) => s.clone(),
        None => {
            let mut sample_rows: Vec<Vec<String>> =
                Vec::with_capacity(opts.infer_rows.min(records.len()));
            for &(s, e) in records.iter().take(opts.infer_rows) {
                sample_rows.push(split_record(&buf[s..e], opts.delimiter)?);
            }
            let width = header
                .as_ref()
                .map(|h| h.len())
                .or_else(|| sample_rows.first().map(|r| r.len()))
                .ok_or_else(|| RylonError::parse("empty csv"))?;
            let fields = (0..width)
                .map(|c| {
                    let name = header
                        .as_ref()
                        .map(|h| h[c].clone())
                        .unwrap_or_else(|| format!("c{c}"));
                    let samples: Vec<&str> = sample_rows
                        .iter()
                        .map(|r| r.get(c).map(|s| s.as_str()).unwrap_or(""))
                        .collect();
                    Field::new(name, infer_dtype(&samples))
                })
                .collect();
            Schema::new(fields)
        }
    };

    if records.is_empty() {
        let cols = schema
            .fields()
            .iter()
            .map(|f| ColumnBuilder::new(f.dtype, 0).finish())
            .collect();
        return Table::try_new(schema, cols);
    }

    // Pass 2: chunked parse — each chunk is a run of whole records;
    // chunks concatenate in file order. The first error in record
    // order wins, matching a serial scan.
    let exec = exec::parallelism_for(records.len());
    let chunks = exec::split_even(records.len(), exec.threads());
    let header_rows = opts.has_header as usize;
    let schema_ref = &schema;
    let delim = opts.delimiter;
    let parts: Vec<Result<Table>> = exec::map_parallel(chunks, |m| {
        parse_records(
            buf,
            &records[m.range()],
            schema_ref,
            m.start + header_rows,
            delim,
        )
    });
    let tables = parts.into_iter().collect::<Result<Vec<Table>>>()?;
    Table::concat_all(&schema, &tables)
}

/// Read a CSV file.
pub fn read_csv(path: impl AsRef<Path>, opts: &CsvOptions) -> Result<Table> {
    let f = std::fs::File::open(path)?;
    read_csv_from(f, opts)
}

fn needs_quoting(s: &str, delim: char) -> bool {
    s.contains(delim) || s.contains('"') || s.contains('\n')
}

/// Write a table to any writer.
pub fn write_csv_to<W: Write>(
    table: &Table,
    writer: W,
    opts: &CsvOptions,
) -> Result<()> {
    let mut w = BufWriter::new(writer);
    let d = opts.delimiter;
    if opts.has_header {
        let names: Vec<&str> = table
            .schema()
            .fields()
            .iter()
            .map(|f| f.name.as_str())
            .collect();
        writeln!(w, "{}", names.join(&d.to_string()))?;
    }
    let mut cell = String::new();
    for r in 0..table.num_rows() {
        for c in 0..table.num_columns() {
            if c > 0 {
                write!(w, "{d}")?;
            }
            cell.clear();
            cell.push_str(&table.column(c).value(r).render());
            if needs_quoting(&cell, d) {
                write!(w, "\"{}\"", cell.replace('"', "\"\""))?;
            } else {
                write!(w, "{cell}")?;
            }
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Write a table to a CSV file.
pub fn write_csv(
    table: &Table,
    path: impl AsRef<Path>,
    opts: &CsvOptions,
) -> Result<()> {
    let f = std::fs::File::create(path)?;
    write_csv_to(table, f, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::types::Value;

    #[test]
    fn read_with_inference() {
        let data = "id,price,name,ok\n1,2.5,apple,true\n2,,\"b,c\",false\n";
        let t = read_csv_from(data.as_bytes(), &CsvOptions::default())
            .unwrap();
        assert_eq!(t.num_rows(), 2);
        assert_eq!(t.schema().field(0).dtype, DataType::Int64);
        assert_eq!(t.schema().field(1).dtype, DataType::Float64);
        assert_eq!(t.schema().field(2).dtype, DataType::Utf8);
        assert_eq!(t.schema().field(3).dtype, DataType::Bool);
        assert_eq!(t.column(1).value(1), Value::Null);
        assert_eq!(t.column(2).value(1), Value::Utf8("b,c".into()));
    }

    #[test]
    fn explicit_schema_and_no_header() {
        let data = "1,x\n2,y\n";
        let opts = CsvOptions::default()
            .no_header()
            .with_schema(Schema::parse("a:i64,b:str").unwrap());
        let t = read_csv_from(data.as_bytes(), &opts).unwrap();
        assert_eq!(t.num_rows(), 2);
        assert_eq!(t.column(0).i64_values(), &[1, 2]);
    }

    #[test]
    fn quoted_quotes_and_roundtrip() {
        let t = Table::from_columns(vec![
            ("s", Column::from_str(&["plain", "has,comma", "has\"quote"])),
            ("v", Column::from_opt_i64(vec![Some(1), None, Some(3)])),
        ])
        .unwrap();
        let mut buf = Vec::new();
        write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
        let opts = CsvOptions::default()
            .with_schema(Schema::parse("s:str,v:i64").unwrap());
        let back = read_csv_from(&buf[..], &opts).unwrap();
        assert_eq!(back.column(0).as_utf8().value(1), "has,comma");
        assert_eq!(back.column(0).as_utf8().value(2), "has\"quote");
        assert_eq!(back.column(1).value(1), Value::Null);
    }

    #[test]
    fn ragged_record_rejected() {
        let data = "a,b\n1,2\n3\n";
        assert!(read_csv_from(data.as_bytes(), &CsvOptions::default())
            .is_err());
    }

    #[test]
    fn bad_literal_with_schema_rejected() {
        let data = "a\nxyz\n";
        let opts = CsvOptions::default()
            .with_schema(Schema::parse("a:i64").unwrap());
        assert!(read_csv_from(data.as_bytes(), &opts).is_err());
    }

    #[test]
    fn unterminated_quote_rejected() {
        let data = "a\n\"oops\n";
        assert!(read_csv_from(data.as_bytes(), &CsvOptions::default())
            .is_err());
    }

    #[test]
    fn stray_quote_mid_field_fails_fast() {
        // A bare quote inside an unquoted field is malformed: the
        // field-start-aware scan must not let it swallow the following
        // rows — the record still fails in `split_record`.
        let data = "a,b\n1,2\"x\n3,4\n";
        assert!(read_csv_from(data.as_bytes(), &CsvOptions::default())
            .is_err());
    }

    #[test]
    fn escaped_quote_before_newline_stays_quoted() {
        // `""` inside a quoted field is an escaped quote, not a close:
        // the newline after it is still part of the field.
        let data = "s,v\n\"a\"\"\nb\",1\n";
        let opts = CsvOptions::default()
            .with_schema(Schema::parse("s:str,v:i64").unwrap());
        let t = read_csv_from(data.as_bytes(), &opts).unwrap();
        assert_eq!(t.num_rows(), 1);
        assert_eq!(t.column(0).as_utf8().value(0), "a\"\nb");
    }

    #[test]
    fn quoted_newline_roundtrip() {
        // The quote-aware boundary scan keeps newlines inside quoted
        // fields (RFC 4180), so multi-line strings survive a roundtrip.
        let t = Table::from_columns(vec![
            ("s", Column::from_str(&["multi\nline", "crlf\r\nfield", "plain"])),
            ("v", Column::from_i64(vec![1, 2, 3])),
        ])
        .unwrap();
        let mut buf = Vec::new();
        write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
        let opts = CsvOptions::default()
            .with_schema(Schema::parse("s:str,v:i64").unwrap());
        let back = read_csv_from(&buf[..], &opts).unwrap();
        assert_eq!(back.num_rows(), 3);
        assert_eq!(back.column(0).as_utf8().value(0), "multi\nline");
        assert_eq!(back.column(0).as_utf8().value(1), "crlf\r\nfield");
    }

    #[test]
    fn parallel_parse_is_bit_identical() {
        // Quoted/multibyte/ragged-null fixture, parsed at several
        // thread counts with the threshold forced down so the parallel
        // path engages on a small input.
        let mut data = String::from("id,name,score,flag\n");
        for i in 0..500 {
            let name = match i % 4 {
                0 => format!("\"quoted,{i}\""),
                1 => format!("日本語{i}"),
                2 => String::new(), // null cell
                _ => format!("\"with \"\"quotes\"\" {i}\""),
            };
            let score = if i % 5 == 0 {
                String::new() // null cell
            } else {
                format!("{}.25", i)
            };
            data.push_str(&format!("{i},{name},{score},{}\n", i % 2 == 0));
        }
        let serial = crate::exec::with_intra_op_threads(1, || {
            read_csv_str(&data, &CsvOptions::default()).unwrap()
        });
        for threads in [2, 4, 8] {
            let par = crate::exec::with_intra_op_threads(threads, || {
                crate::exec::with_par_row_threshold(1, || {
                    read_csv_str(&data, &CsvOptions::default()).unwrap()
                })
            });
            assert_eq!(par, serial, "csv parse diverged at {threads} threads");
        }
        assert_eq!(serial.num_rows(), 500);
        assert_eq!(serial.schema().field(2).dtype, DataType::Float64);
        assert_eq!(serial.column(1).null_count(), 125);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("rylon_csv_test.csv");
        let t = Table::from_columns(vec![
            ("id", Column::from_i64(vec![10, 20])),
            ("v", Column::from_f64(vec![1.25, -0.5])),
        ])
        .unwrap();
        write_csv(&t, &path, &CsvOptions::default()).unwrap();
        let back = read_csv(&path, &CsvOptions::default()).unwrap();
        assert_eq!(back.column(0).i64_values(), &[10, 20]);
        assert_eq!(back.column(1).f64_values(), &[1.25, -0.5]);
        std::fs::remove_file(&path).ok();
    }
}
