//! CSV reader/writer. RFC-4180-style quoting (double-quote fields,
//! doubled quotes inside — including quoted newlines), optional header,
//! explicit or inferred schema. Empty cells are nulls.
//!
//! Reading is a **streaming, bounded-memory, morsel-parallel parse**
//! (cf. "High Performance Data Engineering Everywhere", Widanage et al.
//! 2020, which makes chunked parallel table ingest a first-class
//! kernel). The source is consumed in fixed-size chunks of
//! [`crate::exec::ingest_chunk_bytes`] bytes (`[exec]
//! ingest_chunk_bytes` / `--ingest-chunk`), so raw-text memory is
//! O(chunk + longest record) instead of O(file):
//!
//! 1. **Boundary scan.** Each chunk is scanned for record boundaries by
//!    a three-state DFA (field start / unquoted / quoted) whose state is
//!    carried across chunk seams, so quoted newlines, `""` escapes, and
//!    CRLF pairs may straddle chunks freely. On large chunks the scan is
//!    **speculative and parallel**: workers scan disjoint sub-ranges
//!    under *every* possible entry state, then a cheap prefix pass over
//!    the per-range (exit-state, newline-list) summaries picks the true
//!    entry state of each sub-range and splices the chosen newline
//!    lists — bit-identical to the serial scan.
//! 2. **Record parse.** Each chunk's row-aligned ranges are parsed into
//!    per-chunk [`ColumnBuilder`]s on the calling thread's worker pool
//!    and the chunk tables concatenate in file order, so the streamed
//!    parse is bit-identical to a whole-buffer serial parse (including
//!    schema inference from the first `infer_rows` records) at any
//!    thread count and any chunk size.
//!
//! Multi-byte (non-ASCII) delimiters fall back to the whole-buffer
//! serial scan: a multi-byte delimiter could straddle a chunk seam,
//! which the byte-at-a-time DFA cannot see.
//!
//! The same DFA powers the **cross-rank byte-range speculation** of
//! [`crate::dist::read_csv_partition`]: each rank scans only its own
//! byte range under all three entry states, and a summary exchange
//! picks the truth (see `docs/INGEST.md`).

#![warn(missing_docs)]

use std::io::{BufReader, BufWriter, Read, Write};
use std::ops::Range;
use std::path::Path;

use crate::column::ColumnBuilder;
use crate::error::{Result, RylonError};
use crate::exec;
use crate::table::Table;
use crate::types::{DataType, Field, Schema};

/// CSV read/write options.
#[derive(Debug, Clone)]
pub struct CsvOptions {
    /// Field delimiter (default `,`). Non-ASCII delimiters disable the
    /// streaming byte DFA and fall back to whole-buffer reads.
    pub delimiter: char,
    /// First row is a header (read: column names; write: emit header).
    pub has_header: bool,
    /// Explicit schema; when `None` the reader infers types from the
    /// first `infer_rows` records (i64 ⊂ f64 ⊂ str; bool literal set).
    pub schema: Option<Schema>,
    /// How many leading records inference samples (default 128).
    pub infer_rows: usize,
}

impl Default for CsvOptions {
    fn default() -> Self {
        CsvOptions {
            delimiter: ',',
            has_header: true,
            schema: None,
            infer_rows: 128,
        }
    }
}

impl CsvOptions {
    /// Use an explicit schema instead of inference.
    pub fn with_schema(mut self, schema: Schema) -> CsvOptions {
        self.schema = Some(schema);
        self
    }

    /// Treat the first row as data, not a header.
    pub fn no_header(mut self) -> CsvOptions {
        self.has_header = false;
        self
    }
}

/// Split one CSV record honouring quotes. Returns the cells. `pos`
/// lazily supplies the record's absolute byte offset and 1-based line
/// number for the unterminated-quote error (the only error this can
/// raise), so a stray mid-field quote fails fast *and* points at the
/// offending record instead of an opaque excerpt.
pub(crate) fn split_record(
    line: &str,
    delim: char,
    pos: impl FnOnce() -> (u64, u64),
) -> Result<Vec<String>> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else if c == '"' {
            in_quotes = true;
        } else if c == delim {
            cells.push(std::mem::take(&mut cur));
        } else {
            cur.push(c);
        }
    }
    if in_quotes {
        // An unterminated quote swallows everything to EOF in the
        // boundary scan, so the offending "record" can be near
        // file-sized — bound the excerpt in the message.
        let excerpt: String = line.chars().take(80).collect();
        let (byte, lineno) = pos();
        return Err(RylonError::parse(format!(
            "unterminated quote in record starting at byte {byte}, \
             line {lineno}: {excerpt:?}"
        )));
    }
    cells.push(cur);
    Ok(cells)
}

fn infer_dtype(samples: &[&str]) -> DataType {
    let non_empty: Vec<&&str> =
        samples.iter().filter(|s| !s.is_empty()).collect();
    if non_empty.is_empty() {
        return DataType::Utf8;
    }
    if non_empty
        .iter()
        .all(|s| s.trim().parse::<i64>().is_ok())
    {
        return DataType::Int64;
    }
    if non_empty
        .iter()
        .all(|s| s.trim().parse::<f64>().is_ok())
    {
        return DataType::Float64;
    }
    if non_empty.iter().all(|s| {
        matches!(s.trim(), "true" | "false" | "True" | "False")
    }) {
        return DataType::Bool;
    }
    DataType::Utf8
}

/// Infer the schema from the header (if any) and the first `infer_rows`
/// sampled records — shared by the whole-buffer and streamed readers so
/// both resolve identical types from identical samples.
pub(crate) fn infer_schema(
    header: Option<&Vec<String>>,
    sample_rows: &[Vec<String>],
) -> Result<Schema> {
    let width = header
        .map(|h| h.len())
        .or_else(|| sample_rows.first().map(|r| r.len()))
        .ok_or_else(|| RylonError::parse("empty csv"))?;
    let fields = (0..width)
        .map(|c| {
            let name = header
                .map(|h| h[c].clone())
                .unwrap_or_else(|| format!("c{c}"));
            let samples: Vec<&str> = sample_rows
                .iter()
                .map(|r| r.get(c).map(|s| s.as_str()).unwrap_or(""))
                .collect();
            Field::new(name, infer_dtype(&samples))
        })
        .collect();
    Ok(Schema::new(fields))
}

pub(crate) fn count_newlines(bytes: &[u8]) -> u64 {
    bytes.iter().filter(|&&b| b == b'\n').count() as u64
}

/// Boundary-scan DFA state. Three states suffice: a closing quote
/// (`"` seen inside a quoted field) behaves exactly like field start —
/// another `"` re-enters the quoted field (the `""` escape), a
/// delimiter/newline ends the field/record, anything else continues the
/// field unquoted — so the close-pending state collapses into
/// [`ScanState::FieldStart`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ScanState {
    /// Outside quotes, at the start of a field (a `"` here opens a
    /// quoted field — RFC 4180) or just after a closing quote (a `"`
    /// here is the `""` escape).
    FieldStart,
    /// Outside quotes, mid-field (a stray `"` here is a literal byte;
    /// `split_record` rejects the record later).
    Unquoted,
    /// Inside a quoted field (newlines and delimiters are data).
    Quoted,
}

/// The three possible chunk-entry states, in [`hyp_index`] order.
pub(crate) const HYPOTHESES: [ScanState; 3] =
    [ScanState::FieldStart, ScanState::Unquoted, ScanState::Quoted];

pub(crate) fn hyp_index(s: ScanState) -> usize {
    match s {
        ScanState::FieldStart => 0,
        ScanState::Unquoted => 1,
        ScanState::Quoted => 2,
    }
}

/// Inverse of [`hyp_index`] — used to decode scan states off the wire
/// in the distributed summary exchange.
pub(crate) fn state_from_index(i: u8) -> Option<ScanState> {
    match i {
        0 => Some(ScanState::FieldStart),
        1 => Some(ScanState::Unquoted),
        2 => Some(ScanState::Quoted),
        _ => None,
    }
}

/// One DFA transition. A newline is a record boundary iff the current
/// state is not [`ScanState::Quoted`] (emission is checked by callers).
#[inline]
fn step(s: ScanState, b: u8, d: u8) -> ScanState {
    match s {
        ScanState::Quoted => {
            if b == b'"' {
                ScanState::FieldStart
            } else {
                ScanState::Quoted
            }
        }
        ScanState::FieldStart => {
            if b == b'"' {
                ScanState::Quoted
            } else if b == b'\n' || b == d {
                ScanState::FieldStart
            } else {
                ScanState::Unquoted
            }
        }
        ScanState::Unquoted => {
            if b == b'\n' || b == d {
                ScanState::FieldStart
            } else {
                ScanState::Unquoted
            }
        }
    }
}

/// Serial DFA scan of `bytes[range]` from a known entry state: newline
/// boundary offsets (absolute into `bytes`) and the exit state.
fn scan_range_serial(
    bytes: &[u8],
    range: Range<usize>,
    d: u8,
    entry: ScanState,
) -> (Vec<usize>, ScanState) {
    let mut state = entry;
    let mut nls = Vec::new();
    for i in range {
        let b = bytes[i];
        if b == b'\n' && state != ScanState::Quoted {
            nls.push(i);
        }
        state = step(state, b, d);
    }
    (nls, state)
}

/// Per-range summary of the speculative scan: for each of the three
/// possible entry states, the boundaries that range would emit and the
/// state it would exit in.
pub(crate) struct ScanSummary {
    /// Exit state per entry hypothesis ([`hyp_index`] order).
    pub(crate) exit: [ScanState; 3],
    /// Boundary-newline offsets per entry hypothesis (absolute into the
    /// scanned buffer).
    pub(crate) nls: [Vec<usize>; 3],
}

fn scan_range_speculative(
    bytes: &[u8],
    range: Range<usize>,
    d: u8,
) -> ScanSummary {
    let mut cur = HYPOTHESES;
    let mut nls: [Vec<usize>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for i in range {
        let b = bytes[i];
        if b == b'\n' {
            for (c, nl) in cur.iter_mut().zip(nls.iter_mut()) {
                if *c != ScanState::Quoted {
                    nl.push(i);
                }
                *c = step(*c, b, d);
            }
        } else {
            for c in cur.iter_mut() {
                *c = step(*c, b, d);
            }
        }
    }
    ScanSummary { exit: cur, nls }
}

/// Full-buffer speculative scan: the boundary newlines and exit state
/// `bytes` would produce under **each** of the three possible entry
/// states. Parallel under the calling thread's intra-op budget
/// (sub-range summaries compose by threading each hypothesis's state
/// through the pieces); bit-identical to the serial speculative scan.
/// This is the per-rank half of the distributed byte-range ingest: a
/// rank that cannot know its entry state yet scans once under all
/// three and lets the summary exchange pick the truth.
pub(crate) fn scan_summary(bytes: &[u8], d: u8) -> ScanSummary {
    let exec = exec::parallelism_for(bytes.len());
    if !exec.is_parallel() || bytes.len() < 2 * exec.threads() {
        return scan_range_speculative(bytes, 0..bytes.len(), d);
    }
    let parts = exec::split_even(bytes.len(), exec.threads());
    let summaries: Vec<ScanSummary> = exec::map_parallel(parts, |m| {
        scan_range_speculative(bytes, m.range(), d)
    });
    let mut out = ScanSummary {
        exit: HYPOTHESES,
        nls: [Vec::new(), Vec::new(), Vec::new()],
    };
    for h in 0..3 {
        let mut state = HYPOTHESES[h];
        for s in &summaries {
            let i = hyp_index(state);
            out.nls[h].extend_from_slice(&s.nls[i]);
            state = s.exit[i];
        }
        out.exit[h] = state;
    }
    out
}

/// Record-boundary scan of `bytes` from `entry`: the offsets of every
/// record-terminating newline, and the scan state after the last byte.
/// Parallel (speculative) under the calling thread's intra-op budget
/// when the buffer is at least `par_row_threshold` bytes; bit-identical
/// to the serial scan either way. `d` must be an ASCII delimiter byte.
/// Also the known-entry fast path of the distributed single-pass scan
/// (a rank whose range starts at byte 0 needs no hypotheses).
pub(crate) fn scan_boundaries(
    bytes: &[u8],
    d: u8,
    entry: ScanState,
) -> (Vec<usize>, ScanState) {
    let exec = exec::parallelism_for(bytes.len());
    if !exec.is_parallel() || bytes.len() < 2 * exec.threads() {
        return scan_range_serial(bytes, 0..bytes.len(), d, entry);
    }
    let parts = exec::split_even(bytes.len(), exec.threads());
    let summaries: Vec<ScanSummary> =
        exec::map_parallel(parts, |m| {
            scan_range_speculative(bytes, m.range(), d)
        });
    // Prefix pass: thread the true entry state through the per-range
    // summaries, keeping each range's newline list for the state it was
    // actually entered in.
    let mut state = entry;
    let mut out = Vec::new();
    for s in &summaries {
        let h = hyp_index(state);
        out.extend_from_slice(&s.nls[h]);
        state = s.exit[h];
    }
    (out, state)
}

/// Whole-buffer record scan for a **multi-byte (non-ASCII) delimiter**:
/// the byte-at-a-time DFA cannot track a delimiter that spans bytes, so
/// this keeps the legacy field-start-aware loop. A quoted field opens
/// only at field start and `""` inside it is an escaped quote; one
/// trailing `\r` per record is stripped; empty lines are skipped.
fn scan_records_multibyte(buf: &str, delim: char) -> Vec<(usize, usize)> {
    let bytes = buf.as_bytes();
    let mut dbuf = [0u8; 4];
    let d = delim.encode_utf8(&mut dbuf).as_bytes();
    let mut out = Vec::new();
    let mut start = 0usize;
    let mut in_quotes = false;
    let mut at_field_start = true;
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        if in_quotes {
            if b == b'"' {
                if bytes.get(i + 1) == Some(&b'"') {
                    i += 2; // escaped quote, stay quoted
                    continue;
                }
                in_quotes = false; // field continues unquoted
            }
            i += 1;
            continue;
        }
        if b == b'"' && at_field_start {
            in_quotes = true;
            at_field_start = false;
            i += 1;
            continue;
        }
        if b == b'\n' {
            push_record_range(&mut out, bytes, start, i);
            start = i + 1;
            at_field_start = true;
            i += 1;
            continue;
        }
        if b == d[0] && bytes[i..].starts_with(d) {
            at_field_start = true;
            i += d.len();
            continue;
        }
        at_field_start = false;
        i += 1;
    }
    // An unterminated quote runs to EOF; `split_record` rejects it.
    push_record_range(&mut out, bytes, start, bytes.len());
    out
}

/// Pass 1: byte ranges of the records in `buf`. A newline splits
/// records only outside a quoted field (so quoted fields may contain
/// newlines); one trailing `\r` per record is stripped; empty lines are
/// skipped. Quote and newline are ASCII, so the byte scan is UTF-8
/// safe. ASCII delimiters take the (possibly speculative-parallel) DFA
/// scan; multi-byte delimiters keep the serial legacy loop.
fn scan_records(buf: &str, delim: char) -> Vec<(usize, usize)> {
    if !delim.is_ascii() {
        return scan_records_multibyte(buf, delim);
    }
    let bytes = buf.as_bytes();
    let (nls, _exit) =
        scan_boundaries(bytes, delim as u8, ScanState::FieldStart);
    let mut out = Vec::with_capacity(nls.len() + 1);
    let mut start = 0usize;
    for &nl in &nls {
        push_record_range(&mut out, bytes, start, nl);
        start = nl + 1;
    }
    push_record_range(&mut out, bytes, start, bytes.len());
    out
}

pub(crate) fn push_record_range(
    out: &mut Vec<(usize, usize)>,
    bytes: &[u8],
    start: usize,
    mut end: usize,
) {
    if end > start && bytes[end - 1] == b'\r' {
        end -= 1;
    }
    if end > start {
        out.push((start, end));
    }
}

/// Pass 2 worker: parse a run of whole records into columns.
/// `first_record` is the chunk's absolute record index (for error
/// messages that match a serial parse); `byte_base`/`line_base` locate
/// `buf[0]` in the underlying file (0 for whole-buffer parses) so
/// unterminated-quote errors report absolute positions.
fn parse_records(
    buf: &str,
    ranges: &[(usize, usize)],
    schema: &Schema,
    first_record: usize,
    delim: char,
    byte_base: u64,
    line_base: u64,
) -> Result<Table> {
    let mut builders: Vec<ColumnBuilder> = schema
        .fields()
        .iter()
        .map(|f| ColumnBuilder::new(f.dtype, ranges.len()))
        .collect();
    for (k, &(s, e)) in ranges.iter().enumerate() {
        let rec = split_record(&buf[s..e], delim, || {
            record_pos(buf, s, byte_base, line_base)
        })?;
        if rec.len() != schema.len() {
            return Err(RylonError::parse(format!(
                "record {} has {} cells, schema has {}",
                first_record + k + 1,
                rec.len(),
                schema.len()
            )));
        }
        for (b, cell) in builders.iter_mut().zip(&rec) {
            b.push_parse(cell)?;
        }
    }
    Table::try_new(
        schema.clone(),
        builders.into_iter().map(|b| b.finish()).collect(),
    )
}

/// Absolute (byte offset, 1-based line number) of the record starting
/// at `buf[s]` — computed lazily, only on the error path.
pub(crate) fn record_pos(
    buf: &str,
    s: usize,
    byte_base: u64,
    line_base: u64,
) -> (u64, u64) {
    (
        byte_base + s as u64,
        line_base + count_newlines(&buf.as_bytes()[..s]) + 1,
    )
}

/// Parse a run of whole records morsel-parallel: the ranges are split
/// into per-worker chunks, each parsed with [`parse_records`], and the
/// chunk tables concatenate in range order — bit-identical to a serial
/// parse, with the first error in record order winning.
/// `first_record` is the absolute ordinal (header included) of
/// `ranges[0]`; `byte_base`/`line_base` locate `buf[0]` in the file.
pub(crate) fn parse_ranges_parallel(
    buf: &str,
    ranges: &[(usize, usize)],
    schema: &Schema,
    first_record: usize,
    delim: char,
    byte_base: u64,
    line_base: u64,
) -> Result<Table> {
    if ranges.is_empty() {
        return Ok(Table::empty(schema.clone()));
    }
    let exec = exec::parallelism_for(ranges.len());
    let chunks = exec::split_even(ranges.len(), exec.threads());
    let parts: Vec<Result<Table>> = exec::map_parallel(chunks, |m| {
        parse_records(
            buf,
            &ranges[m.range()],
            schema,
            first_record + m.start,
            delim,
            byte_base,
            line_base,
        )
    });
    let tables = parts.into_iter().collect::<Result<Vec<Table>>>()?;
    Table::concat_all(schema, &tables)
}

/// Read a CSV from any reader — **streaming**: the source is consumed
/// in [`crate::exec::ingest_chunk_bytes`]-sized chunks, so peak
/// raw-text memory is bounded by the chunk size (plus the longest
/// single record), not the file size. Bit-identical to
/// [`read_csv_str`] on the same bytes. Non-ASCII delimiters fall back
/// to a whole-buffer read (a multi-byte delimiter may straddle a chunk
/// seam).
pub fn read_csv_from<R: Read>(reader: R, opts: &CsvOptions) -> Result<Table> {
    let mut parts: Vec<Table> = Vec::new();
    let schema = read_csv_chunked(reader, opts, |t| {
        parts.push(t);
        Ok(())
    })?;
    if parts.is_empty() {
        return Ok(Table::empty(schema));
    }
    Table::concat_all(&schema, &parts)
}

/// Streaming driver: parse the CSV chunk by chunk and hand each chunk's
/// table to `sink` in file order, never holding more than one chunk of
/// raw text (plus the parsed output the sink retains). Returns the
/// resolved schema, so an empty input still yields one. The backbone of
/// [`read_csv_from`] and the bounded-memory CSV→RYF conversion.
pub fn read_csv_chunked<R: Read>(
    reader: R,
    opts: &CsvOptions,
    mut sink: impl FnMut(Table) -> Result<()>,
) -> Result<Schema> {
    if !opts.delimiter.is_ascii() {
        let mut buf = String::new();
        BufReader::new(reader).read_to_string(&mut buf)?;
        let t = read_csv_str(&buf, opts)?;
        let schema = t.schema().clone();
        if t.num_rows() > 0 {
            sink(t)?;
        }
        return Ok(schema);
    }
    stream_csv(reader, opts, None, &mut sink)
}

/// Count the data records (excluding the header) in a CSV without
/// parsing cells — a streaming boundary scan only, no record
/// materialisation (the chunk buffer is the only allocation). Used by
/// the distributed ingest path to block-partition records across
/// ranks; must skip exactly the records `push_record_range` skips
/// (empty lines, lone-`\r` lines) so the count matches the parse.
pub fn count_csv_records<R: Read>(mut reader: R, opts: &CsvOptions) -> Result<usize> {
    if !opts.delimiter.is_ascii() {
        let mut buf = String::new();
        BufReader::new(reader).read_to_string(&mut buf)?;
        let n = scan_records(&buf, opts.delimiter).len();
        return Ok(n.saturating_sub(opts.has_header as usize));
    }
    let d = opts.delimiter as u8;
    let mut scratch = vec![0u8; exec::ingest_chunk_bytes().max(1)];
    let mut state = ScanState::FieldStart;
    // Bytes of the current record seen in earlier chunks, and the last
    // byte seen overall (for the lone-`\r` check when a record's only
    // byte sits in the previous chunk).
    let mut pending_len = 0usize;
    let mut prev_byte = 0u8;
    let mut n = 0usize;
    loop {
        let m = read_full(&mut reader, &mut scratch)?;
        if m == 0 {
            break;
        }
        let (nls, exit) = scan_boundaries(&scratch[..m], d, state);
        state = exit;
        // Record start relative to this chunk (negative while the
        // record began in an earlier chunk).
        let mut rec_start = -(pending_len as i64);
        for &nl in &nls {
            let len = nl as i64 - rec_start;
            let only = if nl == 0 { prev_byte } else { scratch[nl - 1] };
            if !(len == 0 || (len == 1 && only == b'\r')) {
                n += 1;
            }
            rec_start = nl as i64 + 1;
        }
        pending_len = (m as i64 - rec_start) as usize;
        prev_byte = scratch[m - 1];
    }
    // Trailing record with no final newline.
    if pending_len > 0 && !(pending_len == 1 && prev_byte == b'\r') {
        n += 1;
    }
    Ok(n.saturating_sub(opts.has_header as usize))
}

/// Read only data records with global index in `records` (0-based,
/// header excluded), streaming the records before the block past
/// without parsing and **stopping at the end of the block** (the scan
/// never runs to EOF once every selected record is out) — the per-rank
/// partitioned ingest: rank memory is O(chunk + its own block) and
/// rank I/O ends at its own block, never the whole file. Schema
/// inference still samples the first `infer_rows` records of the
/// *file* (reading continues that far even past a shorter block), so
/// every rank resolves the same schema as a whole-file read.
pub fn read_csv_records<R: Read>(
    reader: R,
    opts: &CsvOptions,
    records: Range<usize>,
) -> Result<Table> {
    let mut parts: Vec<Table> = Vec::new();
    let schema =
        read_csv_records_chunked(reader, opts, records, |t| {
            parts.push(t);
            Ok(())
        })?;
    if parts.is_empty() {
        return Ok(Table::empty(schema));
    }
    Table::concat_all(&schema, &parts)
}

/// Chunked-sink form of [`read_csv_records`]: the selected block's
/// records are handed to `sink` one parsed chunk at a time (file
/// order), so a consumer that forwards or reduces the rows — the
/// two-pass distributed ingest, a converter — never holds more than
/// one chunk of parsed output beyond what it retains itself. Returns
/// the resolved schema (an empty selection still yields one).
/// Non-ASCII delimiters fall back to a whole-buffer read sunk as one
/// table.
pub fn read_csv_records_chunked<R: Read>(
    reader: R,
    opts: &CsvOptions,
    records: Range<usize>,
    mut sink: impl FnMut(Table) -> Result<()>,
) -> Result<Schema> {
    if !opts.delimiter.is_ascii() {
        let mut buf = String::new();
        BufReader::new(reader).read_to_string(&mut buf)?;
        let t = read_csv_str(&buf, opts)?;
        let lo = records.start.min(t.num_rows());
        // Clamp inverted ranges to empty, like the streaming path.
        let hi = records.end.min(t.num_rows()).max(lo);
        let schema = t.schema().clone();
        if hi > lo {
            sink(t.slice(lo, hi - lo))?;
        }
        return Ok(schema);
    }
    stream_csv(reader, opts, Some(records), &mut sink)
}

/// Fill `buf` from `reader`, retrying short reads; returns the bytes
/// read (< `buf.len()` only at EOF).
pub(crate) fn read_full<R: Read>(reader: &mut R, buf: &mut [u8]) -> Result<usize> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match reader.read(&mut buf[filled..]) {
            Ok(0) => break,
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(filled)
}

/// One row-aligned run of complete records cut from the byte stream.
struct Segment {
    /// The raw text of the complete records (UTF-8 validated).
    text: String,
    /// Record byte ranges within `text` (empty lines already skipped,
    /// trailing `\r` already stripped).
    ranges: Vec<(usize, usize)>,
    /// Absolute ordinal (0-based, header included) of `ranges[0]`.
    first_record: usize,
    /// File byte offset of `text[0]`.
    byte_base: u64,
    /// Raw `\n` count in the file before `text[0]`.
    line_base: u64,
}

/// Chunked boundary scanner: reads fixed-size chunks, carries the DFA
/// state across seams, and yields row-aligned [`Segment`]s. The bytes
/// of the trailing partial record are kept (never rescanned — the
/// carried state already summarises them), so memory is bounded by the
/// chunk size plus the longest single record.
struct CsvChunkScanner<R: Read> {
    reader: R,
    delim: u8,
    /// Reusable chunk buffer (allocated once, `ingest_chunk_bytes`
    /// long).
    scratch: Vec<u8>,
    /// Partial trailing record (always starts at a record start).
    pending: Vec<u8>,
    /// Scan state after the last byte of `pending`.
    state: ScanState,
    byte_base: u64,
    line_base: u64,
    records_seen: usize,
    eof: bool,
}

impl<R: Read> CsvChunkScanner<R> {
    fn new(reader: R, delim: u8) -> CsvChunkScanner<R> {
        CsvChunkScanner {
            reader,
            delim,
            scratch: vec![0u8; exec::ingest_chunk_bytes().max(1)],
            pending: Vec::new(),
            state: ScanState::FieldStart,
            byte_base: 0,
            line_base: 0,
            records_seen: 0,
            eof: false,
        }
    }

    fn make_segment(
        &mut self,
        text_bytes: Vec<u8>,
        ranges: Vec<(usize, usize)>,
    ) -> Result<Segment> {
        let text = String::from_utf8(text_bytes).map_err(|_| {
            RylonError::parse(format!(
                "csv: invalid utf-8 near byte {}",
                self.byte_base
            ))
        })?;
        let seg = Segment {
            first_record: self.records_seen,
            byte_base: self.byte_base,
            line_base: self.line_base,
            ranges,
            text,
        };
        self.records_seen += seg.ranges.len();
        self.byte_base += seg.text.len() as u64;
        self.line_base += count_newlines(seg.text.as_bytes());
        Ok(seg)
    }

    fn next_segment(&mut self) -> Result<Option<Segment>> {
        loop {
            if self.eof {
                if self.pending.is_empty() {
                    return Ok(None);
                }
                // The remainder is one final record (an unterminated
                // quote reaches here too; `split_record` rejects it).
                let bytes = std::mem::take(&mut self.pending);
                let mut ranges = Vec::new();
                push_record_range(&mut ranges, &bytes, 0, bytes.len());
                if ranges.is_empty() {
                    // Lone "\r" or nothing parseable: consume silently,
                    // exactly like the whole-buffer scan.
                    return Ok(None);
                }
                let seg = self.make_segment(bytes, ranges)?;
                return Ok(Some(seg));
            }
            let mut scratch = std::mem::take(&mut self.scratch);
            let n = read_full(&mut self.reader, &mut scratch)?;
            let fresh_start = self.pending.len();
            self.pending.extend_from_slice(&scratch[..n]);
            self.scratch = scratch;
            if n == 0 {
                self.eof = true;
                continue;
            }
            // Scan only the fresh bytes: the carried state already
            // covers `pending`, so total scan work stays O(file).
            let (rel, exit) = {
                let fresh = &self.pending[fresh_start..];
                scan_boundaries(fresh, self.delim, self.state)
            };
            self.state = exit;
            if rel.is_empty() {
                continue; // no complete record yet; keep accumulating
            }
            let nls: Vec<usize> =
                rel.iter().map(|&i| i + fresh_start).collect();
            let cut = *nls.last().expect("non-empty boundary list") + 1;
            let mut ranges = Vec::new();
            let mut start = 0usize;
            for &nl in &nls {
                push_record_range(&mut ranges, &self.pending, start, nl);
                start = nl + 1;
            }
            let tail = self.pending[cut..].to_vec();
            let mut bytes = std::mem::take(&mut self.pending);
            bytes.truncate(cut);
            self.pending = tail;
            if ranges.is_empty() {
                // Only empty lines in this cut; account for the
                // consumed bytes and keep reading.
                self.byte_base += cut as u64;
                self.line_base += count_newlines(&bytes);
                continue;
            }
            let seg = self.make_segment(bytes, ranges)?;
            return Ok(Some(seg));
        }
    }
}

/// The streaming core: scan → (header, inference) → chunk-parallel
/// parse → sink, with chunks held only until the schema is resolved.
/// `take` restricts parsing to data records with global index in the
/// range — and also bounds the *scan*: once every selected record is
/// out and the schema is resolved, reading stops. Bytes past that
/// point are never scanned or validated (a malformed record or bad
/// UTF-8 after the range does not surface), so with `take` the stream
/// is covered only through the later of the range's end and the
/// inference sample.
fn stream_csv<R: Read>(
    reader: R,
    opts: &CsvOptions,
    take: Option<Range<usize>>,
    sink: &mut dyn FnMut(Table) -> Result<()>,
) -> Result<Schema> {
    let header_rows = opts.has_header as usize;
    let mut scanner = CsvChunkScanner::new(reader, opts.delimiter as u8);
    let mut header: Option<Vec<String>> = None;
    let mut header_pending = opts.has_header;
    let mut schema: Option<Schema> = opts.schema.clone();
    let mut samples: Vec<Vec<String>> = Vec::new();
    let mut held: Vec<Segment> = Vec::new();

    while let Some(mut seg) = scanner.next_segment()? {
        if header_pending {
            let (s, e) = seg.ranges[0];
            header = Some(split_record(
                &seg.text[s..e],
                opts.delimiter,
                || record_pos(&seg.text, s, seg.byte_base, seg.line_base),
            )?);
            seg.ranges.remove(0);
            seg.first_record += 1;
            header_pending = false;
            if seg.ranges.is_empty() {
                continue;
            }
        }
        if schema.is_none() {
            // Sample the first `infer_rows` data records, exactly like
            // the whole-buffer reader (so split errors surface in the
            // same order and inference sees the same cells).
            for &(s, e) in
                seg.ranges.iter().take(opts.infer_rows - samples.len())
            {
                samples.push(split_record(
                    &seg.text[s..e],
                    opts.delimiter,
                    || record_pos(&seg.text, s, seg.byte_base, seg.line_base),
                )?);
            }
            if samples.len() >= opts.infer_rows {
                schema = Some(infer_schema(header.as_ref(), &samples)?);
            } else {
                held.push(seg);
                continue;
            }
        }
        let sch = schema.as_ref().expect("schema resolved");
        for h in held.drain(..) {
            if let Some(t) =
                parse_segment(&h, sch, opts, header_rows, take.as_ref())?
            {
                sink(t)?;
            }
        }
        if let Some(t) =
            parse_segment(&seg, sch, opts, header_rows, take.as_ref())?
        {
            sink(t)?;
        }
        if let Some(r) = take.as_ref() {
            // Every selected record is out (and the schema resolved —
            // this point is only reached with `schema` set): stop
            // reading instead of streaming the scan to EOF. A
            // range-reading rank's bytes end at its own block, not at
            // the end of the file.
            let data_seen =
                seg.first_record + seg.ranges.len() - header_rows;
            if data_seen >= r.end {
                return Ok(schema.expect("schema resolved"));
            }
        }
    }
    // EOF with fewer than `infer_rows` records: infer from what we saw.
    if schema.is_none() {
        schema = Some(infer_schema(header.as_ref(), &samples)?);
        let sch = schema.as_ref().expect("schema resolved");
        for h in held.drain(..) {
            if let Some(t) =
                parse_segment(&h, sch, opts, header_rows, take.as_ref())?
            {
                sink(t)?;
            }
        }
    }
    Ok(schema.expect("schema resolved"))
}

/// Parse one segment's data records (filtered by `take`) on the worker
/// pool. Returns `None` when the filter selects nothing.
fn parse_segment(
    seg: &Segment,
    schema: &Schema,
    opts: &CsvOptions,
    header_rows: usize,
    take: Option<&Range<usize>>,
) -> Result<Option<Table>> {
    // Data index of the segment's first record (the header was removed
    // before any segment reaches here).
    let data_first = seg.first_record - header_rows;
    let (lo, hi) = match take {
        Some(r) => {
            let lo = r.start.saturating_sub(data_first).min(seg.ranges.len());
            let hi = r.end.saturating_sub(data_first).min(seg.ranges.len());
            (lo, hi.max(lo))
        }
        None => (0, seg.ranges.len()),
    };
    let ranges = &seg.ranges[lo..hi];
    if ranges.is_empty() {
        return Ok(None);
    }
    // The absolute ordinal of ranges[0], for error messages that match
    // a whole-buffer serial parse.
    let first_ord = seg.first_record + lo;
    Ok(Some(parse_ranges_parallel(
        &seg.text,
        ranges,
        schema,
        first_ord,
        opts.delimiter,
        seg.byte_base,
        seg.line_base,
    )?))
}

/// Parse CSV text already in memory — the whole-buffer two-pass reader.
/// Pass 1 (the boundary scan) runs the speculative parallel scan on
/// large buffers; pass 2 parses row-aligned chunks on the worker pool.
/// Bit-identical to a serial parse at any thread count.
pub fn read_csv_str(buf: &str, opts: &CsvOptions) -> Result<Table> {
    let ranges = scan_records(buf, opts.delimiter);
    let has_header = opts.has_header && !ranges.is_empty();
    let header: Option<Vec<String>> = if has_header {
        let (s, e) = ranges[0];
        Some(split_record(&buf[s..e], opts.delimiter, || {
            record_pos(buf, s, 0, 0)
        })?)
    } else {
        None
    };
    // Data records: everything past the header row (slice, no shift).
    let records = &ranges[has_header as usize..];

    // Establish the schema (inference samples the first `infer_rows`
    // records, exactly like the serial reader).
    let schema = match &opts.schema {
        Some(s) => s.clone(),
        None => {
            let mut sample_rows: Vec<Vec<String>> =
                Vec::with_capacity(opts.infer_rows.min(records.len()));
            for &(s, e) in records.iter().take(opts.infer_rows) {
                sample_rows.push(split_record(
                    &buf[s..e],
                    opts.delimiter,
                    || record_pos(buf, s, 0, 0),
                )?);
            }
            infer_schema(header.as_ref(), &sample_rows)?
        }
    };

    if records.is_empty() {
        return Ok(Table::empty(schema));
    }

    // Pass 2: chunked parse — each chunk is a run of whole records;
    // chunks concatenate in file order. The first error in record
    // order wins, matching a serial scan.
    parse_ranges_parallel(
        buf,
        records,
        &schema,
        opts.has_header as usize,
        opts.delimiter,
        0,
        0,
    )
}

/// Read a CSV file (streaming — see [`read_csv_from`]).
pub fn read_csv(path: impl AsRef<Path>, opts: &CsvOptions) -> Result<Table> {
    let f = std::fs::File::open(path)?;
    read_csv_from(f, opts)
}

fn needs_quoting(s: &str, delim: char) -> bool {
    s.contains(delim) || s.contains('"') || s.contains('\n')
}

/// Incremental CSV writer: emits the header once on construction, then
/// appends tables (row groups, streamed chunks) across any number of
/// [`CsvWriter::append`] calls — the egress mirror of
/// [`read_csv_chunked`], and what the CLI's streaming RYF→CSV
/// conversion writes through so the whole table is never resident.
/// Output is byte-identical to a single [`write_csv_to`] of the
/// concatenated input.
pub struct CsvWriter<W: Write> {
    w: BufWriter<W>,
    delimiter: char,
    cell: String,
}

impl<W: Write> CsvWriter<W> {
    /// Wrap `writer`, immediately writing `schema`'s header row when
    /// `opts.has_header`. Header names quote by the same rule as data
    /// cells, so a column name containing the delimiter, a quote, or a
    /// newline survives a write → re-read roundtrip.
    pub fn new(
        writer: W,
        schema: &Schema,
        opts: &CsvOptions,
    ) -> Result<CsvWriter<W>> {
        let mut w = BufWriter::new(writer);
        if opts.has_header {
            let names: Vec<String> = schema
                .fields()
                .iter()
                .map(|f| {
                    if needs_quoting(&f.name, opts.delimiter) {
                        format!("\"{}\"", f.name.replace('"', "\"\""))
                    } else {
                        f.name.clone()
                    }
                })
                .collect();
            writeln!(w, "{}", names.join(&opts.delimiter.to_string()))?;
        }
        Ok(CsvWriter {
            w,
            delimiter: opts.delimiter,
            cell: String::new(),
        })
    }

    /// Append every row of `table` (no header row is emitted).
    pub fn append(&mut self, table: &Table) -> Result<()> {
        let d = self.delimiter;
        for r in 0..table.num_rows() {
            for c in 0..table.num_columns() {
                if c > 0 {
                    write!(self.w, "{d}")?;
                }
                self.cell.clear();
                self.cell.push_str(&table.column(c).value(r).render());
                if needs_quoting(&self.cell, d) {
                    write!(self.w, "\"{}\"", self.cell.replace('"', "\"\""))?;
                } else {
                    write!(self.w, "{}", self.cell)?;
                }
            }
            writeln!(self.w)?;
        }
        Ok(())
    }

    /// Flush buffered output to the underlying writer.
    pub fn finish(mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Write a table to any writer.
pub fn write_csv_to<W: Write>(
    table: &Table,
    writer: W,
    opts: &CsvOptions,
) -> Result<()> {
    let mut w = CsvWriter::new(writer, table.schema(), opts)?;
    w.append(table)?;
    w.finish()
}

/// Write a table to a CSV file.
pub fn write_csv(
    table: &Table,
    path: impl AsRef<Path>,
    opts: &CsvOptions,
) -> Result<()> {
    let f = std::fs::File::create(path)?;
    write_csv_to(table, f, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::types::Value;

    #[test]
    fn read_with_inference() {
        let data = "id,price,name,ok\n1,2.5,apple,true\n2,,\"b,c\",false\n";
        let t = read_csv_from(data.as_bytes(), &CsvOptions::default())
            .unwrap();
        assert_eq!(t.num_rows(), 2);
        assert_eq!(t.schema().field(0).dtype, DataType::Int64);
        assert_eq!(t.schema().field(1).dtype, DataType::Float64);
        assert_eq!(t.schema().field(2).dtype, DataType::Utf8);
        assert_eq!(t.schema().field(3).dtype, DataType::Bool);
        assert_eq!(t.column(1).value(1), Value::Null);
        assert_eq!(t.column(2).value(1), Value::Utf8("b,c".into()));
    }

    #[test]
    fn explicit_schema_and_no_header() {
        let data = "1,x\n2,y\n";
        let opts = CsvOptions::default()
            .no_header()
            .with_schema(Schema::parse("a:i64,b:str").unwrap());
        let t = read_csv_from(data.as_bytes(), &opts).unwrap();
        assert_eq!(t.num_rows(), 2);
        assert_eq!(t.column(0).i64_values(), &[1, 2]);
    }

    #[test]
    fn quoted_quotes_and_roundtrip() {
        let t = Table::from_columns(vec![
            ("s", Column::from_str(&["plain", "has,comma", "has\"quote"])),
            ("v", Column::from_opt_i64(vec![Some(1), None, Some(3)])),
        ])
        .unwrap();
        let mut buf = Vec::new();
        write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
        let opts = CsvOptions::default()
            .with_schema(Schema::parse("s:str,v:i64").unwrap());
        let back = read_csv_from(&buf[..], &opts).unwrap();
        assert_eq!(back.column(0).as_utf8().value(1), "has,comma");
        assert_eq!(back.column(0).as_utf8().value(2), "has\"quote");
        assert_eq!(back.column(1).value(1), Value::Null);
    }

    #[test]
    fn ragged_record_rejected() {
        let data = "a,b\n1,2\n3\n";
        assert!(read_csv_from(data.as_bytes(), &CsvOptions::default())
            .is_err());
    }

    #[test]
    fn bad_literal_with_schema_rejected() {
        let data = "a\nxyz\n";
        let opts = CsvOptions::default()
            .with_schema(Schema::parse("a:i64").unwrap());
        assert!(read_csv_from(data.as_bytes(), &opts).is_err());
    }

    #[test]
    fn unterminated_quote_rejected() {
        let data = "a\n\"oops\n";
        assert!(read_csv_from(data.as_bytes(), &CsvOptions::default())
            .is_err());
    }

    #[test]
    fn stray_quote_mid_field_fails_fast() {
        // A bare quote inside an unquoted field is malformed: the
        // field-start-aware scan must not let it swallow the following
        // rows — the record still fails in `split_record`.
        let data = "a,b\n1,2\"x\n3,4\n";
        assert!(read_csv_from(data.as_bytes(), &CsvOptions::default())
            .is_err());
    }

    #[test]
    fn stray_quote_error_reports_byte_and_line() {
        // The fast-fail must point at the offending record: absolute
        // byte offset and 1-based line number, identical from the
        // whole-buffer and the streamed reader at any chunk size.
        let data = "a,b\n1,2\"x\n3,4\n";
        let want = "parse error: unterminated quote in record starting \
                    at byte 4, line 2: \"1,2\\\"x\"";
        let whole = read_csv_str(data, &CsvOptions::default()).unwrap_err();
        assert_eq!(whole.to_string(), want);
        for chunk in [1usize, 3, 64] {
            let streamed = crate::exec::with_ingest_chunk_bytes(chunk, || {
                read_csv_from(data.as_bytes(), &CsvOptions::default())
                    .unwrap_err()
            });
            assert_eq!(streamed.to_string(), want, "chunk {chunk}");
        }
    }

    #[test]
    fn stray_quote_error_counts_quoted_newlines_as_lines() {
        // A quoted newline in an earlier record still advances the
        // reported line number (lines are raw `\n`s, not records).
        let data = "s,v\n\"a\nb\",1\nx,2\"y\n";
        let err = read_csv_str(data, &CsvOptions::default()).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("at byte 12, line 4"),
            "unexpected message: {msg}"
        );
    }

    #[test]
    fn escaped_quote_before_newline_stays_quoted() {
        // `""` inside a quoted field is an escaped quote, not a close:
        // the newline after it is still part of the field.
        let data = "s,v\n\"a\"\"\nb\",1\n";
        let opts = CsvOptions::default()
            .with_schema(Schema::parse("s:str,v:i64").unwrap());
        let t = read_csv_from(data.as_bytes(), &opts).unwrap();
        assert_eq!(t.num_rows(), 1);
        assert_eq!(t.column(0).as_utf8().value(0), "a\"\nb");
    }

    #[test]
    fn quoted_newline_roundtrip() {
        // The quote-aware boundary scan keeps newlines inside quoted
        // fields (RFC 4180), so multi-line strings survive a roundtrip.
        let t = Table::from_columns(vec![
            ("s", Column::from_str(&["multi\nline", "crlf\r\nfield", "plain"])),
            ("v", Column::from_i64(vec![1, 2, 3])),
        ])
        .unwrap();
        let mut buf = Vec::new();
        write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
        let opts = CsvOptions::default()
            .with_schema(Schema::parse("s:str,v:i64").unwrap());
        let back = read_csv_from(&buf[..], &opts).unwrap();
        assert_eq!(back.num_rows(), 3);
        assert_eq!(back.column(0).as_utf8().value(0), "multi\nline");
        assert_eq!(back.column(0).as_utf8().value(1), "crlf\r\nfield");
    }

    #[test]
    fn parallel_parse_is_bit_identical() {
        // Quoted/multibyte/ragged-null fixture, parsed at several
        // thread counts with the threshold forced down so the parallel
        // path engages on a small input.
        let mut data = String::from("id,name,score,flag\n");
        for i in 0..500 {
            let name = match i % 4 {
                0 => format!("\"quoted,{i}\""),
                1 => format!("日本語{i}"),
                2 => String::new(), // null cell
                _ => format!("\"with \"\"quotes\"\" {i}\""),
            };
            let score = if i % 5 == 0 {
                String::new() // null cell
            } else {
                format!("{}.25", i)
            };
            data.push_str(&format!("{i},{name},{score},{}\n", i % 2 == 0));
        }
        let serial = crate::exec::with_intra_op_threads(1, || {
            read_csv_str(&data, &CsvOptions::default()).unwrap()
        });
        for threads in [2, 4, 8] {
            let par = crate::exec::with_intra_op_threads(threads, || {
                crate::exec::with_par_row_threshold(1, || {
                    read_csv_str(&data, &CsvOptions::default()).unwrap()
                })
            });
            assert_eq!(par, serial, "csv parse diverged at {threads} threads");
        }
        assert_eq!(serial.num_rows(), 500);
        assert_eq!(serial.schema().field(2).dtype, DataType::Float64);
        assert_eq!(serial.column(1).null_count(), 125);
    }

    #[test]
    fn streamed_parse_matches_whole_buffer_at_tiny_chunks() {
        // Chunk seams fall inside quoted fields, escaped quotes, CRLF
        // pairs, and multibyte characters; every chunk size must still
        // reproduce the whole-buffer parse bit for bit.
        let mut data = String::from("id,s\n");
        for i in 0..200 {
            let s = match i % 5 {
                0 => format!("\"multi\nline {i}\""),
                1 => format!("\"esc\"\"aped {i}\""),
                2 => format!("\"crlf\r\nin {i}\""),
                3 => format!("日本語{i}"),
                _ => format!("plain{i}"),
            };
            data.push_str(&format!("{i},{s}\r\n"));
        }
        let whole = read_csv_str(&data, &CsvOptions::default()).unwrap();
        for chunk in [1usize, 2, 7, 64, 333, 1 << 20] {
            let streamed = crate::exec::with_ingest_chunk_bytes(chunk, || {
                read_csv_from(data.as_bytes(), &CsvOptions::default())
                    .unwrap()
            });
            assert_eq!(streamed, whole, "diverged at chunk {chunk}");
        }
    }

    #[test]
    fn speculative_scan_matches_serial_scan() {
        // Directly pin the parallel boundary scan against the serial
        // DFA over adversarial quoting, at several thread counts.
        let mut data = String::new();
        for i in 0..300 {
            data.push_str(&match i % 6 {
                0 => format!("\"q,{i}\nx\",{i}\n"),
                1 => format!("{i},\"\"\n"),
                2 => format!("\"\"\"{i}\"\"\",y\n"),
                3 => format!("plain{i},z\n"),
                4 => String::from("\n"),
                _ => format!("a\"b{i},w\r\n"),
            });
        }
        let bytes = data.as_bytes();
        let (serial, serial_exit) = scan_range_serial(
            bytes,
            0..bytes.len(),
            b',',
            ScanState::FieldStart,
        );
        for threads in [2usize, 3, 8] {
            let (par, par_exit) = crate::exec::with_intra_op_threads(
                threads,
                || {
                    crate::exec::with_par_row_threshold(1, || {
                        scan_boundaries(bytes, b',', ScanState::FieldStart)
                    })
                },
            );
            assert_eq!(par, serial, "scan diverged at {threads} threads");
            assert_eq!(par_exit, serial_exit);
        }
    }

    #[test]
    fn chunked_sink_streams_in_file_order() {
        let mut data = String::from("id\n");
        for i in 0..50 {
            data.push_str(&format!("{i}\n"));
        }
        let mut ids: Vec<i64> = Vec::new();
        let mut chunks = 0usize;
        let schema = crate::exec::with_ingest_chunk_bytes(16, || {
            read_csv_chunked(data.as_bytes(), &CsvOptions::default(), |t| {
                chunks += 1;
                ids.extend_from_slice(t.column(0).i64_values());
                Ok(())
            })
            .unwrap()
        });
        assert_eq!(schema.field(0).dtype, DataType::Int64);
        assert!(chunks > 1, "tiny chunks must yield several tables");
        assert_eq!(ids, (0..50).collect::<Vec<i64>>());
    }

    #[test]
    fn count_and_range_read_partition_the_file() {
        let mut data = String::from("id,s\n");
        for i in 0..97 {
            let s = if i % 7 == 0 {
                format!("\"x,\n{i}\"")
            } else {
                format!("s{i}")
            };
            data.push_str(&format!("{i},{s}\n"));
        }
        let whole = read_csv_str(&data, &CsvOptions::default()).unwrap();
        crate::exec::with_ingest_chunk_bytes(32, || {
            let n = count_csv_records(
                data.as_bytes(),
                &CsvOptions::default(),
            )
            .unwrap();
            assert_eq!(n, 97);
            // Three blocks concatenate back to the whole table.
            let mut parts = Vec::new();
            for (lo, hi) in [(0usize, 33usize), (33, 66), (66, 97)] {
                parts.push(
                    read_csv_records(
                        data.as_bytes(),
                        &CsvOptions::default(),
                        lo..hi,
                    )
                    .unwrap(),
                );
            }
            let merged =
                Table::concat_all(whole.schema(), &parts).unwrap();
            assert_eq!(merged, whole);
            // An empty block still resolves the file's schema.
            let empty = read_csv_records(
                data.as_bytes(),
                &CsvOptions::default(),
                5..5,
            )
            .unwrap();
            assert_eq!(empty.num_rows(), 0);
            assert_eq!(empty.schema(), whole.schema());
        });
    }

    #[test]
    fn header_names_needing_quotes_roundtrip() {
        // A column name containing the delimiter must be quoted on
        // write, or the re-read sees a different column count.
        let t = Table::from_columns(vec![
            ("a,b", Column::from_i64(vec![1, 2])),
            ("c\"d", Column::from_i64(vec![3, 4])),
        ])
        .unwrap();
        let mut buf = Vec::new();
        write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
        let back =
            read_csv_from(&buf[..], &CsvOptions::default()).unwrap();
        assert_eq!(back.schema().field(0).name, "a,b");
        assert_eq!(back.schema().field(1).name, "c\"d");
        assert_eq!(back.column(0).i64_values(), &[1, 2]);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("rylon_csv_test.csv");
        let t = Table::from_columns(vec![
            ("id", Column::from_i64(vec![10, 20])),
            ("v", Column::from_f64(vec![1.25, -0.5])),
        ])
        .unwrap();
        write_csv(&t, &path, &CsvOptions::default()).unwrap();
        let back = read_csv(&path, &CsvOptions::default()).unwrap();
        assert_eq!(back.column(0).i64_values(), &[10, 20]);
        assert_eq!(back.column(1).f64_values(), &[1.25, -0.5]);
        std::fs::remove_file(&path).ok();
    }
}
