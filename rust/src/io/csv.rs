//! CSV reader/writer. RFC-4180-style quoting (double-quote fields,
//! doubled quotes inside), optional header, explicit or inferred schema.
//! Empty cells are nulls.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::column::ColumnBuilder;
use crate::error::{Result, RylonError};
use crate::table::Table;
use crate::types::{DataType, Field, Schema};

/// CSV read/write options.
#[derive(Debug, Clone)]
pub struct CsvOptions {
    pub delimiter: char,
    /// First row is a header (read: column names; write: emit header).
    pub has_header: bool,
    /// Explicit schema; when `None` the reader infers types from the
    /// first `infer_rows` records (i64 ⊂ f64 ⊂ str; bool literal set).
    pub schema: Option<Schema>,
    pub infer_rows: usize,
}

impl Default for CsvOptions {
    fn default() -> Self {
        CsvOptions {
            delimiter: ',',
            has_header: true,
            schema: None,
            infer_rows: 128,
        }
    }
}

impl CsvOptions {
    pub fn with_schema(mut self, schema: Schema) -> CsvOptions {
        self.schema = Some(schema);
        self
    }

    pub fn no_header(mut self) -> CsvOptions {
        self.has_header = false;
        self
    }
}

/// Split one CSV record honouring quotes. Returns the cells.
fn split_record(line: &str, delim: char) -> Result<Vec<String>> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else if c == '"' {
            in_quotes = true;
        } else if c == delim {
            cells.push(std::mem::take(&mut cur));
        } else {
            cur.push(c);
        }
    }
    if in_quotes {
        return Err(RylonError::parse(format!(
            "unterminated quote in record: {line:?}"
        )));
    }
    cells.push(cur);
    Ok(cells)
}

fn infer_dtype(samples: &[&str]) -> DataType {
    let non_empty: Vec<&&str> =
        samples.iter().filter(|s| !s.is_empty()).collect();
    if non_empty.is_empty() {
        return DataType::Utf8;
    }
    if non_empty
        .iter()
        .all(|s| s.trim().parse::<i64>().is_ok())
    {
        return DataType::Int64;
    }
    if non_empty
        .iter()
        .all(|s| s.trim().parse::<f64>().is_ok())
    {
        return DataType::Float64;
    }
    if non_empty.iter().all(|s| {
        matches!(s.trim(), "true" | "false" | "True" | "False")
    }) {
        return DataType::Bool;
    }
    DataType::Utf8
}

/// Read a CSV from any reader.
pub fn read_csv_from<R: Read>(reader: R, opts: &CsvOptions) -> Result<Table> {
    let buf = BufReader::new(reader);
    let mut lines = Vec::new();
    for line in buf.lines() {
        let line = line?;
        if !line.is_empty() {
            lines.push(line);
        }
    }
    let mut records: Vec<Vec<String>> = Vec::with_capacity(lines.len());
    for l in &lines {
        records.push(split_record(l, opts.delimiter)?);
    }
    let header: Option<Vec<String>> = if opts.has_header && !records.is_empty()
    {
        Some(records.remove(0))
    } else {
        None
    };

    // Establish the schema.
    let schema = match &opts.schema {
        Some(s) => s.clone(),
        None => {
            let width = header
                .as_ref()
                .map(|h| h.len())
                .or_else(|| records.first().map(|r| r.len()))
                .ok_or_else(|| RylonError::parse("empty csv"))?;
            let fields = (0..width)
                .map(|c| {
                    let name = header
                        .as_ref()
                        .map(|h| h[c].clone())
                        .unwrap_or_else(|| format!("c{c}"));
                    let samples: Vec<&str> = records
                        .iter()
                        .take(opts.infer_rows)
                        .map(|r| r.get(c).map(|s| s.as_str()).unwrap_or(""))
                        .collect();
                    Field::new(name, infer_dtype(&samples))
                })
                .collect();
            Schema::new(fields)
        }
    };

    let mut builders: Vec<ColumnBuilder> = schema
        .fields()
        .iter()
        .map(|f| ColumnBuilder::new(f.dtype, records.len()))
        .collect();
    for (lineno, rec) in records.iter().enumerate() {
        if rec.len() != schema.len() {
            return Err(RylonError::parse(format!(
                "record {} has {} cells, schema has {}",
                lineno + 1 + opts.has_header as usize,
                rec.len(),
                schema.len()
            )));
        }
        for (b, cell) in builders.iter_mut().zip(rec) {
            b.push_parse(cell)?;
        }
    }
    Table::try_new(
        schema,
        builders.into_iter().map(|b| b.finish()).collect(),
    )
}

/// Read a CSV file.
pub fn read_csv(path: impl AsRef<Path>, opts: &CsvOptions) -> Result<Table> {
    let f = std::fs::File::open(path)?;
    read_csv_from(f, opts)
}

fn needs_quoting(s: &str, delim: char) -> bool {
    s.contains(delim) || s.contains('"') || s.contains('\n')
}

/// Write a table to any writer.
pub fn write_csv_to<W: Write>(
    table: &Table,
    writer: W,
    opts: &CsvOptions,
) -> Result<()> {
    let mut w = BufWriter::new(writer);
    let d = opts.delimiter;
    if opts.has_header {
        let names: Vec<&str> = table
            .schema()
            .fields()
            .iter()
            .map(|f| f.name.as_str())
            .collect();
        writeln!(w, "{}", names.join(&d.to_string()))?;
    }
    let mut cell = String::new();
    for r in 0..table.num_rows() {
        for c in 0..table.num_columns() {
            if c > 0 {
                write!(w, "{d}")?;
            }
            cell.clear();
            cell.push_str(&table.column(c).value(r).render());
            if needs_quoting(&cell, d) {
                write!(w, "\"{}\"", cell.replace('"', "\"\""))?;
            } else {
                write!(w, "{cell}")?;
            }
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Write a table to a CSV file.
pub fn write_csv(
    table: &Table,
    path: impl AsRef<Path>,
    opts: &CsvOptions,
) -> Result<()> {
    let f = std::fs::File::create(path)?;
    write_csv_to(table, f, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::types::Value;

    #[test]
    fn read_with_inference() {
        let data = "id,price,name,ok\n1,2.5,apple,true\n2,,\"b,c\",false\n";
        let t = read_csv_from(data.as_bytes(), &CsvOptions::default())
            .unwrap();
        assert_eq!(t.num_rows(), 2);
        assert_eq!(t.schema().field(0).dtype, DataType::Int64);
        assert_eq!(t.schema().field(1).dtype, DataType::Float64);
        assert_eq!(t.schema().field(2).dtype, DataType::Utf8);
        assert_eq!(t.schema().field(3).dtype, DataType::Bool);
        assert_eq!(t.column(1).value(1), Value::Null);
        assert_eq!(t.column(2).value(1), Value::Utf8("b,c".into()));
    }

    #[test]
    fn explicit_schema_and_no_header() {
        let data = "1,x\n2,y\n";
        let opts = CsvOptions::default()
            .no_header()
            .with_schema(Schema::parse("a:i64,b:str").unwrap());
        let t = read_csv_from(data.as_bytes(), &opts).unwrap();
        assert_eq!(t.num_rows(), 2);
        assert_eq!(t.column(0).i64_values(), &[1, 2]);
    }

    #[test]
    fn quoted_quotes_and_roundtrip() {
        let t = Table::from_columns(vec![
            ("s", Column::from_str(&["plain", "has,comma", "has\"quote"])),
            ("v", Column::from_opt_i64(vec![Some(1), None, Some(3)])),
        ])
        .unwrap();
        let mut buf = Vec::new();
        write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
        let opts = CsvOptions::default()
            .with_schema(Schema::parse("s:str,v:i64").unwrap());
        let back = read_csv_from(&buf[..], &opts).unwrap();
        assert_eq!(back.column(0).as_utf8().value(1), "has,comma");
        assert_eq!(back.column(0).as_utf8().value(2), "has\"quote");
        assert_eq!(back.column(1).value(1), Value::Null);
    }

    #[test]
    fn ragged_record_rejected() {
        let data = "a,b\n1,2\n3\n";
        assert!(read_csv_from(data.as_bytes(), &CsvOptions::default())
            .is_err());
    }

    #[test]
    fn bad_literal_with_schema_rejected() {
        let data = "a\nxyz\n";
        let opts = CsvOptions::default()
            .with_schema(Schema::parse("a:i64").unwrap());
        assert!(read_csv_from(data.as_bytes(), &opts).is_err());
    }

    #[test]
    fn unterminated_quote_rejected() {
        let data = "a\n\"oops\n";
        assert!(read_csv_from(data.as_bytes(), &CsvOptions::default())
            .is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("rylon_csv_test.csv");
        let t = Table::from_columns(vec![
            ("id", Column::from_i64(vec![10, 20])),
            ("v", Column::from_f64(vec![1.25, -0.5])),
        ])
        .unwrap();
        write_csv(&t, &path, &CsvOptions::default()).unwrap();
        let back = read_csv(&path, &CsvOptions::default()).unwrap();
        assert_eq!(back.column(0).i64_values(), &[10, 20]);
        assert_eq!(back.column(1).f64_values(), &[1.25, -0.5]);
        std::fs::remove_file(&path).ok();
    }
}
