//! Synthetic workload generators matching the paper's datasets (§V
//! "Dataset Formats"): "CSV files were generated with four columns (one
//! int_64 as index and three doubles)" for the strong-scaling runs, and
//! "two columns (one int_64 as index and one double as payload)" for the
//! larger load tests. Deterministic per (seed, rank) so distributed
//! workloads are reproducible.

use crate::column::Column;
use crate::error::{Result, RylonError};
use crate::table::Table;
use crate::util::rng::Xoshiro256;

/// Key distribution for the index column.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KeyDist {
    /// Uniform over `[0, domain)`.
    Uniform { domain: u64 },
    /// Zipf over `[0, domain)` with exponent `s` (skewed joins).
    Zipf { domain: u64, s: f64 },
    /// Sequential from this partition's global offset (no duplicates).
    Sequential,
}

/// Spec for one generated table partition.
#[derive(Debug, Clone)]
pub struct DataGenSpec {
    pub rows: usize,
    /// Number of f64 payload columns (paper: 3 for scaling, 1 for load).
    pub payload_cols: usize,
    pub key_dist: KeyDist,
    pub seed: u64,
}

impl DataGenSpec {
    /// Paper's strong-scaling relation: int64 index + 3 doubles, uniform
    /// keys over twice the row count (≈50% match rate between two
    /// relations).
    pub fn paper_scaling(rows: usize, seed: u64) -> DataGenSpec {
        DataGenSpec {
            rows,
            payload_cols: 3,
            key_dist: KeyDist::Uniform {
                domain: (rows as u64 * 2).max(1),
            },
            seed,
        }
    }

    /// Paper's larger-load relation: int64 index + 1 double.
    pub fn paper_load(rows: usize, seed: u64) -> DataGenSpec {
        DataGenSpec {
            rows,
            payload_cols: 1,
            key_dist: KeyDist::Uniform {
                domain: (rows as u64 * 2).max(1),
            },
            seed,
        }
    }
}

/// Generate one partition of a table for `rank` of `world`.
/// `spec.rows` is the *total* row count; each rank gets its share
/// (remainder spread over the first ranks).
pub fn gen_partition(
    spec: &DataGenSpec,
    rank: usize,
    world: usize,
) -> Result<Table> {
    if world == 0 || rank >= world {
        return Err(RylonError::invalid(format!(
            "bad rank/world {rank}/{world}"
        )));
    }
    let base = spec.rows / world;
    let extra = spec.rows % world;
    let my_rows = base + (rank < extra) as usize;
    let my_offset: usize =
        base * rank + rank.min(extra);
    // Independent stream per (seed, rank).
    let mut rng = Xoshiro256::new(
        spec.seed ^ crate::compute::hash::splitmix64(rank as u64),
    );

    let keys: Vec<i64> = match spec.key_dist {
        KeyDist::Uniform { domain } => (0..my_rows)
            .map(|_| rng.next_below(domain.max(1)) as i64)
            .collect(),
        KeyDist::Zipf { domain, s } => (0..my_rows)
            .map(|_| rng.next_zipf(domain.max(1), s) as i64)
            .collect(),
        KeyDist::Sequential => {
            (my_offset as i64..(my_offset + my_rows) as i64).collect()
        }
    };

    let mut cols: Vec<(String, Column)> =
        vec![("id".to_string(), Column::from_i64(keys))];
    for c in 0..spec.payload_cols {
        let vals: Vec<f64> =
            (0..my_rows).map(|_| rng.next_normal() * 100.0).collect();
        cols.push((format!("d{c}"), Column::from_f64(vals)));
    }
    let pairs: Vec<(&str, Column)> = cols
        .iter()
        .map(|(n, c)| (n.as_str(), c.clone()))
        .collect();
    Table::from_columns(pairs)
}

/// Generate a whole (single-partition) table.
pub fn gen_table(spec: &DataGenSpec) -> Result<Table> {
    gen_partition(spec, 0, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_cover_total_rows() {
        let spec = DataGenSpec::paper_scaling(103, 7);
        let world = 4;
        let mut total = 0;
        for r in 0..world {
            let t = gen_partition(&spec, r, world).unwrap();
            assert_eq!(t.num_columns(), 4); // id + 3 payloads
            total += t.num_rows();
        }
        assert_eq!(total, 103);
    }

    #[test]
    fn deterministic_per_seed_and_rank() {
        let spec = DataGenSpec::paper_load(50, 42);
        let a = gen_partition(&spec, 1, 3).unwrap();
        let b = gen_partition(&spec, 1, 3).unwrap();
        assert_eq!(a, b);
        let c = gen_partition(&spec, 2, 3).unwrap();
        assert_ne!(
            a.column(0).i64_values(),
            c.column(0).i64_values()
        );
    }

    #[test]
    fn sequential_keys_are_global_offsets() {
        let spec = DataGenSpec {
            rows: 10,
            payload_cols: 0,
            key_dist: KeyDist::Sequential,
            seed: 0,
        };
        let p0 = gen_partition(&spec, 0, 2).unwrap();
        let p1 = gen_partition(&spec, 1, 2).unwrap();
        assert_eq!(p0.column(0).i64_values(), &[0, 1, 2, 3, 4]);
        assert_eq!(p1.column(0).i64_values(), &[5, 6, 7, 8, 9]);
    }

    #[test]
    fn zipf_keys_skewed() {
        let spec = DataGenSpec {
            rows: 20_000,
            payload_cols: 0,
            key_dist: KeyDist::Zipf {
                domain: 1000,
                s: 1.2,
            },
            seed: 3,
        };
        let t = gen_table(&spec).unwrap();
        let hot = t
            .column(0)
            .i64_values()
            .iter()
            .filter(|&&k| k == 0)
            .count();
        assert!(hot > 1000, "zipf head too small: {hot}");
    }

    #[test]
    fn bad_rank_rejected() {
        let spec = DataGenSpec::paper_load(10, 0);
        assert!(gen_partition(&spec, 2, 2).is_err());
        assert!(gen_partition(&spec, 0, 0).is_err());
    }

    #[test]
    fn uniform_match_rate_near_half() {
        // Two relations over domain 2n should inner-join to ≈ n/2 matches
        // per the paper's workload design; sanity-check the generator.
        let a = gen_table(&DataGenSpec::paper_scaling(20_000, 1)).unwrap();
        let b = gen_table(&DataGenSpec::paper_scaling(20_000, 2)).unwrap();
        let j = crate::ops::join::join(
            &a,
            &b,
            &crate::ops::join::JoinOptions::inner("id", "id"),
        )
        .unwrap();
        let ratio = j.num_rows() as f64 / 20_000.0;
        assert!(ratio > 0.2 && ratio < 1.2, "ratio={ratio}");
    }
}
