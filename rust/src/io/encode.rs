//! Per-row-group column encodings and zone-map statistics for the
//! encoded RYF2 format (`docs/STORAGE.md`).
//!
//! A row group serialised by [`encode_group`] stores each column as
//! `dtype | encoding | validity | payload`. Int64 columns pick the
//! smallest of plain / run-length / bit-packed-delta over the *valid*
//! values only (null-stripped), Float64 columns store valid values
//! plain, Bool columns pick plain or run-length, and Utf8 columns pick
//! plain (the wire layout, byte-for-byte) or a dictionary over the row
//! extents. Decoding reconstructs exactly the in-memory column
//! representation the raw (`RYF1`) path produces — invalid slots hold
//! `T::default()`, all-valid primitive bitmaps are dropped, string
//! offsets are reproduced verbatim — so encoded scans are bit-identical
//! to the raw oracle.
//!
//! Zone maps ([`ColumnStats`], one per column per group) record the
//! null count and the min/max over valid rows. [`group_may_match`]
//! evaluates a pushed-down [`Predicate`] against them conservatively:
//! it never rules out a group that could contain a matching row, and a
//! predicate the row-level evaluator would reject (unknown column,
//! type mismatch) passes the group through so the pipeline surfaces
//! exactly the error the raw path would.

#![warn(missing_docs)]

use std::cmp::Ordering;
use std::collections::HashMap;

use crate::buffer::Bitmap;
use crate::column::{Column, PrimitiveColumn, StringColumn};
use crate::error::{Result, RylonError};
use crate::net::wire::{self, Reader};
use crate::ops::select::{CmpOp, Predicate};
use crate::table::Table;
use crate::types::{DataType, Field, Schema, Value};

/// Magic for one encoded row group ("RYG2" little-endian). Distinct
/// from the wire table magic so `read_ryf_group` can dispatch on the
/// first four bytes of any group regardless of the file format.
pub const GROUP_MAGIC: u32 = u32::from_le_bytes(*b"RYG2");

/// Longest string min/max kept in a zone map. Longer bounds are
/// dropped (the group then always passes string predicates) so a
/// wide-string column cannot bloat the footer.
pub const MAX_STATS_STR: usize = 64;

/// One column's physical encoding inside an encoded row group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    /// Values verbatim (the wire layout for strings; null-stripped
    /// fixed-width values for primitives).
    Plain,
    /// Run-length: `(value, count)` pairs over the valid values
    /// (Int64) or rows (Bool).
    Rle,
    /// Frame-of-reference bit-packing: `base + packed deltas` over the
    /// valid Int64 values.
    BitPack,
    /// Dictionary over the row byte extents of a Utf8 column, nulls
    /// included (their extents are normally empty).
    Dict,
}

impl Encoding {
    fn tag(self) -> u8 {
        match self {
            Encoding::Plain => 0,
            Encoding::Rle => 1,
            Encoding::BitPack => 2,
            Encoding::Dict => 3,
        }
    }

    fn from_tag(tag: u8) -> Result<Encoding> {
        match tag {
            0 => Ok(Encoding::Plain),
            1 => Ok(Encoding::Rle),
            2 => Ok(Encoding::BitPack),
            3 => Ok(Encoding::Dict),
            _ => Err(RylonError::parse(format!("bad encoding tag {tag}"))),
        }
    }
}

/// What a projected decode skipped: payload/validity bytes never
/// decoded and the number of pruned column payloads.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DecodePruning {
    /// Validity + payload bytes of pruned columns (never decoded).
    pub avoided_bytes: u64,
    /// Column payloads skipped because the projection excluded them.
    pub pruned_columns: u64,
}

// ---- encoding ------------------------------------------------------------

/// Serialise one row group in the encoded format, choosing the
/// smallest encoding per column.
pub fn encode_group(table: &Table) -> Vec<u8> {
    encode_group_with(table, None)
}

/// Serialise one row group, forcing `force` on every column where the
/// dtype supports it (falling back to [`Encoding::Plain`] where it
/// does not). `None` picks the smallest payload per column — the
/// production path; forcing exists so tests can exercise every
/// encoding on arbitrary data.
pub fn encode_group_with(table: &Table, force: Option<Encoding>) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&GROUP_MAGIC.to_le_bytes());
    out.extend_from_slice(&(table.num_columns() as u32).to_le_bytes());
    out.extend_from_slice(&(table.num_rows() as u64).to_le_bytes());
    for (i, field) in table.schema().fields().iter().enumerate() {
        encode_column(&mut out, &field.name, table.column(i), force);
    }
    out
}

fn encode_column(
    out: &mut Vec<u8>,
    name: &str,
    col: &Column,
    force: Option<Encoding>,
) {
    let (enc, payload) = match col {
        Column::Int64(c) => encode_i64(c, force),
        Column::Float64(c) => (Encoding::Plain, plain_f64(c)),
        Column::Bool(c) => encode_bool(c, force),
        Column::Utf8(c) => encode_utf8(c, force),
    };
    out.push(wire::dtype_tag(col.dtype()));
    out.push(enc.tag());
    out.push(col.validity().is_some() as u8);
    let name_bytes = name.as_bytes();
    out.extend_from_slice(&(name_bytes.len() as u16).to_le_bytes());
    out.extend_from_slice(name_bytes);
    if let Some(bm) = col.validity() {
        for w in bm.words() {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&payload);
}

/// Valid values only (null-stripping): invalid slots are not stored —
/// the decoder rebuilds them as `T::default()` via `from_options`,
/// which is exactly what the wire path produces.
fn present<T: Copy>(c: &PrimitiveColumn<T>) -> Vec<T> {
    match c.validity() {
        None => c.values().to_vec(),
        Some(bm) => c
            .values()
            .iter()
            .enumerate()
            .filter(|(i, _)| bm.get(*i))
            .map(|(_, &v)| v)
            .collect(),
    }
}

fn encode_i64(
    c: &PrimitiveColumn<i64>,
    force: Option<Encoding>,
) -> (Encoding, Vec<u8>) {
    let vals = present(c);
    match force {
        Some(Encoding::Rle) => return (Encoding::Rle, rle_i64(&vals)),
        Some(Encoding::BitPack) => {
            return (Encoding::BitPack, bitpack_i64(&vals))
        }
        Some(_) => return (Encoding::Plain, plain_i64(&vals)),
        None => {}
    }
    let plain = plain_i64(&vals);
    let mut best = (Encoding::Plain, plain);
    let bp = bitpack_i64(&vals);
    if bp.len() < best.1.len() {
        best = (Encoding::BitPack, bp);
    }
    let rle = rle_i64(&vals);
    if rle.len() < best.1.len() {
        best = (Encoding::Rle, rle);
    }
    best
}

fn plain_i64(vals: &[i64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 8);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn plain_f64(c: &PrimitiveColumn<f64>) -> Vec<u8> {
    let vals = present(c);
    let mut out = Vec::with_capacity(vals.len() * 8);
    for v in vals {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    out
}

fn rle_i64(vals: &[i64]) -> Vec<u8> {
    let mut runs: Vec<(i64, u64)> = Vec::new();
    for &v in vals {
        match runs.last_mut() {
            Some((rv, n)) if *rv == v => *n += 1,
            _ => runs.push((v, 1)),
        }
    }
    let mut out = Vec::with_capacity(8 + runs.len() * 16);
    out.extend_from_slice(&(runs.len() as u64).to_le_bytes());
    for (v, n) in runs {
        out.extend_from_slice(&v.to_le_bytes());
        out.extend_from_slice(&n.to_le_bytes());
    }
    out
}

fn bitpack_i64(vals: &[i64]) -> Vec<u8> {
    let base = vals.iter().copied().min().unwrap_or(0);
    let deltas: Vec<u64> = vals
        .iter()
        .map(|&v| (v as i128 - base as i128) as u64)
        .collect();
    let max_delta = deltas.iter().copied().max().unwrap_or(0);
    let width: u8 = if max_delta == 0 {
        0
    } else {
        64 - max_delta.leading_zeros() as u8
    };
    let mut out = Vec::new();
    out.extend_from_slice(&base.to_le_bytes());
    out.push(width);
    out.extend_from_slice(&pack_bits(&deltas, width));
    out
}

fn encode_bool(
    c: &PrimitiveColumn<bool>,
    force: Option<Encoding>,
) -> (Encoding, Vec<u8>) {
    let vals = present(c);
    let plain: Vec<u8> = vals.iter().map(|&b| b as u8).collect();
    let rle = {
        let mut runs: Vec<(bool, u64)> = Vec::new();
        for &v in &vals {
            match runs.last_mut() {
                Some((rv, n)) if *rv == v => *n += 1,
                _ => runs.push((v, 1)),
            }
        }
        let mut out = Vec::with_capacity(8 + runs.len() * 9);
        out.extend_from_slice(&(runs.len() as u64).to_le_bytes());
        for (v, n) in runs {
            out.push(v as u8);
            out.extend_from_slice(&n.to_le_bytes());
        }
        out
    };
    match force {
        Some(Encoding::Rle) => (Encoding::Rle, rle),
        Some(_) => (Encoding::Plain, plain),
        None => {
            if rle.len() < plain.len() {
                (Encoding::Rle, rle)
            } else {
                (Encoding::Plain, plain)
            }
        }
    }
}

fn encode_utf8(
    c: &StringColumn,
    force: Option<Encoding>,
) -> (Encoding, Vec<u8>) {
    let plain = {
        let mut out =
            Vec::with_capacity((c.len() + 2) * 8 + c.bytes().len());
        for o in c.offsets() {
            out.extend_from_slice(&o.to_le_bytes());
        }
        out.extend_from_slice(&(c.bytes().len() as u64).to_le_bytes());
        out.extend_from_slice(c.bytes());
        out
    };
    // Dictionary codes rebuild offsets as the running sum of entry
    // lengths from 0, which only reproduces the raw offsets verbatim
    // when they start at 0 (every constructor's invariant; wire frames
    // could in principle carry a nonzero start, so check).
    let dictable = c.offsets().first() == Some(&0)
        && c.len() < u32::MAX as usize;
    let dict = if dictable { Some(dict_utf8(c)) } else { None };
    match (force, dict) {
        (Some(Encoding::Dict), Some(d)) => (Encoding::Dict, d),
        (Some(_), _) => (Encoding::Plain, plain),
        (None, Some(d)) if d.len() < plain.len() => (Encoding::Dict, d),
        _ => (Encoding::Plain, plain),
    }
}

fn dict_utf8(c: &StringColumn) -> Vec<u8> {
    let bytes = c.bytes();
    let offsets = c.offsets();
    let mut codes = Vec::with_capacity(c.len());
    let mut index: HashMap<&[u8], u32> = HashMap::new();
    let mut entries: Vec<&[u8]> = Vec::new();
    for i in 0..c.len() {
        let s = &bytes[offsets[i] as usize..offsets[i + 1] as usize];
        let code = *index.entry(s).or_insert_with(|| {
            entries.push(s);
            (entries.len() - 1) as u32
        });
        codes.push(code);
    }
    let mut out = Vec::new();
    out.extend_from_slice(&(entries.len() as u64).to_le_bytes());
    let mut off = 0u64;
    out.extend_from_slice(&off.to_le_bytes());
    for e in &entries {
        off += e.len() as u64;
        out.extend_from_slice(&off.to_le_bytes());
    }
    for e in &entries {
        out.extend_from_slice(e);
    }
    out.extend_from_slice(&(codes.len() as u64).to_le_bytes());
    for code in codes {
        out.extend_from_slice(&code.to_le_bytes());
    }
    out
}

fn pack_bits(vals: &[u64], width: u8) -> Vec<u8> {
    if width == 0 {
        return Vec::new();
    }
    let width = width as usize;
    let mut out = vec![0u8; (vals.len() * width).div_ceil(8)];
    let mut bit = 0usize;
    for &v in vals {
        let mut done = 0usize;
        while done < width {
            let (byte, off) = (bit / 8, bit % 8);
            let take = (8 - off).min(width - done);
            let chunk = ((v >> done) & ((1u64 << take) - 1)) as u8;
            out[byte] |= chunk << off;
            bit += take;
            done += take;
        }
    }
    out
}

fn unpack_bits(buf: &[u8], n: usize, width: u8) -> Vec<u64> {
    let width = width as usize;
    let mut out = Vec::with_capacity(n);
    let mut bit = 0usize;
    for _ in 0..n {
        let mut v = 0u64;
        let mut done = 0usize;
        while done < width {
            let (byte, off) = (bit / 8, bit % 8);
            let take = (8 - off).min(width - done);
            let chunk = ((buf[byte] >> off) as u64) & ((1u64 << take) - 1);
            v |= chunk << done;
            bit += take;
            done += take;
        }
        out.push(v);
    }
    out
}

// ---- decoding ------------------------------------------------------------

/// Decode one encoded row group. With a projection, columns whose
/// names are not listed are skipped without decoding their validity or
/// payload bytes (the returned table keeps the file's column order
/// restricted to the projected set — the same rule the raw scan
/// applies, so the two paths stay bit-identical). Fails closed on any
/// malformed byte: truncation, bad tags, invalid UTF-8, out-of-range
/// codes or offsets, or trailing bytes.
pub fn decode_group(
    buf: &[u8],
    projection: Option<&[String]>,
) -> Result<(Table, DecodePruning)> {
    let mut r = Reader::new(buf);
    if r.u32()? != GROUP_MAGIC {
        return Err(RylonError::parse("bad encoded group magic"));
    }
    let ncols = r.u32()? as usize;
    let nrows = r.u64()? as usize;
    // Every column consumes at least its 5-byte fixed header.
    r.check_count(ncols, 5, "encoded columns")?;
    let nwords = nrows.div_ceil(64);
    let mut fields = Vec::new();
    let mut cols = Vec::new();
    let mut pruning = DecodePruning::default();
    for _ in 0..ncols {
        let dtype = wire::tag_dtype(r.u8()?)?;
        let enc = Encoding::from_tag(r.u8()?)?;
        let has_validity = match r.u8()? {
            0 => false,
            1 => true,
            v => {
                return Err(RylonError::parse(format!(
                    "bad validity flag {v}"
                )))
            }
        };
        let name_len = r.u16()? as usize;
        let name = std::str::from_utf8(r.bytes(name_len)?)
            .map_err(|_| RylonError::parse("column name is not utf-8"))?
            .to_string();
        let keep =
            projection.map_or(true, |p| p.iter().any(|n| n == &name));
        if !keep {
            let skip = if has_validity { nwords * 8 } else { 0 };
            r.check_count(skip, 1, "validity words")?;
            r.bytes(skip)?;
            let payload_len = r.u64()? as usize;
            r.bytes(payload_len)?;
            pruning.pruned_columns += 1;
            pruning.avoided_bytes += (skip + payload_len) as u64;
            continue;
        }
        let validity = if has_validity {
            r.check_count(nwords, 8, "validity words")?;
            let mut words = Vec::with_capacity(nwords);
            for _ in 0..nwords {
                words.push(r.u64()?);
            }
            Some(Bitmap::from_words(words, nrows))
        } else {
            None
        };
        let payload_len = r.u64()? as usize;
        let payload = r.bytes(payload_len)?;
        cols.push(decode_column(dtype, enc, nrows, &validity, payload)?);
        fields.push(Field::new(&name, dtype));
    }
    if r.remaining() != 0 {
        return Err(RylonError::parse(
            "trailing bytes after encoded group",
        ));
    }
    Ok((Table::try_new(Schema::new(fields), cols)?, pruning))
}

fn decode_column(
    dtype: DataType,
    enc: Encoding,
    nrows: usize,
    validity: &Option<Bitmap>,
    payload: &[u8],
) -> Result<Column> {
    if let Some(bm) = validity {
        if bm.len() != nrows {
            return Err(RylonError::parse("validity length mismatch"));
        }
    }
    let n_present = validity.as_ref().map_or(nrows, |b| b.count_ones());
    let mut r = Reader::new(payload);
    let col = match (dtype, enc) {
        (DataType::Int64, _) => {
            let vals = decode_i64_values(&mut r, enc, n_present)?;
            Column::Int64(rebuild_prim(vals, nrows, validity)?)
        }
        (DataType::Float64, Encoding::Plain) => {
            r.check_count(n_present, 8, "f64 values")?;
            let mut vals = Vec::with_capacity(n_present);
            for _ in 0..n_present {
                vals.push(f64::from_bits(r.u64()?));
            }
            Column::Float64(rebuild_prim(vals, nrows, validity)?)
        }
        (DataType::Bool, Encoding::Plain) => {
            r.check_count(n_present, 1, "bool values")?;
            let mut vals = Vec::with_capacity(n_present);
            for _ in 0..n_present {
                vals.push(match r.u8()? {
                    0 => false,
                    1 => true,
                    v => {
                        return Err(RylonError::parse(format!(
                            "bad bool byte {v}"
                        )))
                    }
                });
            }
            Column::Bool(rebuild_prim(vals, nrows, validity)?)
        }
        (DataType::Bool, Encoding::Rle) => {
            let n_runs = r.u64()? as usize;
            r.check_count(n_runs, 9, "bool runs")?;
            let mut vals = Vec::new();
            for _ in 0..n_runs {
                let v = match r.u8()? {
                    0 => false,
                    1 => true,
                    v => {
                        return Err(RylonError::parse(format!(
                            "bad bool run value {v}"
                        )))
                    }
                };
                let count = r.u64()? as usize;
                if vals.len() + count > n_present {
                    return Err(RylonError::parse(
                        "bool runs exceed the value count",
                    ));
                }
                vals.extend(std::iter::repeat(v).take(count));
            }
            if vals.len() != n_present {
                return Err(RylonError::parse(
                    "bool runs do not cover the value count",
                ));
            }
            Column::Bool(rebuild_prim(vals, nrows, validity)?)
        }
        (DataType::Utf8, Encoding::Plain) => {
            Column::Utf8(decode_utf8_plain(&mut r, nrows, validity)?)
        }
        (DataType::Utf8, Encoding::Dict) => {
            Column::Utf8(decode_utf8_dict(&mut r, nrows, validity)?)
        }
        (dt, enc) => {
            return Err(RylonError::parse(format!(
                "encoding {enc:?} is invalid for a {dt} column"
            )))
        }
    };
    if r.remaining() != 0 {
        return Err(RylonError::parse(
            "trailing bytes in encoded column payload",
        ));
    }
    Ok(col)
}

fn decode_i64_values(
    r: &mut Reader,
    enc: Encoding,
    n_present: usize,
) -> Result<Vec<i64>> {
    match enc {
        Encoding::Plain => {
            r.check_count(n_present, 8, "i64 values")?;
            let mut vals = Vec::with_capacity(n_present);
            for _ in 0..n_present {
                vals.push(r.u64()? as i64);
            }
            Ok(vals)
        }
        Encoding::Rle => {
            let n_runs = r.u64()? as usize;
            r.check_count(n_runs, 16, "i64 runs")?;
            let mut vals = Vec::new();
            for _ in 0..n_runs {
                let v = r.u64()? as i64;
                let count = r.u64()? as usize;
                if vals.len() + count > n_present {
                    return Err(RylonError::parse(
                        "i64 runs exceed the value count",
                    ));
                }
                vals.extend(std::iter::repeat(v).take(count));
            }
            if vals.len() != n_present {
                return Err(RylonError::parse(
                    "i64 runs do not cover the value count",
                ));
            }
            Ok(vals)
        }
        Encoding::BitPack => {
            let base = r.u64()? as i64;
            let width = r.u8()?;
            if width > 64 {
                return Err(RylonError::parse(format!(
                    "bit-pack width {width} exceeds 64"
                )));
            }
            let packed_len = (n_present * width as usize).div_ceil(8);
            let packed = r.bytes(packed_len)?;
            let deltas = unpack_bits(packed, n_present, width);
            let mut vals = Vec::with_capacity(n_present);
            for d in deltas {
                let v = base as i128 + d as i128;
                let v = i64::try_from(v).map_err(|_| {
                    RylonError::parse(
                        "bit-packed delta overflows i64",
                    )
                })?;
                vals.push(v);
            }
            Ok(vals)
        }
        Encoding::Dict => Err(RylonError::parse(
            "encoding Dict is invalid for an i64 column",
        )),
    }
}

/// Re-expand null-stripped values to the row count. Mirrors the wire
/// path exactly: `from_options` stores `T::default()` in invalid slots
/// and drops an all-valid bitmap, so the decoded column is
/// representation-identical to a raw read.
fn rebuild_prim<T: Copy + Default>(
    present: Vec<T>,
    nrows: usize,
    validity: &Option<Bitmap>,
) -> Result<PrimitiveColumn<T>> {
    match validity {
        None => {
            if present.len() != nrows {
                return Err(RylonError::parse(
                    "value count does not match the row count",
                ));
            }
            Ok(PrimitiveColumn::from_values(present))
        }
        Some(bm) => {
            let mut it = present.into_iter();
            let opts: Vec<Option<T>> = (0..nrows)
                .map(|i| if bm.get(i) { it.next() } else { None })
                .collect();
            Ok(PrimitiveColumn::from_options(opts))
        }
    }
}

fn decode_utf8_plain(
    r: &mut Reader,
    nrows: usize,
    validity: &Option<Bitmap>,
) -> Result<StringColumn> {
    let noffsets = nrows
        .checked_add(1)
        .ok_or_else(|| RylonError::parse("utf8 offset count overflows"))?;
    r.check_count(noffsets, 8, "utf8 offsets")?;
    let mut offsets = Vec::with_capacity(noffsets);
    for _ in 0..noffsets {
        offsets.push(r.u64()?);
    }
    let nbytes = r.u64()? as usize;
    let bytes = r.bytes(nbytes)?.to_vec();
    validate_utf8_extents(&offsets, &bytes)?;
    Ok(StringColumn::from_parts(offsets, bytes, validity.clone()))
}

fn decode_utf8_dict(
    r: &mut Reader,
    nrows: usize,
    validity: &Option<Bitmap>,
) -> Result<StringColumn> {
    let dict_n = r.u64()? as usize;
    let n_dict_offsets = dict_n
        .checked_add(1)
        .ok_or_else(|| RylonError::parse("dict size overflows"))?;
    r.check_count(n_dict_offsets, 8, "dict offsets")?;
    let mut dict_offsets = Vec::with_capacity(n_dict_offsets);
    for _ in 0..n_dict_offsets {
        dict_offsets.push(r.u64()?);
    }
    let dict_nbytes = *dict_offsets.last().unwrap() as usize;
    let dict_bytes = r.bytes(dict_nbytes)?.to_vec();
    validate_utf8_extents(&dict_offsets, &dict_bytes)?;
    let n_codes = r.u64()? as usize;
    if n_codes != nrows {
        return Err(RylonError::parse(format!(
            "dict code count {n_codes} does not match row count {nrows}"
        )));
    }
    r.check_count(n_codes, 4, "dict codes")?;
    let mut offsets = Vec::with_capacity(nrows + 1);
    let mut bytes = Vec::new();
    offsets.push(0u64);
    for _ in 0..n_codes {
        let code = r.u32()? as usize;
        if code >= dict_n {
            return Err(RylonError::parse(format!(
                "dict code {code} out of range ({dict_n} entries)"
            )));
        }
        let lo = dict_offsets[code] as usize;
        let hi = dict_offsets[code + 1] as usize;
        bytes.extend_from_slice(&dict_bytes[lo..hi]);
        offsets.push(bytes.len() as u64);
    }
    Ok(StringColumn::from_parts(offsets, bytes, validity.clone()))
}

/// The wire deserialiser's fail-closed extent checks: offsets must be
/// monotone non-decreasing, land on character boundaries of a valid
/// UTF-8 buffer, start within it, and end exactly at its length —
/// `StringColumn::value` slices without checks downstream.
fn validate_utf8_extents(offsets: &[u64], bytes: &[u8]) -> Result<()> {
    if offsets.is_empty() {
        return Err(RylonError::parse("utf8 offsets are empty"));
    }
    let s = std::str::from_utf8(bytes)
        .map_err(|_| RylonError::parse("string buffer is not utf-8"))?;
    let nbytes = bytes.len() as u64;
    let mut prev = 0u64;
    for (i, &o) in offsets.iter().enumerate() {
        if i > 0 && o < prev {
            return Err(RylonError::parse(format!(
                "utf8 offsets decrease at row {i} ({o} after {prev})"
            )));
        }
        if o > nbytes || !s.is_char_boundary(o as usize) {
            return Err(RylonError::parse(format!(
                "utf8 offset {o} at row {i} splits a character or \
                 exceeds the {nbytes}-byte string buffer"
            )));
        }
        prev = o;
    }
    if prev != nbytes {
        return Err(RylonError::parse(format!(
            "utf8 offsets end at {prev}, not at the {nbytes}-byte \
             string buffer length"
        )));
    }
    Ok(())
}

// ---- zone-map statistics -------------------------------------------------

/// Per-group per-column zone-map statistics: the null count plus the
/// min/max over valid rows (`None` when the group has no valid rows,
/// or for strings longer than [`MAX_STATS_STR`]). Float64 bounds use
/// `total_cmp` — the same total order the predicate evaluator uses —
/// so NaN sorts greatest and pruning stays sound for NaN literals.
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnStats {
    /// Number of null rows in the group.
    pub null_count: u64,
    /// Whether the in-memory column carried a validity bitmap when the
    /// group was written (a null-free slice of a nullable column does,
    /// `docs/STORAGE.md`). The scan uses this to reproduce the raw
    /// path's `concat` validity promotion exactly when groups are
    /// skipped — it plays no part in pruning.
    pub has_validity: bool,
    /// Smallest valid value, if any.
    pub min: Option<Value>,
    /// Largest valid value, if any.
    pub max: Option<Value>,
}

/// Compute the zone-map statistics for one column.
pub fn column_stats(col: &Column) -> ColumnStats {
    let null_count = col.null_count() as u64;
    let has_validity = col.validity().is_some();
    let (mut min, mut max) = (None, None);
    match col {
        Column::Int64(c) => {
            let mut bounds: Option<(i64, i64)> = None;
            for (i, &v) in c.values().iter().enumerate() {
                if c.is_valid(i) {
                    bounds = Some(match bounds {
                        None => (v, v),
                        Some((lo, hi)) => (lo.min(v), hi.max(v)),
                    });
                }
            }
            if let Some((lo, hi)) = bounds {
                min = Some(Value::Int64(lo));
                max = Some(Value::Int64(hi));
            }
        }
        Column::Float64(c) => {
            let mut bounds: Option<(f64, f64)> = None;
            for (i, &v) in c.values().iter().enumerate() {
                if c.is_valid(i) {
                    bounds = Some(match bounds {
                        None => (v, v),
                        Some((lo, hi)) => (
                            if v.total_cmp(&lo) == Ordering::Less {
                                v
                            } else {
                                lo
                            },
                            if v.total_cmp(&hi) == Ordering::Greater {
                                v
                            } else {
                                hi
                            },
                        ),
                    });
                }
            }
            if let Some((lo, hi)) = bounds {
                min = Some(Value::Float64(lo));
                max = Some(Value::Float64(hi));
            }
        }
        Column::Bool(c) => {
            let mut bounds: Option<(bool, bool)> = None;
            for (i, &v) in c.values().iter().enumerate() {
                if c.is_valid(i) {
                    bounds = Some(match bounds {
                        None => (v, v),
                        Some((lo, hi)) => (lo.min(v), hi.max(v)),
                    });
                }
            }
            if let Some((lo, hi)) = bounds {
                min = Some(Value::Bool(lo));
                max = Some(Value::Bool(hi));
            }
        }
        Column::Utf8(c) => {
            let mut bounds: Option<(&str, &str)> = None;
            for i in 0..c.len() {
                if c.is_valid(i) {
                    let v = c.value(i);
                    bounds = Some(match bounds {
                        None => (v, v),
                        Some((lo, hi)) => (lo.min(v), hi.max(v)),
                    });
                }
            }
            if let Some((lo, hi)) = bounds {
                if lo.len() <= MAX_STATS_STR && hi.len() <= MAX_STATS_STR
                {
                    min = Some(Value::Utf8(lo.to_string()));
                    max = Some(Value::Utf8(hi.to_string()));
                }
            }
        }
    }
    ColumnStats {
        null_count,
        has_validity,
        min,
        max,
    }
}

/// Serialise one column's zone-map stats into the RYF2 footer.
pub(crate) fn write_stats(
    out: &mut Vec<u8>,
    dtype: DataType,
    s: &ColumnStats,
) {
    out.extend_from_slice(&s.null_count.to_le_bytes());
    out.push(s.has_validity as u8);
    match (&s.min, &s.max) {
        (Some(min), Some(max)) => {
            out.push(1);
            for v in [min, max] {
                match (dtype, v) {
                    (DataType::Int64, Value::Int64(x)) => {
                        out.extend_from_slice(&x.to_le_bytes())
                    }
                    (DataType::Float64, Value::Float64(x)) => out
                        .extend_from_slice(&x.to_bits().to_le_bytes()),
                    (DataType::Bool, Value::Bool(x)) => {
                        out.push(*x as u8)
                    }
                    (DataType::Utf8, Value::Utf8(x)) => {
                        out.extend_from_slice(
                            &(x.len() as u16).to_le_bytes(),
                        );
                        out.extend_from_slice(x.as_bytes());
                    }
                    _ => unreachable!(
                        "stats value dtype mismatch (writer bug)"
                    ),
                }
            }
        }
        _ => out.push(0),
    }
}

/// Parse one column's zone-map stats from the RYF2 footer.
pub(crate) fn read_stats(
    r: &mut Reader,
    dtype: DataType,
) -> Result<ColumnStats> {
    let null_count = r.u64()?;
    let has_validity = match r.u8()? {
        0 => false,
        1 => true,
        v => {
            return Err(RylonError::parse(format!(
                "bad stats validity flag {v}"
            )))
        }
    };
    let has_minmax = match r.u8()? {
        0 => false,
        1 => true,
        v => {
            return Err(RylonError::parse(format!(
                "bad stats min/max flag {v}"
            )))
        }
    };
    let (mut min, mut max) = (None, None);
    if has_minmax {
        for slot in [&mut min, &mut max] {
            *slot = Some(match dtype {
                DataType::Int64 => Value::Int64(r.u64()? as i64),
                DataType::Float64 => {
                    Value::Float64(f64::from_bits(r.u64()?))
                }
                DataType::Bool => Value::Bool(match r.u8()? {
                    0 => false,
                    1 => true,
                    v => {
                        return Err(RylonError::parse(format!(
                            "bad bool stats byte {v}"
                        )))
                    }
                }),
                DataType::Utf8 => {
                    let len = r.u16()? as usize;
                    let s = std::str::from_utf8(r.bytes(len)?)
                        .map_err(|_| {
                            RylonError::parse(
                                "stats string is not utf-8",
                            )
                        })?;
                    Value::Utf8(s.to_string())
                }
            });
        }
    }
    Ok(ColumnStats {
        null_count,
        has_validity,
        min,
        max,
    })
}

// ---- zone-map pruning ----------------------------------------------------

/// Conservative zone-map test: could any row of a group with these
/// stats match `pred`? `false` means the group provably contains no
/// matching row and can be skipped without decoding. Unknown columns
/// and literal/dtype combinations the row evaluator would reject
/// return `true`, so the surviving pipeline predicate surfaces the
/// identical error the raw path produces.
pub fn group_may_match(
    pred: &Predicate,
    schema: &Schema,
    stats: &[ColumnStats],
    rows: u64,
) -> bool {
    if rows == 0 {
        return false;
    }
    match pred {
        Predicate::Cmp {
            column,
            op,
            literal,
        } => {
            let Some((dtype, s)) = col_stats(schema, stats, column)
            else {
                return true;
            };
            if s.null_count >= rows {
                return false; // no valid rows; Cmp never matches null
            }
            match bound_orderings(dtype, s, literal) {
                Some((lo, hi)) => match op {
                    CmpOp::Eq => {
                        lo != Ordering::Greater && hi != Ordering::Less
                    }
                    CmpOp::Ne => !(lo == Ordering::Equal
                        && hi == Ordering::Equal),
                    CmpOp::Lt => lo == Ordering::Less,
                    CmpOp::Le => lo != Ordering::Greater,
                    CmpOp::Gt => hi == Ordering::Greater,
                    CmpOp::Ge => hi != Ordering::Less,
                },
                None => true,
            }
        }
        Predicate::IsNull { column, negated } => {
            let Some((_, s)) = col_stats(schema, stats, column) else {
                return true;
            };
            if *negated {
                s.null_count < rows
            } else {
                s.null_count > 0
            }
        }
        Predicate::And(a, b) => {
            group_may_match(a, schema, stats, rows)
                && group_may_match(b, schema, stats, rows)
        }
        Predicate::Or(a, b) => {
            group_may_match(a, schema, stats, rows)
                || group_may_match(b, schema, stats, rows)
        }
        Predicate::Not(p) => !group_must_match_all(p, schema, stats, rows),
    }
}

/// Dual of [`group_may_match`]: do *all* rows of the group provably
/// match `pred`? Needed for `Not` (a group can be skipped under
/// `not p` only when every row matches `p`). Conservative toward
/// `false`.
fn group_must_match_all(
    pred: &Predicate,
    schema: &Schema,
    stats: &[ColumnStats],
    rows: u64,
) -> bool {
    if rows == 0 {
        return true;
    }
    match pred {
        Predicate::Cmp {
            column,
            op,
            literal,
        } => {
            let Some((dtype, s)) = col_stats(schema, stats, column)
            else {
                return false;
            };
            if s.null_count > 0 {
                return false; // null rows never match a Cmp
            }
            match bound_orderings(dtype, s, literal) {
                Some((lo, hi)) => match op {
                    CmpOp::Eq => {
                        lo == Ordering::Equal && hi == Ordering::Equal
                    }
                    CmpOp::Ne => {
                        hi == Ordering::Less || lo == Ordering::Greater
                    }
                    CmpOp::Lt => hi == Ordering::Less,
                    CmpOp::Le => hi != Ordering::Greater,
                    CmpOp::Gt => lo == Ordering::Greater,
                    CmpOp::Ge => lo != Ordering::Less,
                },
                None => false,
            }
        }
        Predicate::IsNull { column, negated } => {
            let Some((_, s)) = col_stats(schema, stats, column) else {
                return false;
            };
            if *negated {
                s.null_count == 0
            } else {
                s.null_count >= rows
            }
        }
        Predicate::And(a, b) => {
            group_must_match_all(a, schema, stats, rows)
                && group_must_match_all(b, schema, stats, rows)
        }
        Predicate::Or(a, b) => {
            group_must_match_all(a, schema, stats, rows)
                || group_must_match_all(b, schema, stats, rows)
        }
        Predicate::Not(p) => !group_may_match(p, schema, stats, rows),
    }
}

fn col_stats<'a>(
    schema: &Schema,
    stats: &'a [ColumnStats],
    column: &str,
) -> Option<(DataType, &'a ColumnStats)> {
    let i = schema.index_of(column).ok()?;
    let s = stats.get(i)?;
    Some((schema.field(i).dtype, s))
}

/// `(min.cmp(literal), max.cmp(literal))` under exactly the comparison
/// the row evaluator applies for this dtype/literal pair, or `None`
/// when min/max are absent or the pair is one the evaluator rejects
/// (callers then pass the group through). The Int64-vs-Float64 arm
/// compares through `as f64` — a monotone non-decreasing cast, so the
/// interval logic stays sound.
fn bound_orderings(
    dtype: DataType,
    s: &ColumnStats,
    literal: &Value,
) -> Option<(Ordering, Ordering)> {
    let (min, max) = (s.min.as_ref()?, s.max.as_ref()?);
    match (dtype, literal) {
        (DataType::Int64, Value::Int64(x)) => {
            Some((min.as_i64()?.cmp(x), max.as_i64()?.cmp(x)))
        }
        (DataType::Int64, Value::Float64(x)) => Some((
            (min.as_i64()? as f64).total_cmp(x),
            (max.as_i64()? as f64).total_cmp(x),
        )),
        (DataType::Float64, lit) => {
            let x = lit.as_f64()?;
            match (min, max) {
                (Value::Float64(lo), Value::Float64(hi)) => {
                    Some((lo.total_cmp(&x), hi.total_cmp(&x)))
                }
                _ => None,
            }
        }
        (DataType::Utf8, Value::Utf8(x)) => Some((
            min.as_str()?.cmp(x.as_str()),
            max.as_str()?.cmp(x.as_str()),
        )),
        (DataType::Bool, Value::Bool(x)) => Some((
            min.as_bool()?.cmp(x),
            max.as_bool()?.cmp(x),
        )),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        Table::from_columns(vec![
            (
                "k",
                Column::from_opt_i64(
                    (0..200)
                        .map(|i| {
                            if i % 7 == 0 {
                                None
                            } else {
                                Some(i * 3 - 100)
                            }
                        })
                        .collect(),
                ),
            ),
            (
                "v",
                Column::from_opt_f64(
                    (0..200)
                        .map(|i| {
                            if i % 11 == 0 {
                                None
                            } else {
                                Some(i as f64 * 0.25 - 3.0)
                            }
                        })
                        .collect(),
                ),
            ),
            (
                "s",
                Column::from_opt_str(
                    &(0..200)
                        .map(|i| {
                            if i % 5 == 0 {
                                None
                            } else {
                                Some(format!("tag-{}", i % 9))
                            }
                        })
                        .collect::<Vec<_>>(),
                ),
            ),
            (
                "b",
                Column::from_bool((0..200).map(|i| i % 3 == 0).collect()),
            ),
        ])
        .unwrap()
    }

    #[test]
    fn every_forced_encoding_roundtrips() {
        let t = sample();
        for force in [
            None,
            Some(Encoding::Plain),
            Some(Encoding::Rle),
            Some(Encoding::BitPack),
            Some(Encoding::Dict),
        ] {
            let buf = encode_group_with(&t, force);
            let (back, pruning) = decode_group(&buf, None).unwrap();
            assert_eq!(back, t, "force={force:?}");
            assert_eq!(pruning, DecodePruning::default());
        }
    }

    #[test]
    fn auto_choice_beats_plain_on_compressible_data() {
        let runs = Table::from_columns(vec![
            ("r", Column::from_i64(vec![42; 4096])),
            (
                "small",
                Column::from_i64((0..4096).map(|i| i % 16).collect()),
            ),
            (
                "dict",
                Column::from_str(
                    &(0..4096)
                        .map(|i| format!("name-{}", i % 4))
                        .collect::<Vec<_>>(),
                ),
            ),
        ])
        .unwrap();
        let auto = encode_group(&runs);
        let plain = encode_group_with(&runs, Some(Encoding::Plain));
        assert!(
            auto.len() * 4 < plain.len(),
            "auto {} vs plain {}",
            auto.len(),
            plain.len()
        );
        let (back, _) = decode_group(&auto, None).unwrap();
        assert_eq!(back, runs);
    }

    #[test]
    fn projection_skips_payloads_and_keeps_file_order() {
        let t = sample();
        let buf = encode_group(&t);
        let proj = vec!["b".to_string(), "k".to_string()];
        let (got, pruning) = decode_group(&buf, Some(&proj)).unwrap();
        // File order (k before b), not projection-list order.
        assert_eq!(
            got.schema()
                .fields()
                .iter()
                .map(|f| f.name.as_str())
                .collect::<Vec<_>>(),
            vec!["k", "b"]
        );
        assert_eq!(pruning.pruned_columns, 2);
        assert!(pruning.avoided_bytes > 0);
        assert_eq!(got.column(0), t.column(0));
        assert_eq!(got.column(1), t.column(3));
    }

    #[test]
    fn empty_and_all_null_groups_roundtrip() {
        let empty = Table::from_columns(vec![
            ("a", Column::from_i64(vec![])),
            ("s", Column::from_str::<&str>(&[])),
        ])
        .unwrap();
        let (back, _) =
            decode_group(&encode_group(&empty), None).unwrap();
        assert_eq!(back, empty);

        let nulls = Table::from_columns(vec![
            ("a", Column::from_opt_i64(vec![None; 70])),
            (
                "s",
                Column::from_opt_str(&vec![None::<&str>; 70]),
            ),
        ])
        .unwrap();
        for force in [None, Some(Encoding::Rle), Some(Encoding::Dict)] {
            let (back, _) =
                decode_group(&encode_group_with(&nulls, force), None)
                    .unwrap();
            assert_eq!(back, nulls);
        }
    }

    #[test]
    fn bitpack_handles_extreme_range() {
        let t = Table::from_columns(vec![(
            "x",
            Column::from_i64(vec![i64::MIN, 0, i64::MAX, -1, 1]),
        )])
        .unwrap();
        let buf = encode_group_with(&t, Some(Encoding::BitPack));
        let (back, _) = decode_group(&buf, None).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn truncation_and_garbage_fail_closed() {
        let buf = encode_group(&sample());
        for cut in [0, 3, 4, 11, 12, buf.len() / 2, buf.len() - 1] {
            assert!(
                decode_group(&buf[..cut], None).is_err(),
                "cut at {cut} must fail"
            );
        }
        let mut bad = buf.clone();
        bad[0] ^= 0xFF; // magic
        assert!(decode_group(&bad, None).is_err());
        let mut extra = buf.clone();
        extra.push(0);
        assert!(decode_group(&extra, None).is_err());
    }

    #[test]
    fn stats_capture_minmax_and_nulls() {
        let t = sample();
        let s = column_stats(t.column(0));
        assert_eq!(s.null_count, 29);
        assert!(s.has_validity);
        assert_eq!(s.min, Some(Value::Int64(-97)));
        assert_eq!(s.max, Some(Value::Int64(497)));
        let s = column_stats(&Column::from_i64(vec![1, 2]));
        assert!(!s.has_validity);
        let s = column_stats(&Column::from_opt_i64(vec![None, None]));
        assert_eq!((s.min, s.max, s.null_count), (None, None, 2));
        let long = "x".repeat(MAX_STATS_STR + 1);
        let s = column_stats(&Column::from_str(&[long.as_str()]));
        assert_eq!(s.min, None);
    }

    #[test]
    fn stats_serialization_roundtrips() {
        let t = sample();
        for (i, f) in t.schema().fields().iter().enumerate() {
            let s = column_stats(t.column(i));
            let mut buf = Vec::new();
            write_stats(&mut buf, f.dtype, &s);
            let mut r = Reader::new(&buf);
            let back = read_stats(&mut r, f.dtype).unwrap();
            assert_eq!(back, s, "column {}", f.name);
            assert_eq!(r.remaining(), 0);
        }
    }

    #[test]
    fn pruning_matches_row_evaluation() {
        // Candidate groups × candidate predicates: whenever any row
        // matches, the zone map must keep the group.
        let groups = [
            Table::from_columns(vec![
                ("k", Column::from_i64(vec![10, 20, 30])),
                ("s", Column::from_str(&["aa", "bb", "cc"])),
            ])
            .unwrap(),
            Table::from_columns(vec![
                (
                    "k",
                    Column::from_opt_i64(vec![Some(5), None, Some(7)]),
                ),
                (
                    "s",
                    Column::from_opt_str(&[
                        Some("zz"),
                        None,
                        Some("mm"),
                    ]),
                ),
            ])
            .unwrap(),
            Table::from_columns(vec![
                ("k", Column::from_opt_i64(vec![None, None])),
                ("s", Column::from_opt_str(&[None::<&str>, None])),
            ])
            .unwrap(),
        ];
        let mut preds: Vec<Predicate> = [
            "k == 20",
            "k != 20",
            "k < 6",
            "k <= 5",
            "k > 29",
            "k >= 31",
            "k == 20 and s == bb",
            "k < 6 or s == cc",
            "k is null",
            "k is not null",
            "s == bb",
            "s < aa",
            "s >= zz",
            "k > 2.5",
            "k < 5.5",
        ]
        .iter()
        .map(|p| Predicate::parse(p).unwrap())
        .collect();
        // The parser has no `not` prefix; build negations directly.
        for p in ["k < 100", "k >= 5 and k <= 30", "k is null"] {
            preds.push(Predicate::Not(Box::new(
                Predicate::parse(p).unwrap(),
            )));
        }
        for t in &groups {
            let stats: Vec<ColumnStats> =
                (0..t.num_columns())
                    .map(|i| column_stats(t.column(i)))
                    .collect();
            for pred in &preds {
                let mask = pred.eval_mask(t).unwrap();
                let any = mask.iter().any(|&m| m);
                let may = group_may_match(
                    pred,
                    t.schema(),
                    &stats,
                    t.num_rows() as u64,
                );
                // Soundness: may=false requires no matching row.
                assert!(
                    may || !any,
                    "pred `{pred:?}` pruned a matching group"
                );
            }
        }
    }

    #[test]
    fn pruning_skips_provably_dead_groups() {
        let t = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![100, 150, 199]),
        )])
        .unwrap();
        let stats = vec![column_stats(t.column(0))];
        for (p, expect_skip) in [
            ("k < 100", true),
            ("k > 199", true),
            ("k == 50", true),
            ("k is null", true),
            ("k == 150", false),
            ("k >= 199", false),
            ("missing == 1", false), // unknown column: pass through
            ("k == notanumber", false), // type error: pass through
        ] {
            let pred = Predicate::parse(p).unwrap();
            let may =
                group_may_match(&pred, t.schema(), &stats, 3);
            assert_eq!(may, !expect_skip, "pred `{p}`");
        }
        // `not (k >= 100)` is all-false here: every row matches the
        // inner predicate, so the negated group can be skipped.
        let not_pred = Predicate::Not(Box::new(
            Predicate::parse("k >= 100").unwrap(),
        ));
        assert!(!group_may_match(&not_pred, t.schema(), &stats, 3));
    }
}
