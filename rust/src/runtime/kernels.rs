//! Typed entry points over the AOT artifacts: the shuffle hash kernel
//! and the table→tensor featurizer, each with padding to the artifact
//! shape and a bit-exact/allclose native fallback.

use std::sync::Arc;

use crate::compute::hash::splitmix64;
use crate::error::{Result, RylonError};
use crate::runtime::registry::Runtime;

/// Hash-partition kernel: `pid = splitmix64(key) % nparts` + histogram.
/// Mirrors `python/compile/kernels/hash_partition.py` exactly.
pub struct HashKernel<'rt> {
    runtime: Option<&'rt Runtime>,
    nparts: usize,
}

impl<'rt> HashKernel<'rt> {
    /// Artifact-backed kernel (falls back to native if no artifact of
    /// this `nparts` exists — the caller can check [`HashKernel::is_aot`]).
    pub fn new(runtime: &'rt Runtime, nparts: usize) -> HashKernel<'rt> {
        HashKernel {
            runtime: Some(runtime),
            nparts,
        }
    }

    /// Pure-native kernel (no artifacts needed).
    pub fn native(nparts: usize) -> HashKernel<'static> {
        HashKernel {
            runtime: None,
            nparts,
        }
    }

    /// Whether an AOT artifact will serve calls of `n` keys.
    pub fn is_aot(&self, n: usize) -> bool {
        self.runtime
            .and_then(|rt| {
                rt.find("hash_partition", "n", n, &[("nparts", self.nparts)])
            })
            .is_some()
    }

    /// Compute pids + histogram for `keys`.
    pub fn run(&self, keys: &[i64]) -> Result<(Vec<i32>, Vec<u64>)> {
        if let Some(rt) = self.runtime {
            if let Some(meta) = rt.find(
                "hash_partition",
                "n",
                keys.len(),
                &[("nparts", self.nparts)],
            ) {
                return self.run_aot(rt, &meta.name.clone(), keys);
            }
        }
        Ok(self.run_native(keys))
    }

    /// Native path (bit-exact with the artifact; cross-checked in
    /// rust/tests/pjrt_artifacts.rs).
    pub fn run_native(&self, keys: &[i64]) -> (Vec<i32>, Vec<u64>) {
        let mut hist = vec![0u64; self.nparts];
        let pids: Vec<i32> = keys
            .iter()
            .map(|&k| {
                let pid =
                    (splitmix64(k as u64) % self.nparts as u64) as i32;
                hist[pid as usize] += 1;
                pid
            })
            .collect();
        (pids, hist)
    }

    /// AOT path: pad to the artifact batch size, mask padding, execute,
    /// trim.
    pub fn run_aot(
        &self,
        rt: &Runtime,
        artifact: &str,
        keys: &[i64],
    ) -> Result<(Vec<i32>, Vec<u64>)> {
        let exe = rt.executable(artifact)?;
        let meta = rt
            .artifacts()
            .iter()
            .find(|m| m.name == artifact)
            .unwrap();
        let n = meta.params["n"];
        if keys.len() > n {
            return Err(RylonError::runtime(format!(
                "batch {} exceeds artifact capacity {n}",
                keys.len()
            )));
        }
        let mut padded: Vec<u64> = Vec::with_capacity(n);
        padded.extend(keys.iter().map(|&k| k as u64));
        padded.resize(n, 0);
        let mut mask = vec![1.0f32; keys.len()];
        mask.resize(n, 0.0);

        let key_lit = xla::Literal::vec1(&padded);
        let mask_lit = xla::Literal::vec1(&mask);
        let result = exec_tuple(&exe, &[key_lit, mask_lit])?;
        let (pids_lit, hist_lit) = result.to_tuple2().map_err(|e| {
            RylonError::runtime(format!("untuple: {e:?}"))
        })?;
        let pids_all: Vec<i32> = pids_lit.to_vec().map_err(|e| {
            RylonError::runtime(format!("pids read: {e:?}"))
        })?;
        let hist_f: Vec<f32> = hist_lit.to_vec().map_err(|e| {
            RylonError::runtime(format!("hist read: {e:?}"))
        })?;
        Ok((
            pids_all[..keys.len()].to_vec(),
            hist_f.iter().map(|&v| v as u64).collect(),
        ))
    }
}

/// Output of the featurize bridge.
#[derive(Debug, Clone)]
pub struct FeaturizeResult {
    /// Row-major standardized features, `rows × cols`.
    pub features: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
    pub mean: Vec<f32>,
    pub inv_std: Vec<f32>,
}

/// Table→tensor featurizer (paper Fig 1 / §IV bridge). Mirrors
/// `python/compile/model.py::featurize_model`.
pub struct FeaturizeKernel<'rt> {
    runtime: Option<&'rt Runtime>,
}

impl<'rt> FeaturizeKernel<'rt> {
    pub fn new(runtime: &'rt Runtime) -> FeaturizeKernel<'rt> {
        FeaturizeKernel {
            runtime: Some(runtime),
        }
    }

    pub fn native() -> FeaturizeKernel<'static> {
        FeaturizeKernel { runtime: None }
    }

    pub fn is_aot(&self, rows: usize, cols: usize) -> bool {
        self.runtime
            .and_then(|rt| {
                rt.find("featurize", "rows", rows, &[("cols", cols)])
            })
            .is_some()
    }

    /// Standardise an `rows × cols` row-major f32 matrix.
    pub fn run(
        &self,
        x: &[f32],
        rows: usize,
        cols: usize,
    ) -> Result<FeaturizeResult> {
        if x.len() != rows * cols {
            return Err(RylonError::invalid(format!(
                "featurize: {} values for {rows}x{cols}",
                x.len()
            )));
        }
        if let Some(rt) = self.runtime {
            if let Some(meta) =
                rt.find("featurize", "rows", rows, &[("cols", cols)])
            {
                // Padding rows would skew the column statistics, so the
                // AOT path requires an exact row match; otherwise fall
                // through to native (same numerics).
                if meta.params["rows"] == rows {
                    return self.run_aot(rt, &meta.name.clone(), x, rows, cols);
                }
            }
        }
        Ok(self.run_native(x, rows, cols))
    }

    /// Native path — identical math (mean, eps-guarded inv-std,
    /// standardise) in f32 like the kernel.
    pub fn run_native(
        &self,
        x: &[f32],
        rows: usize,
        cols: usize,
    ) -> FeaturizeResult {
        const EPS: f32 = 1e-6;
        let mut mean = vec![0.0f32; cols];
        for r in 0..rows {
            for c in 0..cols {
                mean[c] += x[r * cols + c];
            }
        }
        for m in &mut mean {
            *m /= rows.max(1) as f32;
        }
        let mut var = vec![0.0f32; cols];
        for r in 0..rows {
            for c in 0..cols {
                let d = x[r * cols + c] - mean[c];
                var[c] += d * d;
            }
        }
        let inv_std: Vec<f32> = var
            .iter()
            .map(|&v| 1.0 / (v / rows.max(1) as f32 + EPS).sqrt())
            .collect();
        let mut features = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                features[r * cols + c] =
                    (x[r * cols + c] - mean[c]) * inv_std[c];
            }
        }
        FeaturizeResult {
            features,
            rows,
            cols,
            mean,
            inv_std,
        }
    }

    pub fn run_aot(
        &self,
        rt: &Runtime,
        artifact: &str,
        x: &[f32],
        rows: usize,
        cols: usize,
    ) -> Result<FeaturizeResult> {
        let exe = rt.executable(artifact)?;
        let x_lit = xla::Literal::vec1(x)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| RylonError::runtime(format!("reshape: {e:?}")))?;
        let result = exec_tuple(&exe, &[x_lit])?;
        let (f_lit, mean_lit, istd_lit) =
            result.to_tuple3().map_err(|e| {
                RylonError::runtime(format!("untuple: {e:?}"))
            })?;
        Ok(FeaturizeResult {
            features: f_lit.to_vec().map_err(|e| {
                RylonError::runtime(format!("features read: {e:?}"))
            })?,
            rows,
            cols,
            mean: mean_lit.to_vec().map_err(|e| {
                RylonError::runtime(format!("mean read: {e:?}"))
            })?,
            inv_std: istd_lit.to_vec().map_err(|e| {
                RylonError::runtime(format!("inv_std read: {e:?}"))
            })?,
        })
    }
}

/// Execute and pull the (tupled) first result to host.
fn exec_tuple(
    exe: &Arc<xla::PjRtLoadedExecutable>,
    inputs: &[xla::Literal],
) -> Result<xla::Literal> {
    let bufs = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| RylonError::runtime(format!("execute: {e:?}")))?;
    bufs[0][0]
        .to_literal_sync()
        .map_err(|e| RylonError::runtime(format!("to_literal: {e:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_hash_kernel_formula() {
        let k = HashKernel::native(16);
        let keys = vec![0i64, 1, -5, i64::MAX];
        let (pids, hist) = k.run(&keys).unwrap();
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(pids[i], (splitmix64(key as u64) % 16) as i32);
        }
        assert_eq!(hist.iter().sum::<u64>(), 4);
    }

    #[test]
    fn native_featurize_standardises() {
        let k = FeaturizeKernel::native();
        // 4 rows × 2 cols.
        let x = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let r = k.run(&x, 4, 2).unwrap();
        assert_eq!(r.mean, vec![2.5, 25.0]);
        // Column means of the output ≈ 0, std ≈ 1.
        for c in 0..2 {
            let m: f32 =
                (0..4).map(|i| r.features[i * 2 + c]).sum::<f32>() / 4.0;
            assert!(m.abs() < 1e-6);
            let v: f32 = (0..4)
                .map(|i| r.features[i * 2 + c].powi(2))
                .sum::<f32>()
                / 4.0;
            assert!((v - 1.0).abs() < 1e-3, "var={v}");
        }
    }

    #[test]
    fn featurize_validates_shape() {
        let k = FeaturizeKernel::native();
        assert!(k.run(&[1.0, 2.0], 3, 4).is_err());
    }
}
