//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them once on the CPU PJRT client,
//! and execute them from the Rust hot path. Python never runs here.
//!
//! Every kernel has a **native fallback** (`compute::hash`,
//! `bridge`-style featurize) that is bit-exact/allclose with the
//! artifact — `rust/tests/pjrt_artifacts.rs` cross-checks them — so the
//! engine works without `artifacts/` and callers can choose the path
//! per-call (Fig 12's "binding overhead" bench drives all paths).

pub mod registry;
pub mod kernels;

pub use kernels::{FeaturizeResult, HashKernel, FeaturizeKernel};
pub use registry::{ArtifactMeta, Runtime};
