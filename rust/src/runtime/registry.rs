//! Artifact registry: reads `artifacts/manifest.json`, loads HLO text on
//! demand, compiles with the PJRT CPU client and caches the executables.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Result, RylonError};
use crate::util::json::Json;

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub file: String,
    /// kind-specific integer params (n, nparts, rows, cols, block…).
    pub params: HashMap<String, usize>,
}

/// Lazily-compiling artifact store. One PJRT CPU client per runtime.
pub struct Runtime {
    dir: PathBuf,
    client: xla::PjRtClient,
    metas: Vec<ArtifactMeta>,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            RylonError::runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let json = Json::parse(&text)
            .map_err(|e| RylonError::runtime(format!("bad manifest: {e}")))?;
        let mut metas = Vec::new();
        for a in json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| RylonError::runtime("manifest missing artifacts"))?
        {
            let get_str = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| {
                        RylonError::runtime(format!("manifest entry missing {k}"))
                    })
            };
            let mut params = HashMap::new();
            if let Json::Obj(map) = a {
                for (k, v) in map {
                    if let Some(n) = v.as_f64() {
                        params.insert(k.clone(), n as usize);
                    }
                }
            }
            metas.push(ArtifactMeta {
                name: get_str("name")?,
                kind: get_str("kind")?,
                file: get_str("file")?,
                params,
            });
        }
        let client = xla::PjRtClient::cpu().map_err(|e| {
            RylonError::runtime(format!("PJRT CPU client: {e:?}"))
        })?;
        Ok(Runtime {
            dir,
            client,
            metas,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.metas
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Find the artifact of `kind` with the smallest capacity parameter
    /// `cap_key` that is ≥ `needed` (padding model), with exact match on
    /// the other constraints.
    pub fn find(
        &self,
        kind: &str,
        cap_key: &str,
        needed: usize,
        exact: &[(&str, usize)],
    ) -> Option<&ArtifactMeta> {
        self.metas
            .iter()
            .filter(|m| m.kind == kind)
            .filter(|m| {
                exact.iter().all(|(k, v)| m.params.get(*k) == Some(v))
            })
            .filter(|m| {
                m.params.get(cap_key).is_some_and(|&c| c >= needed)
            })
            .min_by_key(|m| m.params[cap_key])
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .metas
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                RylonError::runtime(format!("unknown artifact '{name}'"))
            })?;
        let path = self.dir.join(&meta.file);
        // HLO *text*, not serialized protos: jax ≥0.5 emits 64-bit ids
        // that xla_extension 0.5.1 rejects; the text parser reassigns
        // them (see DESIGN.md §7).
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
            RylonError::runtime(format!(
                "parse {}: {e:?}",
                path.display()
            ))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| {
            RylonError::runtime(format!("compile {name}: {e:?}"))
        })?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Loading real artifacts is covered by rust/tests/pjrt_artifacts.rs
    // (requires `make artifacts`). Here: manifest parsing paths.

    #[test]
    fn open_missing_dir_errors_helpfully() {
        let err = match Runtime::open("/definitely/not/here") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn bad_manifest_rejected() {
        let dir = std::env::temp_dir().join("rylon_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(Runtime::open(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), "{\"artifacts\": 3}")
            .unwrap();
        assert!(Runtime::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
