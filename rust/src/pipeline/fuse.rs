//! Fused morsel pipelines: compile a [`Pipeline`]'s stage chain into
//! segments separated only by true pipeline breakers, then run each
//! segment as one job where every morsel flows through
//! select → project → join-probe → partial-agg in a single pass, with
//! no intermediate [`Table`] materialised between fused stages
//! (`docs/PIPELINE.md`).
//!
//! Fusable stages: `Select` and `Project` always; a `Join` when it is a
//! hash inner/left join (the probe is per-row once the build side
//! exists); a terminal `GroupBy` in local runs (per-worker partial
//! aggregation with a deterministic merge). Everything else — sort
//! joins, set ops, `OrderBy`, `Rebalance`, `Distinct`, and every
//! distributed exchange — is a breaker executed operator-at-a-time.
//!
//! The contract is *bit-identity*: a fused run produces exactly the
//! bytes of the operator-at-a-time path — f64 accumulation order,
//! splitmix64 bucket placement, SQL null semantics, and validity-bitmap
//! representation all included — at any thread count, steal setting, or
//! batch size. The `[exec] pipeline_fuse` knob flips executors so CI
//! can hold the two paths against each other as oracles.

use std::sync::Arc;

use crate::buffer::Bitmap;
use crate::column::{Column, ColumnBuilder};
use crate::compute::aggregate::Accumulator;
use crate::compute::filter::take_parallel;
use crate::compute::hash::{self, GroupIndex, HashChains};
use crate::dist::{shuffle, RankCtx};
use crate::error::{Result, RylonError};
use crate::exec;
use crate::metrics::{Phases, StageClock, Timer};
use crate::ops;
use crate::ops::groupby::GroupByOptions;
use crate::ops::join::{
    key_columns, key_has_null, probe_rows, take_opt, take_opt_prim,
    take_opt_str, validate, JoinAlgo, JoinOptions, JoinType,
};
use crate::ops::select::Predicate;
use crate::pipeline::{Env, Pipeline, Stage};
use crate::table::Table;
use crate::types::{DataType, Field, Schema};

// ---- segment planner -------------------------------------------------------

/// One unit of the compiled plan: a fused run of stages, or a breaker.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum Segment {
    /// A maximal run of fusable stages executed as one morsel pass.
    Fused(FusedSegment),
    /// A stage that must materialise its input (pipeline breaker),
    /// executed by the operator-at-a-time stage runner.
    Breaker(usize),
}

/// Stage-index span of one fused segment (`end` exclusive).
#[derive(Debug, PartialEq, Eq)]
pub(crate) struct FusedSegment {
    pub start: usize,
    pub end: usize,
    /// Position of the segment's hash-join probe, if any.
    pub join_at: Option<usize>,
    /// Position of the segment's terminal partial-agg, if any
    /// (always `end - 1`).
    pub group_at: Option<usize>,
}

/// True for joins whose probe side can stream per-morsel: hash algo,
/// inner or left semantics (right/full-outer need global right-side
/// match flags, which is a barrier over all probes).
fn fusable_join(opts: &JoinOptions) -> bool {
    opts.algo == JoinAlgo::Hash
        && matches!(opts.join_type, JoinType::Inner | JoinType::Left)
}

/// Compile the stage chain into fused segments and breakers. In
/// distributed plans a fusable join still starts its own segment (the
/// key shuffle is an exchange, so stages before it flush first) and
/// `GroupBy` is always a breaker (`dist_groupby` shuffles by key).
pub(crate) fn plan(stages: &[Stage], dist: bool) -> Vec<Segment> {
    fn flush(
        segs: &mut Vec<Segment>,
        run: &mut Option<(usize, Option<usize>)>,
        end: usize,
        group_at: Option<usize>,
    ) {
        if let Some((start, join_at)) = run.take() {
            segs.push(Segment::Fused(FusedSegment {
                start,
                end,
                join_at,
                group_at,
            }));
        }
    }

    let mut segs: Vec<Segment> = Vec::new();
    // (start, probe position) of the open fused run, if any.
    let mut run: Option<(usize, Option<usize>)> = None;
    for (i, stage) in stages.iter().enumerate() {
        match stage {
            Stage::Select(_) | Stage::Project(_) => {
                if run.is_none() {
                    run = Some((i, None));
                }
            }
            Stage::Join { opts, .. } if fusable_join(opts) => {
                let occupied = matches!(run, Some((_, Some(_))));
                if occupied || dist {
                    flush(&mut segs, &mut run, i, None);
                }
                match &mut run {
                    Some((_, j)) => *j = Some(i),
                    None => run = Some((i, Some(i))),
                }
            }
            Stage::GroupBy(_) if !dist => {
                if run.is_none() {
                    run = Some((i, None));
                }
                flush(&mut segs, &mut run, i + 1, Some(i));
            }
            _ => {
                flush(&mut segs, &mut run, i, None);
                segs.push(Segment::Breaker(i));
            }
        }
    }
    flush(&mut segs, &mut run, stages.len(), None);
    segs
}

// ---- per-morsel operator descriptors ---------------------------------------

/// Which input table a fused output column reads from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Side {
    L,
    R,
}

/// One fused stage as seen by the morsel pass, aligned 1:1 with the
/// segment's stage slots (for per-stage clock attribution).
enum SegOp<'p> {
    /// Pre-join row filter. `snap` is the zero-copy view at this stage
    /// (so dropped-column errors and name resolution match the
    /// materialised path); `cols`/`fields` are the predicate's resolved
    /// columns for the sparse re-filter path.
    PreFilter {
        pred: &'p Predicate,
        snap: Table,
        cols: Vec<usize>,
        fields: Vec<Field>,
    },
    /// Pre-join projection marker: the projection is applied to the
    /// view once at plan time (zero-copy); per morsel it only counts
    /// rows flowing through.
    PreMark,
    /// The fused hash-join probe.
    Probe,
    /// Post-join pair filter over the predicate's gathered columns.
    PostFilter {
        pred: &'p Predicate,
        cols: Vec<(Side, usize)>,
        fields: Vec<Field>,
    },
    /// Post-join projection marker (output-column remap at plan time).
    PostMark,
    /// Terminal partial-agg marker; runs in the segment epilogue.
    GroupMark,
}

/// Pre-built probe state shared by every morsel: resolved key columns,
/// the build-side chains, and the monomorphic i64 fast path.
struct ProbeCtx<'t> {
    lk: Vec<&'t Column>,
    rk: Vec<&'t Column>,
    chains: HashChains,
    fast: Option<(&'t [i64], &'t [i64])>,
    want_left_unmatched: bool,
}

/// Resolved groupby plan: each key/agg source as (side, column index)
/// into the left view / right table.
struct GroupPlan<'p> {
    opts: &'p GroupByOptions,
    key_srcs: Vec<(Side, usize)>,
    agg_srcs: Vec<(Side, usize)>,
    out_dtypes: Vec<DataType>,
}

/// One morsel's contribution: surviving rows (no-join segments) or
/// surviving index pairs (join segments), the unmatched-probe flag for
/// the morsel's full pair list, and the per-stage clock.
struct MorselOut {
    rows: Vec<usize>,
    li: Vec<i64>,
    ri: Vec<i64>,
    saw: bool,
    clock: StageClock,
}

/// Collect the column names a predicate references, deduplicated in
/// first-reference order (also used by the scan-pushdown planner in
/// [`Pipeline::scan_pushdown`]).
pub(crate) fn pred_columns(p: &Predicate, out: &mut Vec<String>) {
    match p {
        Predicate::Cmp { column, .. } | Predicate::IsNull { column, .. } => {
            if !out.iter().any(|c| c == column) {
                out.push(column.clone());
            }
        }
        Predicate::And(a, b) | Predicate::Or(a, b) => {
            pred_columns(a, out);
            pred_columns(b, out);
        }
        Predicate::Not(a) => pred_columns(a, out),
    }
}

/// Serial `-1`-aware gather (the per-morsel twin of `take_opt`, which
/// must not be called inside a morsel closure: its dense fast path
/// nests a parallel kernel).
fn serial_take_opt(col: &Column, idx: &[i64]) -> Column {
    match col {
        Column::Int64(c) => Column::Int64(take_opt_prim(c, idx)),
        Column::Float64(c) => Column::Float64(take_opt_prim(c, idx)),
        Column::Bool(c) => Column::Bool(take_opt_prim(c, idx)),
        Column::Utf8(c) => Column::Utf8(take_opt_str(c, idx)),
    }
}

/// Attach an all-true validity bitmap when a gather's dense fast path
/// dropped it. The materialised path decides a right-side column's
/// bitmap *presence* from the join's full pair list (any `-1` routes it
/// through the null-aware gather, which keeps a bitmap), while the
/// fused path gathers only the rows surviving later stages — which may
/// all be matches. Forcing the bitmap back on whenever the full list
/// had an unmatched row keeps the representation bit-identical
/// (`Bitmap::ones` masks tail bits, so it equals a set-all-true map).
fn force_valid(col: Column) -> Column {
    let n = col.len();
    match col {
        Column::Int64(mut c) => {
            if c.validity.is_none() {
                c.validity = Some(Bitmap::ones(n));
            }
            Column::Int64(c)
        }
        Column::Float64(mut c) => {
            if c.validity.is_none() {
                c.validity = Some(Bitmap::ones(n));
            }
            Column::Float64(c)
        }
        Column::Bool(mut c) => {
            if c.validity.is_none() {
                c.validity = Some(Bitmap::ones(n));
            }
            Column::Bool(c)
        }
        Column::Utf8(mut c) => {
            if c.validity.is_none() {
                c.validity = Some(Bitmap::ones(n));
            }
            Column::Utf8(c)
        }
    }
}

/// Gather a predicate's columns at `rows` into a small eval table
/// (serial — runs inside a morsel closure).
fn gather_rows_table(
    snap: &Table,
    cols: &[usize],
    fields: &[Field],
    rows: &[usize],
) -> Table {
    let gathered: Vec<Arc<Column>> = cols
        .iter()
        .map(|&i| Arc::new(snap.column(i).take(rows)))
        .collect();
    Table::from_parts(Schema::new(fields.to_vec()), gathered, rows.len())
}

/// Gather a post-join predicate's columns at the morsel's current pair
/// list (serial — runs inside a morsel closure).
fn gather_pairs_table(
    view: &Table,
    right: Option<&Table>,
    cols: &[(Side, usize)],
    fields: &[Field],
    li: &[i64],
    ri: &[i64],
) -> Table {
    let mut lrows: Option<Vec<usize>> = None;
    let gathered: Vec<Arc<Column>> = cols
        .iter()
        .map(|&(s, i)| {
            let c = match s {
                Side::L => {
                    let lr = lrows.get_or_insert_with(|| {
                        li.iter().map(|&x| x as usize).collect()
                    });
                    view.column(i).take(lr)
                }
                Side::R => serial_take_opt(
                    right.expect("post-join gather without right side")
                        .column(i),
                    ri,
                ),
            };
            Arc::new(c)
        })
        .collect();
    Table::from_parts(Schema::new(fields.to_vec()), gathered, li.len())
}

// ---- fused segment executor ------------------------------------------------

/// Run one fused segment: validate every stage in chain order (so a
/// fused plan fails with exactly the materialised path's first error),
/// build the probe state, stream every morsel through the fused ops,
/// then finish with the partial-agg merge or the single output gather.
/// `pre_joined` carries a distributed probe's already-shuffled right
/// side and the shuffle seconds to book under the join's stage slot.
fn run_segment(
    pipe: &Pipeline,
    seg: &FusedSegment,
    input: &Table,
    env: &Env,
    phases: &mut Phases,
    pre_joined: Option<(&Table, f64)>,
) -> Result<Table> {
    let stages = &pipe.stages[seg.start..seg.end];
    let names: Vec<String> =
        stages.iter().map(|s| s.name().to_string()).collect();
    let mut seg_clock = StageClock::new(names.clone());

    // ---- plan walk: validate in stage order, build per-morsel ops ----
    let mut view = input.clone();
    let mut mops: Vec<SegOp> = Vec::with_capacity(stages.len());
    let mut join_info: Option<(&Table, &JoinOptions)> = None;
    // Post-join logical schema and its (side, source) column mapping.
    let mut cur_schema: Option<Schema> = None;
    let mut out_cols: Vec<(Side, usize)> = Vec::new();
    let mut group_plan: Option<GroupPlan> = None;

    for (k, stage) in stages.iter().enumerate() {
        match stage {
            Stage::Select(pred) => {
                if join_info.is_none() {
                    // Zero-row eval surfaces missing-column and type
                    // errors in exact evaluation order.
                    pred.eval_mask_range(&view, 0, 0)?;
                    let mut names_v = Vec::new();
                    pred_columns(pred, &mut names_v);
                    let mut cols = Vec::new();
                    let mut fields = Vec::new();
                    for nm in &names_v {
                        let i = view.schema().index_of(nm)?;
                        cols.push(i);
                        fields.push(view.schema().fields()[i].clone());
                    }
                    mops.push(SegOp::PreFilter {
                        pred,
                        snap: view.clone(),
                        cols,
                        fields,
                    });
                } else {
                    let schema = cur_schema.as_ref().expect("joined schema");
                    let mut names_v = Vec::new();
                    pred_columns(pred, &mut names_v);
                    let mut cols = Vec::new();
                    let mut fields = Vec::new();
                    for nm in &names_v {
                        // Permissive: unresolvable names are left out so
                        // the zero-row eval below reports them (or an
                        // earlier type error) in evaluation order.
                        if let Ok(i) = schema.index_of(nm) {
                            cols.push(out_cols[i]);
                            fields.push(schema.fields()[i].clone());
                        }
                    }
                    let t0 = Table::empty(Schema::new(fields.clone()));
                    pred.eval_mask_range(&t0, 0, 0)?;
                    mops.push(SegOp::PostFilter { pred, cols, fields });
                }
            }
            Stage::Project(cols) => {
                if join_info.is_none() {
                    let t = Timer::start();
                    let names_p: Vec<&str> =
                        cols.iter().map(|s| s.as_str()).collect();
                    view = ops::project(&view, &names_p)?;
                    seg_clock.add_seconds(k, t.seconds());
                    mops.push(SegOp::PreMark);
                } else {
                    let schema = cur_schema.as_mut().expect("joined schema");
                    let idxs: Vec<usize> = cols
                        .iter()
                        .map(|nm| schema.index_of(nm))
                        .collect::<Result<Vec<_>>>()?;
                    out_cols = idxs.iter().map(|&i| out_cols[i]).collect();
                    *schema = schema.project(&idxs);
                    mops.push(SegOp::PostMark);
                }
            }
            Stage::Join { right, opts } => {
                let rt: &Table = match pre_joined {
                    Some((t, _)) => t,
                    None => Pipeline::side(env, right)?,
                };
                validate(&view, rt, opts)?;
                cur_schema =
                    Some(view.schema().join(rt.schema(), &opts.suffix));
                out_cols = (0..view.num_columns())
                    .map(|i| (Side::L, i))
                    .chain((0..rt.num_columns()).map(|j| (Side::R, j)))
                    .collect();
                join_info = Some((rt, opts));
                mops.push(SegOp::Probe);
            }
            Stage::GroupBy(gopts) => {
                // Mirror ops::groupby's validation order exactly.
                if gopts.keys.is_empty() {
                    return Err(RylonError::invalid(
                        "groupby requires at least one key",
                    ));
                }
                if gopts.aggs.is_empty() {
                    return Err(RylonError::invalid(
                        "groupby requires at least one aggregate",
                    ));
                }
                let joined = join_info.is_some();
                let schema_ref: &Schema = match &cur_schema {
                    Some(s) => s,
                    None => view.schema(),
                };
                let src_of = |i: usize| -> (Side, usize) {
                    if joined {
                        out_cols[i]
                    } else {
                        (Side::L, i)
                    }
                };
                let mut key_srcs = Vec::new();
                for kk in &gopts.keys {
                    let i = schema_ref.index_of(kk)?;
                    key_srcs.push(src_of(i));
                }
                let mut agg_srcs = Vec::new();
                let mut agg_dts = Vec::new();
                for a in &gopts.aggs {
                    let i = schema_ref.index_of(&a.column)?;
                    agg_srcs.push(src_of(i));
                    agg_dts.push(schema_ref.fields()[i].dtype);
                }
                let mut out_dtypes = Vec::new();
                for (a, dt) in gopts.aggs.iter().zip(&agg_dts) {
                    out_dtypes.push(a.kind.output_dtype(*dt)?);
                }
                group_plan = Some(GroupPlan {
                    opts: gopts,
                    key_srcs,
                    agg_srcs,
                    out_dtypes,
                });
                mops.push(SegOp::GroupMark);
            }
            _ => unreachable!("non-fusable stage in fused segment"),
        }
    }

    // ---- join prologue: build-side chains (the view is final now) ----
    let probe_ctx: Option<ProbeCtx> = match join_info {
        Some((rt, opts)) => {
            let t = Timer::start();
            let lk = key_columns(&view, &opts.left_on)?;
            let rk = key_columns(rt, &opts.right_on)?;
            let mut rh = Vec::new();
            hash::hash_columns(&rk, rt.num_rows(), &mut rh);
            let chains = HashChains::build_parallel(
                &rh,
                |j| key_has_null(&rk, j),
                exec::parallelism_for(rt.num_rows()),
            );
            let fast = match (&lk[..], &rk[..]) {
                ([Column::Int64(a)], [Column::Int64(b)]) => {
                    Some((a.values(), b.values()))
                }
                _ => None,
            };
            let join_slot = seg.join_at.expect("probe without join_at")
                - seg.start;
            let shuffle_secs = pre_joined.map(|(_, s)| s).unwrap_or(0.0);
            seg_clock.add_seconds(join_slot, t.seconds() + shuffle_secs);
            Some(ProbeCtx {
                lk,
                rk,
                chains,
                fast,
                want_left_unmatched: opts.join_type == JoinType::Left,
            })
        }
        None => None,
    };
    let right_tbl: Option<&Table> = join_info.map(|(t, _)| t);

    // ---- the fused morsel pass ----
    let n = view.num_rows();
    let mexec = exec::parallelism_for(n);
    let has_join = probe_ctx.is_some();
    // A groupby over an unfiltered view still needs explicit entry ids.
    let force_rows = group_plan.is_some() && !has_join;
    let outs = exec::for_each_morsel(n, mexec, |m| -> Result<MorselOut> {
        let mut clock = StageClock::new(names.clone());
        let mut rows: Vec<usize> = Vec::new();
        // While `dense`, the surviving rows are exactly `m.range()`.
        let mut dense = true;
        let mut li: Vec<i64> = Vec::new();
        let mut ri: Vec<i64> = Vec::new();
        let mut saw = false;
        let mut hbuf: Vec<u64> = Vec::new();
        for (k, op) in mops.iter().enumerate() {
            let t = Timer::start();
            match op {
                SegOp::PreFilter {
                    pred,
                    snap,
                    cols,
                    fields,
                } => {
                    if dense {
                        let mask =
                            pred.eval_mask_range(snap, m.start, m.end)?;
                        rows = m
                            .range()
                            .zip(mask)
                            .filter_map(|(i, keep)| keep.then_some(i))
                            .collect();
                        dense = false;
                    } else {
                        let t0 =
                            gather_rows_table(snap, cols, fields, &rows);
                        let mask =
                            pred.eval_mask_range(&t0, 0, rows.len())?;
                        let mut it = mask.iter();
                        rows.retain(|_| *it.next().expect("mask len"));
                    }
                    clock.add_seconds(k, t.seconds());
                    clock.add_rows(k, rows.len() as u64);
                }
                SegOp::PreMark => {
                    let flowing =
                        if dense { m.len() } else { rows.len() };
                    clock.add_seconds(k, t.seconds());
                    clock.add_rows(k, flowing as u64);
                }
                SegOp::Probe => {
                    let p = probe_ctx.as_ref().expect("probe ctx");
                    if dense {
                        rows = m.range().collect();
                        dense = false;
                    }
                    hash::hash_rows(&p.lk, &rows, &mut hbuf);
                    probe_rows(
                        &p.lk,
                        &p.rk,
                        &rows,
                        &hbuf,
                        &p.chains,
                        p.fast,
                        p.want_left_unmatched,
                        &mut li,
                        &mut ri,
                    );
                    // Unmatched flag over the morsel's *full* pair list,
                    // before any post-join filter trims it.
                    saw = ri.iter().any(|&r| r < 0);
                    clock.add_seconds(k, t.seconds());
                    clock.add_rows(k, li.len() as u64);
                }
                SegOp::PostFilter { pred, cols, fields } => {
                    let t0 = gather_pairs_table(
                        &view, right_tbl, cols, fields, &li, &ri,
                    );
                    let mask = pred.eval_mask_range(&t0, 0, li.len())?;
                    let mut ia = mask.iter();
                    li.retain(|_| *ia.next().expect("mask len"));
                    let mut ib = mask.iter();
                    ri.retain(|_| *ib.next().expect("mask len"));
                    clock.add_seconds(k, t.seconds());
                    clock.add_rows(k, li.len() as u64);
                }
                SegOp::PostMark => {
                    clock.add_seconds(k, t.seconds());
                    clock.add_rows(k, li.len() as u64);
                }
                SegOp::GroupMark => {}
            }
        }
        if dense && force_rows {
            rows = m.range().collect();
        }
        Ok(MorselOut {
            rows,
            li,
            ri,
            saw,
            clock,
        })
    });

    // ---- fold morsel outputs in morsel order ----
    let mut all_rows: Vec<usize> = Vec::new();
    let mut all_li: Vec<i64> = Vec::new();
    let mut all_ri: Vec<i64> = Vec::new();
    let mut saw = false;
    for o in outs {
        let o = o?;
        seg_clock.absorb(&o.clock);
        if has_join {
            all_li.extend(o.li);
            all_ri.extend(o.ri);
            saw |= o.saw;
        } else {
            all_rows.extend(o.rows);
        }
    }

    // ---- segment epilogue: partial-agg merge or one output gather ----
    let last = mops.len() - 1;
    let out = if let Some(gp) = &group_plan {
        let t = Timer::start();
        let li_owned;
        let (li, ri): (&[i64], &[i64]) = if has_join {
            (&all_li, &all_ri)
        } else {
            li_owned = all_rows
                .iter()
                .map(|&r| r as i64)
                .collect::<Vec<i64>>();
            (&li_owned, &[])
        };
        let g = group_epilogue(gp, &view, right_tbl, li, ri, saw)?;
        seg_clock.add_seconds(last, t.seconds());
        seg_clock.add_rows(last, g.num_rows() as u64);
        g
    } else if has_join {
        let t = Timer::start();
        let schema = cur_schema.clone().expect("joined schema");
        let cols: Vec<Arc<Column>> = out_cols
            .iter()
            .map(|&(s, i)| {
                let src = match s {
                    Side::L => view.column(i),
                    Side::R => {
                        right_tbl.expect("right side").column(i)
                    }
                };
                let idx = match s {
                    Side::L => &all_li,
                    Side::R => &all_ri,
                };
                let mut c = take_opt(src, idx);
                if s == Side::R && saw {
                    c = force_valid(c);
                }
                Arc::new(c)
            })
            .collect();
        let joined = Table::from_parts(schema, cols, all_li.len());
        seg_clock.add_seconds(last, t.seconds());
        joined
    } else if mops
        .iter()
        .any(|o| matches!(o, SegOp::PreFilter { .. }))
    {
        let t = Timer::start();
        let taken = take_parallel(
            &view,
            &all_rows,
            exec::parallelism_for(all_rows.len()),
        );
        seg_clock.add_seconds(last, t.seconds());
        taken
    } else {
        // Projection-only segment: the view *is* the output (zero-copy).
        view
    };
    seg_clock.commit(phases);
    Ok(out)
}

/// The fused partial-agg: group the surviving (left, right) entries and
/// fold each aggregate without materialising the joined table. Hashing,
/// partitioning, intern order, accumulator fold order and group-order
/// recovery all mirror `ops::groupby` exactly, so the output is
/// bit-identical to grouping the materialised table.
fn group_epilogue(
    gp: &GroupPlan,
    view: &Table,
    right: Option<&Table>,
    li: &[i64],
    ri: &[i64],
    saw_unmatched: bool,
) -> Result<Table> {
    let n = li.len();
    let col_of = |s: Side, i: usize| -> &Column {
        match s {
            Side::L => view.column(i),
            Side::R => right.expect("grouped right side").column(i),
        }
    };
    let row_of = |s: Side, e: usize| -> i64 {
        match s {
            Side::L => li[e],
            Side::R => ri[e],
        }
    };
    // Hash of one entry's key cell — equals hash_cell on the cell the
    // materialised gather would have produced (`-1` gathers a null).
    let cell_hash = |src: (Side, usize), e: usize| -> u64 {
        let r = row_of(src.0, e);
        if r < 0 {
            hash::hash_null()
        } else {
            hash::hash_cell(col_of(src.0, src.1), r as usize)
        }
    };
    // hash_columns' fold: first column's cell hash, then hash_combine.
    let entry_hash = |e: usize| -> u64 {
        let mut h = cell_hash(gp.key_srcs[0], e);
        for &src in &gp.key_srcs[1..] {
            h = hash::hash_combine(h, cell_hash(src, e));
        }
        h
    };
    let mut ehash = vec![0u64; n];
    let hexec = exec::parallelism_for(n);
    exec::fill_parallel(ehash.as_mut_slice(), hexec, |m, dst| {
        for (k, d) in dst.iter_mut().enumerate() {
            *d = entry_hash(m.start + k);
        }
    });

    // Key equality on materialised-cell semantics: both-null cells are
    // equal (one group), null vs value are not.
    let cell_eq = |src: (Side, usize), a: usize, b: usize| -> bool {
        let c = col_of(src.0, src.1);
        let ra = row_of(src.0, a);
        let rb = row_of(src.0, b);
        let va = ra >= 0 && c.is_valid(ra as usize);
        let vb = rb >= 0 && c.is_valid(rb as usize);
        match (va, vb) {
            (true, true) => c.eq_rows(ra as usize, c, rb as usize),
            (false, false) => true,
            _ => false,
        }
    };
    let entry_eq = |a: usize, b: usize| -> bool {
        gp.key_srcs.iter().all(|&src| cell_eq(src, a, b))
    };
    let new_acc_row = || -> Vec<Accumulator> {
        gp.opts
            .aggs
            .iter()
            .zip(&gp.agg_srcs)
            .map(|(a, &(s, i))| {
                a.kind.new_acc(col_of(s, i).dtype() == DataType::Int64)
            })
            .collect()
    };
    let update_row = |accs: &mut Vec<Accumulator>, e: usize| {
        for (acc, &(s, i)) in accs.iter_mut().zip(&gp.agg_srcs) {
            let r = row_of(s, e);
            if r >= 0 {
                // A null-extended entry is a null cell: skipped, just
                // like Accumulator::update skips invalid source cells.
                acc.update(col_of(s, i), r as usize);
            }
        }
    };

    let gexec = exec::parallelism_for(n);
    let (rep_entries, accs): (Vec<usize>, Vec<Vec<Accumulator>>) =
        if gexec.is_parallel() {
            let nparts = gexec.threads();
            let rows_by_part =
                hash::partition_rows(&ehash, nparts, gexec, |_| false);
            let parts = exec::run_partitions(nparts, |p| {
                let mut gi = GroupIndex::with_capacity(n / nparts + 8);
                let mut part_accs: Vec<Vec<Accumulator>> = Vec::new();
                for morsel_buckets in &rows_by_part {
                    for &row in &morsel_buckets[p] {
                        let e = row as usize;
                        let (gid, new) =
                            gi.intern(ehash[e], e, entry_eq);
                        if new {
                            part_accs.push(new_acc_row());
                        }
                        update_row(&mut part_accs[gid as usize], e);
                    }
                }
                (gi, part_accs)
            });
            let mut order: Vec<(usize, usize, usize)> = Vec::new();
            for (p, (gi, _)) in parts.iter().enumerate() {
                for (g, &rep) in gi.rep_rows().iter().enumerate() {
                    order.push((rep, p, g));
                }
            }
            order.sort_unstable();
            let mut parts_accs: Vec<Vec<Option<Vec<Accumulator>>>> = parts
                .into_iter()
                .map(|(_, a)| a.into_iter().map(Some).collect())
                .collect();
            let mut rep_entries = Vec::with_capacity(order.len());
            let mut accs = Vec::with_capacity(order.len());
            for &(rep, p, g) in &order {
                rep_entries.push(rep);
                accs.push(
                    parts_accs[p][g].take().expect("group consumed twice"),
                );
            }
            (rep_entries, accs)
        } else {
            let mut gi = GroupIndex::with_capacity(n);
            let mut accs: Vec<Vec<Accumulator>> = Vec::new();
            for e in 0..n {
                let (gid, new) = gi.intern(ehash[e], e, entry_eq);
                if new {
                    accs.push(new_acc_row());
                }
                update_row(&mut accs[gid as usize], e);
            }
            (gi.rep_rows().to_vec(), accs)
        };

    // Assemble: key columns gathered at the representative entries,
    // then one column per aggregate.
    let ngroups = rep_entries.len();
    let mut fields: Vec<Field> = Vec::new();
    let mut out: Vec<Column> = Vec::new();
    for (k, &(s, i)) in gp.opts.keys.iter().zip(&gp.key_srcs) {
        let src = col_of(s, i);
        let idx: Vec<i64> =
            rep_entries.iter().map(|&e| row_of(s, e)).collect();
        let mut kc = take_opt(src, &idx);
        if s == Side::R && saw_unmatched {
            kc = force_valid(kc);
        }
        fields.push(Field::new(k.clone(), src.dtype()));
        out.push(kc);
    }
    for ((agg, &dt), slot) in gp
        .opts
        .aggs
        .iter()
        .zip(&gp.out_dtypes)
        .zip(0..gp.opts.aggs.len())
    {
        fields.push(Field::new(agg.name.clone(), dt));
        let mut b = ColumnBuilder::new(dt, ngroups);
        for acc_row in &accs {
            b.push_value(&acc_row[slot].finish())?;
        }
        out.push(b.finish());
    }
    Table::try_new(Schema::new(fields), out)
}

// ---- fused pipeline drivers ------------------------------------------------

/// Fused local executor: breakers run operator-at-a-time through the
/// shared stage runner, fused segments stream. The streaming prefix is
/// subsumed — morsels already bound the working set, so `batch_rows`
/// changes nothing under fusion.
pub(crate) fn run_local(
    pipe: &Pipeline,
    input: &Table,
    env: &Env,
) -> Result<(Table, Phases)> {
    let mut phases = Phases::new();
    let mut cur = input.clone();
    for seg in plan(&pipe.stages, false) {
        match seg {
            Segment::Breaker(i) => {
                let stage = &pipe.stages[i];
                cur = phases.time(stage.name(), || {
                    Pipeline::run_stage_local(stage, &cur, env)
                })?;
                phases.count("rows_out", cur.num_rows() as u64);
            }
            Segment::Fused(fseg) => {
                cur = run_segment(pipe, &fseg, &cur, env, &mut phases, None)?;
            }
        }
    }
    Ok((cur, phases))
}

/// Fused SPMD executor: exchanges stay breakers; a fused probe segment
/// shuffles both sides by key (the same `dist_join` exchange and fault
/// label) and then streams the local probe.
pub(crate) fn run_dist(
    pipe: &Pipeline,
    ctx: &mut RankCtx,
    input: &Table,
    env: &Env,
) -> Result<(Table, Phases)> {
    let mut phases = Phases::new();
    let mut cur = input.clone();
    for seg in plan(&pipe.stages, true) {
        match seg {
            Segment::Breaker(i) => {
                let stage = &pipe.stages[i];
                let t = Timer::start();
                cur = Pipeline::run_stage_dist(ctx, stage, &cur, env)?;
                phases.add_seconds(stage.name(), t.seconds());
                phases.count("rows_out", cur.num_rows() as u64);
            }
            Segment::Fused(fseg) => {
                cur = match fseg.join_at {
                    Some(j) => {
                        let (right, opts) = match &pipe.stages[j] {
                            Stage::Join { right, opts } => (right, opts),
                            _ => unreachable!("join_at points at a join"),
                        };
                        let right_tbl = Pipeline::side(env, right)?;
                        let t = Timer::start();
                        ctx.set_op("dist_join");
                        let ls = shuffle(ctx, &cur, &opts.left_on)?;
                        let rs = shuffle(ctx, right_tbl, &opts.right_on)?;
                        let secs = t.seconds();
                        run_segment(
                            pipe,
                            &fseg,
                            &ls,
                            env,
                            &mut phases,
                            Some((&rs, secs)),
                        )?
                    }
                    None => run_segment(
                        pipe, &fseg, &cur, env, &mut phases, None,
                    )?,
                };
            }
        }
    }
    Ok((cur, phases))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::groupby::{Agg, GroupByOptions};
    use crate::ops::orderby::SortKey;

    fn stages_of(p: &Pipeline) -> &[Stage] {
        p.stages()
    }

    #[test]
    fn plan_fuses_select_project_hash_join_groupby() {
        let p = Pipeline::new()
            .select("v >= 10")
            .unwrap()
            .project(&["grp", "v"])
            .join(
                "dim",
                JoinOptions::inner("grp", "grp").with_algo(JoinAlgo::Hash),
            )
            .select("v < 90")
            .unwrap()
            .groupby(GroupByOptions::new(&["name"], vec![Agg::sum("v")]));
        let segs = plan(stages_of(&p), false);
        assert_eq!(
            segs,
            vec![Segment::Fused(FusedSegment {
                start: 0,
                end: 5,
                join_at: Some(2),
                group_at: Some(4),
            })]
        );
    }

    #[test]
    fn plan_breaks_on_sort_join_and_orderby() {
        let p = Pipeline::new()
            .select("v >= 10")
            .unwrap()
            .join("dim", JoinOptions::inner("grp", "grp")) // Sort algo
            .groupby(GroupByOptions::new(&["name"], vec![Agg::sum("v")]))
            .orderby(vec![SortKey::asc("name")]);
        let segs = plan(stages_of(&p), false);
        assert_eq!(
            segs,
            vec![
                Segment::Fused(FusedSegment {
                    start: 0,
                    end: 1,
                    join_at: None,
                    group_at: None,
                }),
                Segment::Breaker(1),
                Segment::Fused(FusedSegment {
                    start: 2,
                    end: 3,
                    join_at: None,
                    group_at: Some(2),
                }),
                Segment::Breaker(3),
            ]
        );
    }

    #[test]
    fn plan_splits_two_probes_and_dist_groupby() {
        let hash = |l: &str, r: &str| {
            JoinOptions::inner(l, r).with_algo(JoinAlgo::Hash)
        };
        let p = Pipeline::new()
            .select("v >= 10")
            .unwrap()
            .join("a", hash("k", "k"))
            .join("b", hash("k2", "k2"))
            .groupby(GroupByOptions::new(&["k"], vec![Agg::sum("v")]));
        // Local: one probe per segment; the second segment absorbs the
        // terminal groupby.
        let segs = plan(stages_of(&p), false);
        assert_eq!(
            segs,
            vec![
                Segment::Fused(FusedSegment {
                    start: 0,
                    end: 2,
                    join_at: Some(1),
                    group_at: None,
                }),
                Segment::Fused(FusedSegment {
                    start: 2,
                    end: 4,
                    join_at: Some(2),
                    group_at: Some(3),
                }),
            ]
        );
        // Distributed: probes start their own segments (shuffle is an
        // exchange) and groupby is a breaker.
        let dsegs = plan(stages_of(&p), true);
        assert_eq!(
            dsegs,
            vec![
                Segment::Fused(FusedSegment {
                    start: 0,
                    end: 1,
                    join_at: None,
                    group_at: None,
                }),
                Segment::Fused(FusedSegment {
                    start: 1,
                    end: 2,
                    join_at: Some(1),
                    group_at: None,
                }),
                Segment::Fused(FusedSegment {
                    start: 2,
                    end: 3,
                    join_at: Some(2),
                    group_at: None,
                }),
                Segment::Breaker(3),
            ]
        );
    }
}
