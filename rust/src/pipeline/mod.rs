//! ETL pipeline: a declarative stage chain (select → project → join →
//! groupby → …) executed locally or SPMD across a cluster, with
//! per-stage timing. This is the "streaming orchestrator" face of the
//! coordinator: sources are processed in bounded batches where stages
//! allow it, and the chunked shuffle bounds in-flight bytes for the
//! stages that don't (backpressure end to end).

mod fuse;

use std::collections::HashMap;
use std::path::Path;

use crate::dist::{
    dist_difference, dist_groupby, dist_intersect, dist_join, dist_sort,
    dist_union, rebalance, RankCtx,
};
use crate::error::{Result, RylonError};
use crate::io::ryf::{scan_ryf, scan_ryf_partition, ScanOptions};
use crate::metrics::Phases;
use crate::ops;
use crate::ops::groupby::GroupByOptions;
use crate::ops::join::JoinOptions;
use crate::ops::orderby::SortKey;
use crate::ops::select::Predicate;
use crate::table::Table;

/// One pipeline stage.
#[derive(Debug, Clone)]
pub enum Stage {
    /// Row filter (streamable).
    Select(Predicate),
    /// Column subset (streamable).
    Project(Vec<String>),
    /// Join against a named side table from the environment.
    Join { right: String, opts: JoinOptions },
    /// Set operators against a named side table.
    Union { other: String },
    Intersect { other: String },
    Difference { other: String },
    /// Group + aggregate.
    GroupBy(GroupByOptions),
    /// Global sort.
    OrderBy(Vec<SortKey>),
    /// Even out partition sizes (dist only; local no-op).
    Rebalance,
    /// Drop duplicate rows.
    Distinct,
}

impl Stage {
    fn name(&self) -> &'static str {
        match self {
            Stage::Select(_) => "select",
            Stage::Project(_) => "project",
            Stage::Join { .. } => "join",
            Stage::Union { .. } => "union",
            Stage::Intersect { .. } => "intersect",
            Stage::Difference { .. } => "difference",
            Stage::GroupBy(_) => "groupby",
            Stage::OrderBy(_) => "orderby",
            Stage::Rebalance => "rebalance",
            Stage::Distinct => "distinct",
        }
    }

    /// Streamable stages commute with row batching.
    fn streamable(&self) -> bool {
        matches!(self, Stage::Select(_) | Stage::Project(_))
    }
}

/// Named side tables a pipeline's join/set stages reference. In
/// distributed runs, each rank's env holds that rank's partitions.
pub type Env = HashMap<String, Table>;

/// A declarative stage chain.
#[derive(Debug, Clone, Default)]
pub struct Pipeline {
    stages: Vec<Stage>,
    /// Batch size for the streaming prefix (0 = no batching).
    batch_rows: usize,
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    /// Process the streamable stage prefix in batches of `rows`.
    pub fn with_batch_rows(mut self, rows: usize) -> Pipeline {
        self.batch_rows = rows;
        self
    }

    pub fn select(mut self, expr: &str) -> Result<Pipeline> {
        self.stages.push(Stage::Select(Predicate::parse(expr)?));
        Ok(self)
    }

    pub fn select_pred(mut self, pred: Predicate) -> Pipeline {
        self.stages.push(Stage::Select(pred));
        self
    }

    pub fn project(mut self, columns: &[&str]) -> Pipeline {
        self.stages.push(Stage::Project(
            columns.iter().map(|s| s.to_string()).collect(),
        ));
        self
    }

    pub fn join(mut self, right: &str, opts: JoinOptions) -> Pipeline {
        self.stages.push(Stage::Join {
            right: right.to_string(),
            opts,
        });
        self
    }

    pub fn union(mut self, other: &str) -> Pipeline {
        self.stages.push(Stage::Union {
            other: other.to_string(),
        });
        self
    }

    pub fn intersect(mut self, other: &str) -> Pipeline {
        self.stages.push(Stage::Intersect {
            other: other.to_string(),
        });
        self
    }

    pub fn difference(mut self, other: &str) -> Pipeline {
        self.stages.push(Stage::Difference {
            other: other.to_string(),
        });
        self
    }

    pub fn groupby(mut self, opts: GroupByOptions) -> Pipeline {
        self.stages.push(Stage::GroupBy(opts));
        self
    }

    pub fn orderby(mut self, keys: Vec<SortKey>) -> Pipeline {
        self.stages.push(Stage::OrderBy(keys));
        self
    }

    pub fn rebalance(mut self) -> Pipeline {
        self.stages.push(Stage::Rebalance);
        self
    }

    pub fn distinct(mut self) -> Pipeline {
        self.stages.push(Stage::Distinct);
        self
    }

    pub fn stages(&self) -> &[Stage] {
        &self.stages
    }

    fn side<'e>(env: &'e Env, name: &str) -> Result<&'e Table> {
        env.get(name).ok_or_else(|| {
            RylonError::invalid(format!("pipeline env missing table '{name}'"))
        })
    }

    /// Run one stage operator-at-a-time, locally. Shared by the
    /// materialized executor below and the fused executor's breaker
    /// path ([`fuse`]), so both paths run the exact same operator for
    /// any stage that materialises.
    fn run_stage_local(
        stage: &Stage,
        cur: &Table,
        env: &Env,
    ) -> Result<Table> {
        match stage {
            Stage::Select(p) => ops::select(cur, p),
            Stage::Project(cols) => {
                let names: Vec<&str> =
                    cols.iter().map(|s| s.as_str()).collect();
                ops::project(cur, &names)
            }
            Stage::Join { right, opts } => {
                ops::join(cur, Self::side(env, right)?, opts)
            }
            Stage::Union { other } => {
                ops::union(cur, Self::side(env, other)?)
            }
            Stage::Intersect { other } => {
                ops::intersect(cur, Self::side(env, other)?)
            }
            Stage::Difference { other } => {
                ops::difference(cur, Self::side(env, other)?)
            }
            Stage::GroupBy(opts) => ops::groupby(cur, opts),
            Stage::OrderBy(keys) => ops::orderby(cur, keys),
            Stage::Rebalance => Ok(cur.clone()),
            Stage::Distinct => Ok(ops::distinct(cur)),
        }
    }

    /// Run one stage SPMD on a rank (distributed operators for the
    /// barrier stages, local operators for the element-wise ones) —
    /// shared by the materialized executor and the fused breaker path.
    fn run_stage_dist(
        ctx: &mut RankCtx,
        stage: &Stage,
        cur: &Table,
        env: &Env,
    ) -> Result<Table> {
        match stage {
            Stage::Select(p) => ops::select(cur, p),
            Stage::Project(cols) => {
                let names: Vec<&str> =
                    cols.iter().map(|s| s.as_str()).collect();
                ops::project(cur, &names)
            }
            Stage::Join { right, opts } => {
                dist_join(ctx, cur, Self::side(env, right)?, opts)
            }
            Stage::Union { other } => {
                dist_union(ctx, cur, Self::side(env, other)?)
            }
            Stage::Intersect { other } => {
                dist_intersect(ctx, cur, Self::side(env, other)?)
            }
            Stage::Difference { other } => {
                dist_difference(ctx, cur, Self::side(env, other)?)
            }
            Stage::GroupBy(opts) => dist_groupby(ctx, cur, opts),
            Stage::OrderBy(keys) => dist_sort(ctx, cur, keys),
            Stage::Rebalance => rebalance(ctx, cur),
            Stage::Distinct => {
                let local = crate::dist::shuffle_all_columns(ctx, cur)?;
                Ok(ops::distinct(&local))
            }
        }
    }

    /// Execute locally (single partition). With `[exec] pipeline_fuse`
    /// on (the default), the stage chain is compiled into fused morsel
    /// segments ([`fuse`], `docs/PIPELINE.md`); the operator-at-a-time
    /// path below is the bit-identity oracle it is checked against.
    pub fn run_local(
        &self,
        input: &Table,
        env: &Env,
    ) -> Result<(Table, Phases)> {
        if crate::exec::pipeline_fuse() {
            return fuse::run_local(self, input, env);
        }
        let mut phases = Phases::new();
        let mut cur = self.run_stream_prefix_local(input, &mut phases)?;
        for stage in self.stages.iter().skip(self.stream_prefix_len()) {
            cur = phases.time(stage.name(), || {
                Self::run_stage_local(stage, &cur, env)
            })?;
            phases.count("rows_out", cur.num_rows() as u64);
        }
        Ok((cur, phases))
    }

    /// Execute SPMD on a rank (distributed operators for the barrier
    /// stages, local operators for the element-wise ones). Honours the
    /// `[exec] pipeline_fuse` knob exactly like [`Pipeline::run_local`].
    pub fn run_dist(
        &self,
        ctx: &mut RankCtx,
        input: &Table,
        env: &Env,
    ) -> Result<(Table, Phases)> {
        if crate::exec::pipeline_fuse() {
            return fuse::run_dist(self, ctx, input, env);
        }
        let mut phases = Phases::new();
        let mut cur = self.run_stream_prefix_local(input, &mut phases)?;
        for stage in self.stages.iter().skip(self.stream_prefix_len()) {
            let t = crate::metrics::Timer::start();
            cur = Self::run_stage_dist(ctx, stage, &cur, env)?;
            phases.add_seconds(stage.name(), t.seconds());
            phases.count("rows_out", cur.num_rows() as u64);
        }
        Ok((cur, phases))
    }

    /// The pushdown view of this pipeline's leading streamable run:
    /// the conjunction of its `Select` predicates and — when the run
    /// contains a `Project` — the live column set (the first
    /// projection's columns plus every pushed predicate's columns).
    /// The scan uses the predicate to skip whole row groups via zone
    /// maps and the column set to skip dead column payloads; every
    /// stage still runs over the scan output, so results *and errors*
    /// are identical to an unpruned read (`docs/STORAGE.md`).
    pub fn scan_pushdown(&self) -> ScanOptions {
        let mut predicate: Option<Predicate> = None;
        let mut pred_cols: Vec<String> = Vec::new();
        let mut project: Option<&Vec<String>> = None;
        for stage in &self.stages {
            match stage {
                Stage::Select(p) => {
                    fuse::pred_columns(p, &mut pred_cols);
                    predicate = Some(match predicate.take() {
                        None => p.clone(),
                        Some(acc) => acc.and(p.clone()),
                    });
                }
                Stage::Project(cols) => {
                    if project.is_none() {
                        project = Some(cols);
                    }
                }
                _ => break,
            }
        }
        let projection = project.map(|cols| {
            let mut live = cols.clone();
            for c in pred_cols {
                if !live.contains(&c) {
                    live.push(c);
                }
            }
            live
        });
        ScanOptions {
            predicate,
            projection,
        }
    }

    /// Execute locally over an RYF file, pushing the leading predicate
    /// and live column set into the scan ([`scan_ryf`]). The scan
    /// seconds land in a `scan` phase and the post-pushdown row count
    /// in a `rows_scanned` counter.
    pub fn run_ryf_local(
        &self,
        path: impl AsRef<Path>,
        env: &Env,
    ) -> Result<(Table, Phases)> {
        let opts = self.scan_pushdown();
        let t = crate::metrics::Timer::start();
        let input = scan_ryf(path, &opts)?;
        let secs = t.seconds();
        let (out, mut phases) = self.run_local(&input, env)?;
        phases.add_seconds("scan", secs);
        phases.count("rows_scanned", input.num_rows() as u64);
        Ok((out, phases))
    }

    /// Execute SPMD over an RYF file: each rank scans its share of row
    /// groups ([`scan_ryf_partition`]) with pushdown, then runs the
    /// stage chain.
    pub fn run_ryf_dist(
        &self,
        ctx: &mut RankCtx,
        path: impl AsRef<Path>,
        env: &Env,
    ) -> Result<(Table, Phases)> {
        let opts = self.scan_pushdown();
        let t = crate::metrics::Timer::start();
        let input = scan_ryf_partition(path, ctx.rank, ctx.size, &opts)?;
        let secs = t.seconds();
        let (out, mut phases) = self.run_dist(ctx, &input, env)?;
        phases.add_seconds("scan", secs);
        phases.count("rows_scanned", input.num_rows() as u64);
        Ok((out, phases))
    }

    /// Length of the leading streamable run (batched when batch_rows>0).
    fn stream_prefix_len(&self) -> usize {
        if self.batch_rows == 0 {
            return 0;
        }
        self.stages
            .iter()
            .take_while(|s| s.streamable())
            .count()
    }

    /// Run the streamable prefix in bounded batches.
    fn run_stream_prefix_local(
        &self,
        input: &Table,
        phases: &mut Phases,
    ) -> Result<Table> {
        let k = self.stream_prefix_len();
        if k == 0 {
            return Ok(input.clone());
        }
        let batch = self.batch_rows;
        let mut outs: Vec<Table> = Vec::new();
        let mut offset = 0;
        while offset < input.num_rows() || (offset == 0 && input.is_empty())
        {
            let chunk = input.slice(offset, batch.min(input.num_rows()));
            let mut cur = chunk;
            for stage in &self.stages[..k] {
                cur = phases.time(stage.name(), || -> Result<Table> {
                    match stage {
                        Stage::Select(p) => ops::select(&cur, p),
                        Stage::Project(cols) => {
                            let names: Vec<&str> =
                                cols.iter().map(|s| s.as_str()).collect();
                            ops::project(&cur, &names)
                        }
                        _ => unreachable!("non-streamable in prefix"),
                    }
                })?;
            }
            outs.push(cur);
            offset += batch;
            if input.is_empty() {
                break;
            }
        }
        let schema = outs
            .first()
            .map(|t| t.schema().clone())
            .unwrap_or_else(|| input.schema().clone());
        Table::concat_all(&schema, &outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::dist::{Cluster, DistConfig};
    use crate::ops::groupby::Agg;

    fn input() -> Table {
        Table::from_columns(vec![
            ("id", Column::from_i64((0..100).collect())),
            (
                "grp",
                Column::from_i64((0..100).map(|i| i % 5).collect()),
            ),
            (
                "v",
                Column::from_f64((0..100).map(|i| i as f64).collect()),
            ),
        ])
        .unwrap()
    }

    fn dim() -> Table {
        Table::from_columns(vec![
            ("grp", Column::from_i64((0..5).collect())),
            ("name", Column::from_str(&["a", "b", "c", "d", "e"])),
        ])
        .unwrap()
    }

    #[test]
    fn local_pipeline_end_to_end() {
        let p = Pipeline::new()
            .select("v >= 10")
            .unwrap()
            .join("dim", JoinOptions::inner("grp", "grp"))
            .groupby(GroupByOptions::new(
                &["name"],
                vec![Agg::sum("v"), Agg::count("v")],
            ))
            .orderby(vec![SortKey::asc("name")]);
        let mut env = Env::new();
        env.insert("dim".to_string(), dim());
        let (out, phases) = p.run_local(&input(), &env).unwrap();
        assert_eq!(out.num_rows(), 5);
        assert!(phases.seconds("join") >= 0.0);
        assert!(phases.counter("rows_out") > 0);
        // groups of 18 values each (ids 10..100, %5 → 18 per group).
        assert_eq!(
            out.column_by_name("count_v").unwrap().i64_values(),
            &[18, 18, 18, 18, 18]
        );
    }

    #[test]
    fn batched_prefix_equals_unbatched() {
        let p_batched = Pipeline::new()
            .with_batch_rows(7)
            .select("v < 50")
            .unwrap()
            .project(&["id", "v"]);
        let p_plain = Pipeline::new()
            .select("v < 50")
            .unwrap()
            .project(&["id", "v"]);
        let env = Env::new();
        let (a, _) = p_batched.run_local(&input(), &env).unwrap();
        let (b, _) = p_plain.run_local(&input(), &env).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.num_rows(), 50);
    }

    #[test]
    fn dist_pipeline_matches_local() {
        let build = || {
            Pipeline::new()
                .select("v >= 10")
                .unwrap()
                .join("dim", JoinOptions::inner("grp", "grp"))
                .groupby(GroupByOptions::new(&["name"], vec![Agg::sum("v")]))
        };
        // Local reference.
        let mut env = Env::new();
        env.insert("dim".to_string(), dim());
        let (local, _) = build().run_local(&input(), &env).unwrap();

        // Distributed: input split by rank, dim on rank 0 only.
        let cluster = Cluster::new(DistConfig::threads(4)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let whole = input();
                let n = whole.num_rows();
                let base = n / ctx.size;
                let extra = n % ctx.size;
                let my = base + (ctx.rank < extra) as usize;
                let off = base * ctx.rank + ctx.rank.min(extra);
                let part = whole.slice(off, my);
                let mut env = Env::new();
                env.insert(
                    "dim".to_string(),
                    if ctx.rank == 0 {
                        dim()
                    } else {
                        Table::empty(dim().schema().clone())
                    },
                );
                let (out, _) = build().run_dist(ctx, &part, &env)?;
                Ok(out)
            })
            .unwrap();
        let gathered = Table::concat_all(outs[0].schema(), &outs).unwrap();
        // Compare as sorted rows.
        let sort = |t: &Table| {
            let mut rows: Vec<_> =
                (0..t.num_rows()).map(|i| t.row(i)).collect();
            rows.sort_by(|a, b| {
                a[0].total_cmp(&b[0])
            });
            rows
        };
        assert_eq!(sort(&gathered), sort(&local));
    }

    #[test]
    fn fused_matches_materialized_local() {
        use crate::ops::join::JoinAlgo;
        let build = || {
            Pipeline::new()
                .select("v >= 10")
                .unwrap()
                .project(&["grp", "v"])
                .join(
                    "dim",
                    JoinOptions::inner("grp", "grp")
                        .with_algo(JoinAlgo::Hash),
                )
                .select("v < 90")
                .unwrap()
                .groupby(GroupByOptions::new(
                    &["name"],
                    vec![Agg::sum("v"), Agg::mean("v"), Agg::count("v")],
                ))
        };
        let mut env = Env::new();
        env.insert("dim".to_string(), dim());
        let (fused, fp) = crate::exec::with_pipeline_fuse(true, || {
            build().run_local(&input(), &env)
        })
        .unwrap();
        let (mat, mp) = crate::exec::with_pipeline_fuse(false, || {
            build().run_local(&input(), &env)
        })
        .unwrap();
        // Bit-identity: schema, row order, values, validity bitmaps.
        assert_eq!(fused, mat);
        // Per-stage accounting survives fusion: same phase names, same
        // cumulative rows_out.
        assert_eq!(fp.counter("rows_out"), mp.counter("rows_out"));
        for phase in ["select", "project", "join", "groupby"] {
            assert!(fp.seconds(phase) >= 0.0, "{phase} slot missing");
        }
    }

    #[test]
    fn fused_left_join_matches_materialized() {
        use crate::ops::join::{JoinAlgo, JoinType};
        // dim covers only grp 0..3 → unmatched probe rows null-extend
        // the right side (exercises the validity force rule).
        let dim_small = Table::from_columns(vec![
            ("grp", Column::from_i64((0..3).collect())),
            ("name", Column::from_str(&["a", "b", "c"])),
        ])
        .unwrap();
        let build = || {
            Pipeline::new()
                .join(
                    "dim",
                    JoinOptions::new(JoinType::Left, &["grp"], &["grp"])
                        .with_algo(JoinAlgo::Hash),
                )
                .select("v < 50")
                .unwrap()
        };
        let mut env = Env::new();
        env.insert("dim".to_string(), dim_small);
        let (fused, _) = crate::exec::with_pipeline_fuse(true, || {
            build().run_local(&input(), &env)
        })
        .unwrap();
        let (mat, _) = crate::exec::with_pipeline_fuse(false, || {
            build().run_local(&input(), &env)
        })
        .unwrap();
        assert_eq!(fused, mat);
        // The surviving filter range keeps some unmatched rows, so the
        // right-side columns must carry a validity bitmap either way.
        assert!(fused
            .column_by_name("name")
            .unwrap()
            .validity()
            .is_some());
    }

    #[test]
    fn fused_errors_match_materialized() {
        use crate::ops::join::JoinAlgo;
        // Post-join select over a column that exists in neither input:
        // the fused plan walk must surface the materialized path's
        // error, not a different one from a later stage.
        let build = || {
            Pipeline::new()
                .join(
                    "dim",
                    JoinOptions::inner("grp", "grp")
                        .with_algo(JoinAlgo::Hash),
                )
                .select("ghost >= 1")
                .unwrap()
                .groupby(GroupByOptions::new(&[], vec![]))
        };
        let mut env = Env::new();
        env.insert("dim".to_string(), dim());
        let fe = crate::exec::with_pipeline_fuse(true, || {
            build().run_local(&input(), &env)
        })
        .unwrap_err();
        let me = crate::exec::with_pipeline_fuse(false, || {
            build().run_local(&input(), &env)
        })
        .unwrap_err();
        assert_eq!(format!("{fe:?}"), format!("{me:?}"));
    }

    #[test]
    fn missing_env_table_errors() {
        let p = Pipeline::new()
            .join("ghost", JoinOptions::inner("grp", "grp"));
        assert!(p.run_local(&input(), &Env::new()).is_err());
    }

    #[test]
    fn scan_pushdown_collects_the_streamable_prefix() {
        let p = Pipeline::new()
            .select("v >= 10")
            .unwrap()
            .project(&["grp", "v"])
            .select("v < 90")
            .unwrap()
            .groupby(GroupByOptions::new(&["grp"], vec![Agg::sum("v")]));
        let opts = p.scan_pushdown();
        // Both prefix selects fold into one conjunction.
        match opts.predicate {
            Some(Predicate::And(_, _)) => {}
            other => panic!("expected And conjunction, got {other:?}"),
        }
        assert_eq!(
            opts.projection,
            Some(vec!["grp".to_string(), "v".to_string()])
        );
        // No project in the prefix → scan keeps every column.
        let p = Pipeline::new().select("v >= 10").unwrap();
        let opts = p.scan_pushdown();
        assert!(opts.predicate.is_some());
        assert!(opts.projection.is_none());
        // A non-streamable head stops the walk before anything pushes.
        let p = Pipeline::new().distinct().select("v >= 10").unwrap();
        let opts = p.scan_pushdown();
        assert!(opts.predicate.is_none());
        assert!(opts.projection.is_none());
        // Predicate columns join the live set even when not projected.
        let p = Pipeline::new()
            .select("id >= 50")
            .unwrap()
            .project(&["v"]);
        let opts = p.scan_pushdown();
        assert_eq!(
            opts.projection,
            Some(vec!["v".to_string(), "id".to_string()])
        );
    }

    #[test]
    fn ryf_pushdown_run_matches_in_memory_run() {
        let table = input();
        let path = std::env::temp_dir().join("rylon_pipe_ryf_push");
        crate::exec::with_ryf_encoding(true, || {
            crate::io::ryf::write_ryf(&table, &path, 10)
        })
        .unwrap();
        let p = Pipeline::new()
            .select("id >= 50")
            .unwrap()
            .project(&["id", "v"]);
        let env = Env::new();
        let (mem, _) = p.run_local(&table, &env).unwrap();
        let _ = crate::exec::take_scan_stats();
        let (scanned, phases) = p.run_ryf_local(&path, &env).unwrap();
        let c = crate::exec::take_scan_stats();
        assert_eq!(scanned, mem, "pushdown must not change the result");
        assert!(phases.seconds("scan") >= 0.0);
        assert_eq!(phases.counter("rows_scanned"), 50);
        assert_eq!(c.groups_total, 10);
        assert_eq!(c.groups_skipped, 5, "ids 0..49 live in dead groups");
        assert_eq!(c.pruned_columns, 5, "grp pruned in each survivor");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ryf_dist_scan_covers_all_groups() {
        let table = input();
        let path = std::env::temp_dir().join("rylon_pipe_ryf_dist");
        crate::exec::with_ryf_encoding(true, || {
            crate::io::ryf::write_ryf(&table, &path, 10)
        })
        .unwrap();
        let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let p = Pipeline::new()
                    .select("id >= 50")?
                    .project(&["id", "v"]);
                let (out, _) =
                    p.run_ryf_dist(ctx, &path, &Env::new())?;
                Ok(out)
            })
            .unwrap();
        let mut ids: Vec<i64> = outs
            .iter()
            .flat_map(|t| t.column(0).i64_values().to_vec())
            .collect();
        ids.sort();
        assert_eq!(ids, (50..100).collect::<Vec<_>>());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dist_rebalance_and_distinct() {
        let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
        let outs = cluster
            .run(|ctx| {
                // Skewed input: all on rank 0, with duplicates.
                let t = if ctx.rank == 0 {
                    Table::from_columns(vec![(
                        "x",
                        Column::from_i64(
                            (0..30).map(|i| i % 10).collect(),
                        ),
                    )])
                    .unwrap()
                } else {
                    Table::empty(
                        crate::types::Schema::parse("x:i64").unwrap(),
                    )
                };
                let p = Pipeline::new().rebalance().distinct();
                let (out, _) = p.run_dist(ctx, &t, &Env::new())?;
                Ok(out)
            })
            .unwrap();
        let total: usize = outs.iter().map(|t| t.num_rows()).sum();
        assert_eq!(total, 10);
    }
}
