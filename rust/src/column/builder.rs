//! Incremental column construction (CSV reader, gather paths, binding
//! layer). One builder per output column; `finish()` produces the packed
//! [`Column`].

use crate::column::{Column, PrimitiveColumn, StringColumn};
use crate::error::{Result, RylonError};
use crate::types::{DataType, Value};

/// Append-only builder for one column.
#[derive(Debug)]
pub enum ColumnBuilder {
    Int64(Vec<Option<i64>>),
    Float64(Vec<Option<f64>>),
    Utf8(Vec<Option<String>>),
    Bool(Vec<Option<bool>>),
}

impl ColumnBuilder {
    pub fn new(dtype: DataType, capacity: usize) -> ColumnBuilder {
        match dtype {
            DataType::Int64 => ColumnBuilder::Int64(Vec::with_capacity(capacity)),
            DataType::Float64 => {
                ColumnBuilder::Float64(Vec::with_capacity(capacity))
            }
            DataType::Utf8 => ColumnBuilder::Utf8(Vec::with_capacity(capacity)),
            DataType::Bool => ColumnBuilder::Bool(Vec::with_capacity(capacity)),
        }
    }

    pub fn dtype(&self) -> DataType {
        match self {
            ColumnBuilder::Int64(_) => DataType::Int64,
            ColumnBuilder::Float64(_) => DataType::Float64,
            ColumnBuilder::Utf8(_) => DataType::Utf8,
            ColumnBuilder::Bool(_) => DataType::Bool,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ColumnBuilder::Int64(v) => v.len(),
            ColumnBuilder::Float64(v) => v.len(),
            ColumnBuilder::Utf8(v) => v.len(),
            ColumnBuilder::Bool(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn push_null(&mut self) {
        match self {
            ColumnBuilder::Int64(v) => v.push(None),
            ColumnBuilder::Float64(v) => v.push(None),
            ColumnBuilder::Utf8(v) => v.push(None),
            ColumnBuilder::Bool(v) => v.push(None),
        }
    }

    /// Append a boxed value; `Null` is accepted by every builder, other
    /// variants must match the builder dtype.
    pub fn push_value(&mut self, v: &Value) -> Result<()> {
        match (self, v) {
            (b, Value::Null) => {
                b.push_null();
                Ok(())
            }
            (ColumnBuilder::Int64(vec), Value::Int64(x)) => {
                vec.push(Some(*x));
                Ok(())
            }
            (ColumnBuilder::Float64(vec), Value::Float64(x)) => {
                vec.push(Some(*x));
                Ok(())
            }
            (ColumnBuilder::Float64(vec), Value::Int64(x)) => {
                vec.push(Some(*x as f64));
                Ok(())
            }
            (ColumnBuilder::Utf8(vec), Value::Utf8(s)) => {
                vec.push(Some(s.clone()));
                Ok(())
            }
            (ColumnBuilder::Bool(vec), Value::Bool(x)) => {
                vec.push(Some(*x));
                Ok(())
            }
            (b, v) => Err(RylonError::ty(format!(
                "cannot append {:?} to {} builder",
                v,
                b.dtype()
            ))),
        }
    }

    /// Parse-and-append a CSV cell. Empty string is null.
    pub fn push_parse(&mut self, cell: &str) -> Result<()> {
        if cell.is_empty() {
            self.push_null();
            return Ok(());
        }
        match self {
            ColumnBuilder::Int64(v) => {
                let x = cell.trim().parse::<i64>().map_err(|_| {
                    RylonError::parse(format!("bad i64 literal '{cell}'"))
                })?;
                v.push(Some(x));
            }
            ColumnBuilder::Float64(v) => {
                let x = cell.trim().parse::<f64>().map_err(|_| {
                    RylonError::parse(format!("bad f64 literal '{cell}'"))
                })?;
                v.push(Some(x));
            }
            ColumnBuilder::Utf8(v) => v.push(Some(cell.to_string())),
            ColumnBuilder::Bool(v) => {
                let x = match cell.trim() {
                    "true" | "True" | "TRUE" | "1" => true,
                    "false" | "False" | "FALSE" | "0" => false,
                    _ => {
                        return Err(RylonError::parse(format!(
                            "bad bool literal '{cell}'"
                        )))
                    }
                };
                v.push(Some(x));
            }
        }
        Ok(())
    }

    pub fn finish(self) -> Column {
        match self {
            ColumnBuilder::Int64(v) => {
                Column::Int64(PrimitiveColumn::from_options(v))
            }
            ColumnBuilder::Float64(v) => {
                Column::Float64(PrimitiveColumn::from_options(v))
            }
            ColumnBuilder::Utf8(v) => Column::Utf8(StringColumn::from_options(&v)),
            ColumnBuilder::Bool(v) => {
                Column::Bool(PrimitiveColumn::from_options(v))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_append_and_finish() {
        let mut b = ColumnBuilder::new(DataType::Int64, 4);
        b.push_value(&Value::Int64(1)).unwrap();
        b.push_null();
        b.push_value(&Value::Null).unwrap();
        let c = b.finish();
        assert_eq!(c.len(), 3);
        assert_eq!(c.null_count(), 2);
        assert_eq!(c.value(0), Value::Int64(1));
    }

    #[test]
    fn type_mismatch_rejected() {
        let mut b = ColumnBuilder::new(DataType::Bool, 1);
        assert!(b.push_value(&Value::Int64(1)).is_err());
    }

    #[test]
    fn int_widens_to_float() {
        let mut b = ColumnBuilder::new(DataType::Float64, 1);
        b.push_value(&Value::Int64(3)).unwrap();
        assert_eq!(b.finish().value(0), Value::Float64(3.0));
    }

    #[test]
    fn parse_cells() {
        let mut b = ColumnBuilder::new(DataType::Float64, 3);
        b.push_parse("1.5").unwrap();
        b.push_parse("").unwrap();
        assert!(b.push_parse("abc").is_err());
        let c = b.finish();
        assert_eq!(c.value(0), Value::Float64(1.5));
        assert_eq!(c.value(1), Value::Null);
    }

    #[test]
    fn parse_bools() {
        let mut b = ColumnBuilder::new(DataType::Bool, 4);
        for s in ["true", "FALSE", "1", "0"] {
            b.push_parse(s).unwrap();
        }
        let c = b.finish();
        assert_eq!(c.bool_values(), &[true, false, true, false]);
    }
}
