//! [`Column`] — one typed, nullable column. The enum dispatches to the
//! typed storages ([`PrimitiveColumn`], [`StringColumn`]); operator hot
//! loops match once on the variant and then run monomorphic code over raw
//! slices, so dynamic dispatch never appears inside a row loop.

pub mod primitive;
pub mod string;
mod builder;

use std::cmp::Ordering;

pub use builder::ColumnBuilder;
pub use primitive::PrimitiveColumn;
pub use string::StringColumn;

use crate::buffer::Bitmap;
use crate::error::{Result, RylonError};
use crate::types::{DataType, Value};

/// A typed column of row values.
#[derive(Debug, Clone, PartialEq)]
pub enum Column {
    Int64(PrimitiveColumn<i64>),
    Float64(PrimitiveColumn<f64>),
    Utf8(StringColumn),
    Bool(PrimitiveColumn<bool>),
}

impl Column {
    // ---- constructors ----------------------------------------------------

    pub fn from_i64(values: Vec<i64>) -> Column {
        Column::Int64(PrimitiveColumn::from_values(values))
    }

    pub fn from_f64(values: Vec<f64>) -> Column {
        Column::Float64(PrimitiveColumn::from_values(values))
    }

    pub fn from_str<S: AsRef<str>>(values: &[S]) -> Column {
        Column::Utf8(StringColumn::from_values(values))
    }

    pub fn from_bool(values: Vec<bool>) -> Column {
        Column::Bool(PrimitiveColumn::from_values(values))
    }

    pub fn from_opt_i64(values: Vec<Option<i64>>) -> Column {
        Column::Int64(PrimitiveColumn::from_options(values))
    }

    pub fn from_opt_f64(values: Vec<Option<f64>>) -> Column {
        Column::Float64(PrimitiveColumn::from_options(values))
    }

    pub fn from_opt_str<S: AsRef<str>>(values: &[Option<S>]) -> Column {
        Column::Utf8(StringColumn::from_options(values))
    }

    pub fn from_opt_bool(values: Vec<Option<bool>>) -> Column {
        Column::Bool(PrimitiveColumn::from_options(values))
    }

    /// Build a column of `dtype` from boxed values (binding layer / CSV).
    pub fn from_values(dtype: DataType, values: &[Value]) -> Result<Column> {
        let mut b = ColumnBuilder::new(dtype, values.len());
        for v in values {
            b.push_value(v)?;
        }
        Ok(b.finish())
    }

    // ---- introspection ---------------------------------------------------

    pub fn dtype(&self) -> DataType {
        match self {
            Column::Int64(_) => DataType::Int64,
            Column::Float64(_) => DataType::Float64,
            Column::Utf8(_) => DataType::Utf8,
            Column::Bool(_) => DataType::Bool,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Column::Int64(c) => c.len(),
            Column::Float64(c) => c.len(),
            Column::Utf8(c) => c.len(),
            Column::Bool(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn null_count(&self) -> usize {
        match self {
            Column::Int64(c) => c.null_count(),
            Column::Float64(c) => c.null_count(),
            Column::Utf8(c) => c.null_count(),
            Column::Bool(c) => c.null_count(),
        }
    }

    #[inline]
    pub fn is_valid(&self, i: usize) -> bool {
        match self {
            Column::Int64(c) => c.is_valid(i),
            Column::Float64(c) => c.is_valid(i),
            Column::Utf8(c) => c.is_valid(i),
            Column::Bool(c) => c.is_valid(i),
        }
    }

    pub fn validity(&self) -> Option<&Bitmap> {
        match self {
            Column::Int64(c) => c.validity(),
            Column::Float64(c) => c.validity(),
            Column::Utf8(c) => c.validity(),
            Column::Bool(c) => c.validity(),
        }
    }

    /// Boxed cell at row i (off the hot path).
    pub fn value(&self, i: usize) -> Value {
        if !self.is_valid(i) {
            return Value::Null;
        }
        match self {
            Column::Int64(c) => Value::Int64(c.value(i)),
            Column::Float64(c) => Value::Float64(c.value(i)),
            Column::Utf8(c) => Value::Utf8(c.value(i).to_string()),
            Column::Bool(c) => Value::Bool(c.value(i)),
        }
    }

    /// Typed accessors (panic on type mismatch — operator code checks
    /// dtypes up front).
    pub fn i64_values(&self) -> &[i64] {
        match self {
            Column::Int64(c) => c.values(),
            _ => panic!("i64_values on {:?} column", self.dtype()),
        }
    }

    pub fn f64_values(&self) -> &[f64] {
        match self {
            Column::Float64(c) => c.values(),
            _ => panic!("f64_values on {:?} column", self.dtype()),
        }
    }

    pub fn as_utf8(&self) -> &StringColumn {
        match self {
            Column::Utf8(c) => c,
            _ => panic!("as_utf8 on {:?} column", self.dtype()),
        }
    }

    pub fn bool_values(&self) -> &[bool] {
        match self {
            Column::Bool(c) => c.values(),
            _ => panic!("bool_values on {:?} column", self.dtype()),
        }
    }

    /// In-memory footprint of the value buffers (metrics / cost model).
    pub fn byte_size(&self) -> usize {
        let validity = self
            .validity()
            .map_or(0, |b| b.words().len() * 8);
        validity
            + match self {
                Column::Int64(c) => c.len() * 8,
                Column::Float64(c) => c.len() * 8,
                Column::Bool(c) => c.len(),
                Column::Utf8(c) => c.bytes().len() + (c.len() + 1) * 8,
            }
    }

    // ---- row kernels (used by ops) ----------------------------------------

    /// Row equality between two columns of the same dtype. Nulls compare
    /// equal to nulls (SQL `IS NOT DISTINCT FROM` — required for the set
    /// operators' duplicate semantics, paper Table I).
    #[inline]
    pub fn eq_rows(&self, i: usize, other: &Column, j: usize) -> bool {
        match (self, other) {
            (Column::Int64(a), Column::Int64(b)) => {
                match (a.is_valid(i), b.is_valid(j)) {
                    (true, true) => a.value(i) == b.value(j),
                    (false, false) => true,
                    _ => false,
                }
            }
            (Column::Float64(a), Column::Float64(b)) => {
                match (a.is_valid(i), b.is_valid(j)) {
                    (true, true) => {
                        a.value(i).to_bits() == b.value(j).to_bits()
                    }
                    (false, false) => true,
                    _ => false,
                }
            }
            (Column::Utf8(a), Column::Utf8(b)) => {
                match (a.is_valid(i), b.is_valid(j)) {
                    (true, true) => a.value(i) == b.value(j),
                    (false, false) => true,
                    _ => false,
                }
            }
            (Column::Bool(a), Column::Bool(b)) => {
                match (a.is_valid(i), b.is_valid(j)) {
                    (true, true) => a.value(i) == b.value(j),
                    (false, false) => true,
                    _ => false,
                }
            }
            _ => false,
        }
    }

    /// Total order between rows (nulls first, NaN greatest).
    #[inline]
    pub fn cmp_rows(&self, i: usize, other: &Column, j: usize) -> Ordering {
        match (self.is_valid(i), other.is_valid(j)) {
            (false, false) => return Ordering::Equal,
            (false, true) => return Ordering::Less,
            (true, false) => return Ordering::Greater,
            (true, true) => {}
        }
        match (self, other) {
            (Column::Int64(a), Column::Int64(b)) => a.value(i).cmp(&b.value(j)),
            (Column::Float64(a), Column::Float64(b)) => {
                a.value(i).total_cmp(&b.value(j))
            }
            (Column::Utf8(a), Column::Utf8(b)) => a.value(i).cmp(b.value(j)),
            (Column::Bool(a), Column::Bool(b)) => a.value(i).cmp(&b.value(j)),
            _ => panic!("cmp_rows across dtypes"),
        }
    }

    // ---- structural ops ---------------------------------------------------

    pub fn take(&self, indices: &[usize]) -> Column {
        match self {
            Column::Int64(c) => Column::Int64(c.take(indices)),
            Column::Float64(c) => Column::Float64(c.take(indices)),
            Column::Utf8(c) => Column::Utf8(c.take(indices)),
            Column::Bool(c) => Column::Bool(c.take(indices)),
        }
    }

    pub fn slice(&self, offset: usize, len: usize) -> Column {
        match self {
            Column::Int64(c) => Column::Int64(c.slice(offset, len)),
            Column::Float64(c) => Column::Float64(c.slice(offset, len)),
            Column::Utf8(c) => Column::Utf8(c.slice(offset, len)),
            Column::Bool(c) => Column::Bool(c.slice(offset, len)),
        }
    }

    pub fn concat(&self, other: &Column) -> Result<Column> {
        match (self, other) {
            (Column::Int64(a), Column::Int64(b)) => {
                Ok(Column::Int64(a.concat(b)))
            }
            (Column::Float64(a), Column::Float64(b)) => {
                Ok(Column::Float64(a.concat(b)))
            }
            (Column::Utf8(a), Column::Utf8(b)) => Ok(Column::Utf8(a.concat(b))),
            (Column::Bool(a), Column::Bool(b)) => Ok(Column::Bool(a.concat(b))),
            _ => Err(RylonError::ty(format!(
                "concat {} with {}",
                self.dtype(),
                other.dtype()
            ))),
        }
    }

    /// Cast numeric columns to f64 (the tensor-bridge path).
    pub fn cast_f64(&self) -> Result<Vec<f64>> {
        match self {
            Column::Int64(c) => Ok(c
                .values()
                .iter()
                .enumerate()
                .map(|(i, &v)| if c.is_valid(i) { v as f64 } else { f64::NAN })
                .collect()),
            Column::Float64(c) => Ok(c
                .values()
                .iter()
                .enumerate()
                .map(|(i, &v)| if c.is_valid(i) { v } else { f64::NAN })
                .collect()),
            Column::Bool(c) => Ok(c
                .values()
                .iter()
                .enumerate()
                .map(|(i, &v)| {
                    if c.is_valid(i) {
                        v as u8 as f64
                    } else {
                        f64::NAN
                    }
                })
                .collect()),
            Column::Utf8(_) => {
                Err(RylonError::ty("cannot cast utf8 column to f64"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_boxing() {
        let c = Column::from_opt_i64(vec![Some(1), None]);
        assert_eq!(c.value(0), Value::Int64(1));
        assert_eq!(c.value(1), Value::Null);
        assert_eq!(c.null_count(), 1);
    }

    #[test]
    fn eq_rows_null_semantics() {
        let a = Column::from_opt_i64(vec![Some(1), None]);
        let b = Column::from_opt_i64(vec![Some(1), None]);
        assert!(a.eq_rows(0, &b, 0));
        assert!(a.eq_rows(1, &b, 1)); // null == null for set ops
        assert!(!a.eq_rows(0, &b, 1));
    }

    #[test]
    fn cmp_rows_null_first() {
        let a = Column::from_opt_f64(vec![None, Some(2.0)]);
        assert_eq!(a.cmp_rows(0, &a, 1), Ordering::Less);
        assert_eq!(a.cmp_rows(1, &a, 0), Ordering::Greater);
        assert_eq!(a.cmp_rows(0, &a, 0), Ordering::Equal);
    }

    #[test]
    fn concat_type_checked() {
        let a = Column::from_i64(vec![1]);
        let b = Column::from_f64(vec![2.0]);
        assert!(a.concat(&b).is_err());
        assert_eq!(a.concat(&a).unwrap().len(), 2);
    }

    #[test]
    fn cast_f64_paths() {
        assert_eq!(
            Column::from_i64(vec![1, 2]).cast_f64().unwrap(),
            vec![1.0, 2.0]
        );
        assert_eq!(
            Column::from_bool(vec![true, false]).cast_f64().unwrap(),
            vec![1.0, 0.0]
        );
        assert!(Column::from_str(&["x"]).cast_f64().is_err());
        let with_null = Column::from_opt_f64(vec![Some(1.0), None]);
        let v = with_null.cast_f64().unwrap();
        assert!(v[1].is_nan());
    }

    #[test]
    fn byte_size_counts_buffers() {
        let c = Column::from_i64(vec![0; 100]);
        assert_eq!(c.byte_size(), 800);
        let s = Column::from_str(&["ab", "c"]);
        assert_eq!(s.byte_size(), 3 + 3 * 8);
    }
}
