//! Variable-width UTF-8 column: Arrow-style offsets + contiguous byte
//! buffer, so string data stays cache-friendly and serialises to the wire
//! with two memcpys.

use crate::buffer::Bitmap;

/// UTF-8 column storage. `offsets.len() == len + 1`; value i occupies
/// `bytes[offsets[i]..offsets[i+1]]`. Null rows have empty extents.
#[derive(Debug, Clone, PartialEq)]
pub struct StringColumn {
    pub(crate) offsets: Vec<u64>,
    pub(crate) bytes: Vec<u8>,
    pub(crate) validity: Option<Bitmap>,
}

impl StringColumn {
    pub fn from_values<S: AsRef<str>>(values: &[S]) -> Self {
        let mut offsets = Vec::with_capacity(values.len() + 1);
        let mut bytes = Vec::new();
        offsets.push(0);
        for v in values {
            bytes.extend_from_slice(v.as_ref().as_bytes());
            offsets.push(bytes.len() as u64);
        }
        StringColumn {
            offsets,
            bytes,
            validity: None,
        }
    }

    pub fn from_options<S: AsRef<str>>(values: &[Option<S>]) -> Self {
        let mut offsets = Vec::with_capacity(values.len() + 1);
        let mut bytes = Vec::new();
        let mut validity = Bitmap::zeros(values.len());
        let mut any_null = false;
        offsets.push(0);
        for (i, v) in values.iter().enumerate() {
            match v {
                Some(s) => {
                    validity.set(i, true);
                    bytes.extend_from_slice(s.as_ref().as_bytes());
                }
                None => any_null = true,
            }
            offsets.push(bytes.len() as u64);
        }
        StringColumn {
            offsets,
            bytes,
            validity: if any_null { Some(validity) } else { None },
        }
    }

    /// Construct from raw Arrow-layout parts (wire deserialisation).
    pub fn from_parts(
        offsets: Vec<u64>,
        bytes: Vec<u8>,
        validity: Option<Bitmap>,
    ) -> Self {
        assert!(!offsets.is_empty());
        assert_eq!(*offsets.last().unwrap() as usize, bytes.len());
        StringColumn {
            offsets,
            bytes,
            validity,
        }
    }

    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn is_valid(&self, i: usize) -> bool {
        self.validity.as_ref().map_or(true, |b| b.get(i))
    }

    #[inline]
    pub fn value(&self, i: usize) -> &str {
        let lo = self.offsets[i] as usize;
        let hi = self.offsets[i + 1] as usize;
        // Bytes arrived from &str or validated wire data.
        unsafe { std::str::from_utf8_unchecked(&self.bytes[lo..hi]) }
    }

    pub fn get(&self, i: usize) -> Option<&str> {
        if self.is_valid(i) {
            Some(self.value(i))
        } else {
            None
        }
    }

    pub fn null_count(&self) -> usize {
        self.validity.as_ref().map_or(0, |b| b.count_zeros())
    }

    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn validity(&self) -> Option<&Bitmap> {
        self.validity.as_ref()
    }

    pub fn take(&self, indices: &[usize]) -> Self {
        let mut offsets = Vec::with_capacity(indices.len() + 1);
        let mut bytes = Vec::new();
        offsets.push(0u64);
        for &i in indices {
            let lo = self.offsets[i] as usize;
            let hi = self.offsets[i + 1] as usize;
            bytes.extend_from_slice(&self.bytes[lo..hi]);
            offsets.push(bytes.len() as u64);
        }
        let validity = self.validity.as_ref().map(|b| b.take(indices));
        StringColumn {
            offsets,
            bytes,
            validity,
        }
    }

    pub fn slice(&self, offset: usize, len: usize) -> Self {
        let lo = self.offsets[offset] as usize;
        let hi = self.offsets[offset + len] as usize;
        let offsets = self.offsets[offset..=offset + len]
            .iter()
            .map(|&o| o - lo as u64)
            .collect();
        StringColumn {
            offsets,
            bytes: self.bytes[lo..hi].to_vec(),
            validity: self.validity.as_ref().map(|b| b.slice(offset, len)),
        }
    }

    pub fn concat(&self, other: &Self) -> Self {
        let mut offsets = self.offsets.clone();
        let base = self.bytes.len() as u64;
        offsets.extend(other.offsets.iter().skip(1).map(|&o| o + base));
        let mut bytes = self.bytes.clone();
        bytes.extend_from_slice(&other.bytes);
        let validity = match (&self.validity, &other.validity) {
            (None, None) => None,
            (a, b) => {
                let left =
                    a.clone().unwrap_or_else(|| Bitmap::ones(self.len()));
                let right =
                    b.clone().unwrap_or_else(|| Bitmap::ones(other.len()));
                Some(left.concat(&right))
            }
        };
        StringColumn {
            offsets,
            bytes,
            validity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_values() {
        let c = StringColumn::from_values(&["ab", "", "cde"]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.value(0), "ab");
        assert_eq!(c.value(1), "");
        assert_eq!(c.value(2), "cde");
        assert_eq!(c.null_count(), 0);
    }

    #[test]
    fn options_and_nulls() {
        let c = StringColumn::from_options(&[Some("x"), None, Some("yz")]);
        assert_eq!(c.get(0), Some("x"));
        assert_eq!(c.get(1), None);
        assert_eq!(c.get(2), Some("yz"));
        assert_eq!(c.null_count(), 1);
    }

    #[test]
    fn take_slice_concat() {
        let c = StringColumn::from_values(&["a", "bb", "ccc", "dddd"]);
        let t = c.take(&[3, 0]);
        assert_eq!(t.value(0), "dddd");
        assert_eq!(t.value(1), "a");
        let s = c.slice(1, 2);
        assert_eq!(s.value(0), "bb");
        assert_eq!(s.value(1), "ccc");
        let j = t.concat(&s);
        assert_eq!(j.len(), 4);
        assert_eq!(j.value(2), "bb");
    }

    #[test]
    fn unicode_safe() {
        let c = StringColumn::from_values(&["héllo", "日本語"]);
        assert_eq!(c.value(0), "héllo");
        assert_eq!(c.value(1), "日本語");
        let s = c.slice(1, 1);
        assert_eq!(s.value(0), "日本語");
    }

    #[test]
    fn parts_roundtrip() {
        let c = StringColumn::from_options(&[Some("ab"), None]);
        let c2 = StringColumn::from_parts(
            c.offsets().to_vec(),
            c.bytes().to_vec(),
            c.validity().cloned(),
        );
        assert_eq!(c, c2);
    }
}
