//! Fixed-width column storage: a contiguous `Vec<T>` plus an optional
//! validity bitmap (absent ⇔ all rows valid) — the Arrow layout the paper
//! adopts (§III-A).

use crate::buffer::Bitmap;

/// Storage for `i64` / `f64` / `bool` columns.
#[derive(Debug, Clone, PartialEq)]
pub struct PrimitiveColumn<T> {
    pub(crate) values: Vec<T>,
    pub(crate) validity: Option<Bitmap>,
}

impl<T: Copy + Default> PrimitiveColumn<T> {
    /// Non-null column from raw values.
    pub fn from_values(values: Vec<T>) -> Self {
        PrimitiveColumn {
            values,
            validity: None,
        }
    }

    /// Column from optional values.
    pub fn from_options(values: Vec<Option<T>>) -> Self {
        let mut validity = Bitmap::zeros(values.len());
        let mut out = Vec::with_capacity(values.len());
        let mut any_null = false;
        for (i, v) in values.into_iter().enumerate() {
            match v {
                Some(v) => {
                    validity.set(i, true);
                    out.push(v);
                }
                None => {
                    any_null = true;
                    out.push(T::default());
                }
            }
        }
        PrimitiveColumn {
            values: out,
            validity: if any_null { Some(validity) } else { None },
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    #[inline]
    pub fn is_valid(&self, i: usize) -> bool {
        self.validity.as_ref().map_or(true, |b| b.get(i))
    }

    #[inline]
    pub fn value(&self, i: usize) -> T {
        self.values[i]
    }

    pub fn get(&self, i: usize) -> Option<T> {
        if self.is_valid(i) {
            Some(self.values[i])
        } else {
            None
        }
    }

    pub fn values(&self) -> &[T] {
        &self.values
    }

    pub fn validity(&self) -> Option<&Bitmap> {
        self.validity.as_ref()
    }

    pub fn null_count(&self) -> usize {
        self.validity.as_ref().map_or(0, |b| b.count_zeros())
    }

    /// Gather rows by index (out-of-range panics in debug).
    pub fn take(&self, indices: &[usize]) -> Self {
        let values = indices.iter().map(|&i| self.values[i]).collect();
        let validity = self.validity.as_ref().map(|b| b.take(indices));
        PrimitiveColumn { values, validity }
    }

    pub fn slice(&self, offset: usize, len: usize) -> Self {
        PrimitiveColumn {
            values: self.values[offset..offset + len].to_vec(),
            validity: self.validity.as_ref().map(|b| b.slice(offset, len)),
        }
    }

    pub fn concat(&self, other: &Self) -> Self {
        let mut values = self.values.clone();
        values.extend_from_slice(&other.values);
        let validity = match (&self.validity, &other.validity) {
            (None, None) => None,
            (a, b) => {
                let left = a.clone().unwrap_or_else(|| Bitmap::ones(self.len()));
                let right =
                    b.clone().unwrap_or_else(|| Bitmap::ones(other.len()));
                Some(left.concat(&right))
            }
        };
        PrimitiveColumn { values, validity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_options_tracks_nulls() {
        let c = PrimitiveColumn::from_options(vec![Some(1i64), None, Some(3)]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.null_count(), 1);
        assert_eq!(c.get(0), Some(1));
        assert_eq!(c.get(1), None);
        assert_eq!(c.get(2), Some(3));
    }

    #[test]
    fn all_valid_drops_bitmap() {
        let c = PrimitiveColumn::from_options(vec![Some(1i64), Some(2)]);
        assert!(c.validity().is_none());
    }

    #[test]
    fn take_reorders_values_and_nulls() {
        let c = PrimitiveColumn::from_options(vec![Some(10i64), None, Some(30)]);
        let t = c.take(&[2, 1, 0, 2]);
        assert_eq!(t.get(0), Some(30));
        assert_eq!(t.get(1), None);
        assert_eq!(t.get(2), Some(10));
        assert_eq!(t.get(3), Some(30));
    }

    #[test]
    fn slice_concat() {
        let a = PrimitiveColumn::from_values(vec![1i64, 2, 3, 4]);
        let s = a.slice(1, 2);
        assert_eq!(s.values(), &[2, 3]);
        let b = PrimitiveColumn::from_options(vec![None, Some(9)]);
        let c = s.concat(&b);
        assert_eq!(c.len(), 4);
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(3), Some(9));
    }
}
