//! Partitioning + the chunked AllToAll shuffle — the data-movement core
//! every distributed operator composes with a local kernel.

use crate::compute::filter::scatter_indices;
use crate::compute::hash::hash_table_keys;
use crate::dist::RankCtx;
use crate::error::{Result, RylonError};
use crate::net::collectives::{allgather, allreduce_u64};
use crate::net::wire::{deserialize_table, serialize_table_into};
use crate::net::{OutBufs, ReduceOp};
use crate::table::Table;

/// Maps each row of a table to a destination partition.
pub trait Partitioner: Send + Sync {
    /// Number of partitions rows are routed into.
    fn nparts(&self) -> usize;

    /// Fill `out` with one partition id per row (`-1` = drop the row —
    /// the convention of masked lanes from the AOT kernel path).
    fn partition(&self, table: &Table, out: &mut Vec<i32>) -> Result<()>;
}

/// Key-hash partitioner: `pid = splitmix64-combined(key) % nparts` —
/// bit-identical routing to the L1 `hash_partition` kernel
/// (`runtime::HashKernel`), cross-checked in `rust/tests/pjrt_artifacts.rs`.
pub struct HashPartitioner {
    keys: Vec<String>,
    nparts: usize,
}

impl HashPartitioner {
    /// Partitioner routing by the combined hash of `keys` into
    /// `nparts` buckets.
    pub fn new(keys: &[String], nparts: usize) -> Result<HashPartitioner> {
        if keys.is_empty() {
            return Err(RylonError::invalid(
                "hash partitioner needs at least one key column",
            ));
        }
        if nparts == 0 {
            return Err(RylonError::invalid("nparts must be ≥ 1"));
        }
        Ok(HashPartitioner {
            keys: keys.to_vec(),
            nparts,
        })
    }
}

impl Partitioner for HashPartitioner {
    fn nparts(&self) -> usize {
        self.nparts
    }

    fn partition(&self, table: &Table, out: &mut Vec<i32>) -> Result<()> {
        let mut hashes = Vec::new();
        hash_table_keys(table, &self.keys, &mut hashes)?;
        out.clear();
        out.reserve(hashes.len());
        let n = self.nparts as u64;
        out.extend(hashes.iter().map(|&h| (h % n) as i32));
        Ok(())
    }
}

/// Key-based shuffle: route every row to `hash(keys) % world`, so equal
/// keys land on one rank. Chunked to bound in-flight bytes
/// ([`RankCtx::shuffle_chunk_rows`]); ranks agree on the round count
/// through an allreduce, so the exchange sequence stays in lockstep
/// even with skewed partition sizes.
pub fn shuffle(ctx: &mut RankCtx, table: &Table, keys: &[String]) -> Result<Table> {
    let p = HashPartitioner::new(keys, ctx.size)?;
    shuffle_with(ctx, table, &p)
}

/// Shuffle by the hash of *all* columns — the routing used by the
/// distributed set operators and `distinct`, where whole-row equality
/// decides placement.
pub fn shuffle_all_columns(ctx: &mut RankCtx, table: &Table) -> Result<Table> {
    let keys: Vec<String> = table
        .schema()
        .fields()
        .iter()
        .map(|f| f.name.clone())
        .collect();
    shuffle(ctx, table, &keys)
}

/// Shuffle with an explicit partitioner (must have `nparts == world`).
pub fn shuffle_with(
    ctx: &mut RankCtx,
    table: &Table,
    partitioner: &dyn Partitioner,
) -> Result<Table> {
    if partitioner.nparts() != ctx.size {
        return Err(RylonError::invalid(format!(
            "partitioner has {} parts for world {}",
            partitioner.nparts(),
            ctx.size
        )));
    }
    ctx.set_op("shuffle");
    let chunk = ctx.shuffle_chunk_rows.max(1);
    let my_rounds = table.num_rows().div_ceil(chunk) as u64;
    let rounds = allreduce_u64(
        ctx.fabric(),
        ctx.rank,
        &[my_rounds],
        ReduceOp::Max,
    )?[0] as usize;

    let mut received: Vec<Table> = Vec::new();
    let mut pids: Vec<i32> = Vec::new();
    for round in 0..rounds {
        let offset = round * chunk;
        let mut out: OutBufs = vec![Vec::new(); ctx.size];
        if offset < table.num_rows() {
            let slice = table.slice(offset, chunk);
            partitioner.partition(&slice, &mut pids)?;
            let parts = scatter_indices(&pids, ctx.size);
            for (dst, idx) in parts.iter().enumerate() {
                if !idx.is_empty() {
                    serialize_table_into(&slice.take(idx), &mut out[dst]);
                }
            }
        }
        let incoming = ctx.fabric().exchange(ctx.rank, out)?;
        for (src, buf) in incoming.iter().enumerate() {
            if !buf.is_empty() {
                received.push(deserialize_from_rank(buf, src)?);
            }
        }
    }
    Table::concat_all(table.schema(), &received)
}

/// Decode one peer's shuffle frame, attributing a malformed frame to
/// the rank that sent it (the wire hardening of `net::wire` rejects
/// corrupt counts/offsets; this names the culprit).
fn deserialize_from_rank(buf: &[u8], src: usize) -> Result<Table> {
    deserialize_table(buf).map_err(|e| {
        RylonError::comm(format!("malformed frame from rank {src}: {e}"))
    })
}

/// Even out partition sizes across ranks while preserving the global
/// rank-major row order (sizes end within ±1 of each other).
pub fn rebalance(ctx: &mut RankCtx, table: &Table) -> Result<Table> {
    if ctx.size == 1 {
        return Ok(table.clone());
    }
    ctx.set_op("rebalance");
    let counts_bufs = allgather(
        ctx.fabric(),
        ctx.rank,
        (table.num_rows() as u64).to_le_bytes().to_vec(),
    )?;
    let counts: Vec<usize> = counts_bufs
        .iter()
        .map(|b| {
            let arr: [u8; 8] = b
                .get(..8)
                .and_then(|s| s.try_into().ok())
                .ok_or_else(|| RylonError::comm("bad rebalance count"))?;
            Ok(u64::from_le_bytes(arr) as usize)
        })
        .collect::<Result<_>>()?;
    let total: usize = counts.iter().sum();
    let my_start: usize = counts[..ctx.rank].iter().sum();
    let base = total / ctx.size;
    let extra = total % ctx.size;
    // Global start of dest rank d's target range.
    let target_start = |d: usize| d * base + d.min(extra);

    let mut out: OutBufs = vec![Vec::new(); ctx.size];
    for dst in 0..ctx.size {
        let lo = target_start(dst).max(my_start);
        let hi = target_start(dst + 1).min(my_start + table.num_rows());
        if hi > lo {
            serialize_table_into(
                &table.slice(lo - my_start, hi - lo),
                &mut out[dst],
            );
        }
    }
    let incoming = ctx.fabric().exchange(ctx.rank, out)?;
    // Sources arrive in rank order and each sent a contiguous ascending
    // slice, so concatenation preserves the global order.
    let mut parts = Vec::new();
    for (src, buf) in incoming.iter().enumerate() {
        if !buf.is_empty() {
            parts.push(deserialize_from_rank(buf, src)?);
        }
    }
    Table::concat_all(table.schema(), &parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::compute::hash::splitmix64;
    use crate::dist::{Cluster, DistConfig};

    #[test]
    fn hash_partitioner_matches_kernel_formula() {
        let keys: Vec<i64> = (0..1000).map(|i| i * 37 - 250).collect();
        let t = Table::from_columns(vec![(
            "id",
            Column::from_i64(keys.clone()),
        )])
        .unwrap();
        let p = HashPartitioner::new(&["id".to_string()], 16).unwrap();
        let mut pids = Vec::new();
        p.partition(&t, &mut pids).unwrap();
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(pids[i], (splitmix64(k as u64) % 16) as i32);
        }
    }

    #[test]
    fn partitioner_validation() {
        assert!(HashPartitioner::new(&[], 4).is_err());
        assert!(HashPartitioner::new(&["k".to_string()], 0).is_err());
    }

    #[test]
    fn chunked_shuffle_handles_skew_without_deadlock() {
        // Rank 0 holds everything; tiny chunks force many rounds, and
        // the allreduce keeps empty ranks in lockstep.
        let mut cfg = DistConfig::threads(3);
        cfg.shuffle_chunk_rows = 8;
        let cluster = Cluster::new(cfg).unwrap();
        let outs = cluster
            .run(|ctx| {
                let t = if ctx.rank == 0 {
                    Table::from_columns(vec![(
                        "k",
                        Column::from_i64((0..100).collect()),
                    )])
                    .unwrap()
                } else {
                    Table::empty(
                        crate::types::Schema::parse("k:i64").unwrap(),
                    )
                };
                shuffle(ctx, &t, &["k".to_string()])
            })
            .unwrap();
        let total: usize = outs.iter().map(|t| t.num_rows()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn rebalance_single_rank_is_identity() {
        let cluster = Cluster::new(DistConfig::threads(1)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let t = Table::from_columns(vec![(
                    "v",
                    Column::from_i64(vec![1, 2, 3]),
                )])
                .unwrap();
                rebalance(ctx, &t)
            })
            .unwrap();
        assert_eq!(outs[0].num_rows(), 3);
    }
}
