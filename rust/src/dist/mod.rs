//! Distributed execution: a [`Cluster`] of SPMD rank threads over a
//! pluggable [`crate::net::Fabric`], plus the `dist_*` operators that
//! compose the local kernels with a key-based shuffle — exactly the
//! paper's recipe (§III-C: "a key-based partition followed by a
//! key-based shuffle ... to collect similar records into a single
//! process").
//!
//! Execution is **two-level** (the hybrid model of Perera et al. 2023):
//!
//! * **Inter-rank** — `world` rank threads exchange through the fabric
//!   (threads for real concurrency, the calibrated BSP simulator for
//!   scaling figures).
//! * **Intra-rank** — each rank's local kernels fan out over the morsel
//!   worker pool ([`crate::exec`]), budgeted by
//!   [`DistConfig::intra_op_threads`]: `0` = auto (available cores /
//!   world, so rank threads × morsel workers never oversubscribe), `1`
//!   = the paper's serial-per-rank behaviour. Parallel kernels are
//!   bit-identical to serial ones, so the knob never changes results.

mod partition;
mod ops;

use std::sync::Arc;

use crate::error::{Result, RylonError};
use crate::net::local::LocalFabric;
use crate::net::sim::SimFabric;
use crate::net::{CostModel, Fabric, FabricRef};

pub use self::ops::{
    dist_difference, dist_groupby, dist_groupby_preagg, dist_intersect,
    dist_join, dist_sort, dist_union,
};
pub use self::partition::{
    rebalance, shuffle, shuffle_all_columns, shuffle_with, HashPartitioner,
    Partitioner,
};

/// Which communication substrate a cluster runs on.
#[derive(Debug, Clone, Copy)]
pub enum FabricKind {
    /// Real shared-memory rank threads (correctness-grade execution).
    Threads,
    /// The calibrated BSP simulator (scaling figures on small hosts).
    Sim(CostModel),
}

/// Cluster configuration.
#[derive(Debug, Clone)]
pub struct DistConfig {
    /// World size (number of ranks).
    pub world: usize,
    pub fabric: FabricKind,
    /// Rows per shuffle chunk (backpressure: bounds in-flight bytes).
    pub shuffle_chunk_rows: usize,
    /// Morsel workers per rank for the local kernels. `0` = auto
    /// (available cores / world), `1` = serial (the seed behaviour).
    pub intra_op_threads: usize,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            world: 1,
            fabric: FabricKind::Threads,
            shuffle_chunk_rows: 1 << 16,
            intra_op_threads: 0,
        }
    }
}

impl DistConfig {
    /// Real rank threads.
    pub fn threads(world: usize) -> DistConfig {
        DistConfig {
            world,
            fabric: FabricKind::Threads,
            ..DistConfig::default()
        }
    }

    /// Simulated fabric with the given cost model.
    pub fn sim(world: usize, cost: CostModel) -> DistConfig {
        DistConfig {
            world,
            fabric: FabricKind::Sim(cost),
            ..DistConfig::default()
        }
    }

    /// Override the intra-rank morsel worker budget.
    pub fn with_intra_op_threads(mut self, threads: usize) -> DistConfig {
        self.intra_op_threads = threads;
        self
    }
}

/// Per-rank execution context handed to the SPMD closure.
pub struct RankCtx {
    pub rank: usize,
    pub size: usize,
    /// Rows per shuffle chunk (see [`DistConfig::shuffle_chunk_rows`]).
    pub shuffle_chunk_rows: usize,
    /// Resolved morsel worker budget for this rank's local kernels.
    pub intra_op_threads: usize,
    fabric: FabricRef,
}

impl RankCtx {
    /// The communication substrate (collectives take `&dyn Fabric`).
    pub fn fabric(&self) -> &dyn Fabric {
        self.fabric.as_ref()
    }
}

/// A job-scoped cluster: spawns one thread per rank, runs the SPMD
/// closure on each, and gathers the per-rank results in rank order.
pub struct Cluster {
    world: usize,
    shuffle_chunk_rows: usize,
    intra_op_threads: usize,
    fabric: FabricRef,
    sim: Option<Arc<SimFabric>>,
}

impl Cluster {
    pub fn new(cfg: DistConfig) -> Result<Cluster> {
        if cfg.world == 0 {
            return Err(RylonError::invalid("cluster world must be ≥ 1"));
        }
        let (fabric, sim): (FabricRef, Option<Arc<SimFabric>>) =
            match cfg.fabric {
                FabricKind::Threads => {
                    (Arc::new(LocalFabric::new(cfg.world)), None)
                }
                FabricKind::Sim(cost) => {
                    let sim = Arc::new(SimFabric::new(cfg.world, cost));
                    (sim.clone(), Some(sim))
                }
            };
        // The sim fabric meters compute with per-thread CPU clocks, so
        // work done on unmetered morsel workers would corrupt the
        // modeled makespan: auto (0) resolves to serial ranks there.
        // An explicit setting is honoured (caveat emptor for figures).
        let intra_op_threads = match cfg.fabric {
            FabricKind::Sim(_) if cfg.intra_op_threads == 0 => 1,
            _ => crate::exec::resolve_intra_op_threads(
                cfg.intra_op_threads,
                cfg.world,
            ),
        };
        Ok(Cluster {
            world: cfg.world,
            shuffle_chunk_rows: cfg.shuffle_chunk_rows.max(1),
            intra_op_threads,
            fabric,
            sim,
        })
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// The resolved per-rank morsel worker budget.
    pub fn intra_op_threads(&self) -> usize {
        self.intra_op_threads
    }

    /// Run the SPMD closure on every rank; returns per-rank results in
    /// rank order, or the first rank error.
    pub fn run<T, F>(&self, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(&mut RankCtx) -> Result<T> + Send + Sync,
    {
        let world = self.world;
        let results: Vec<Result<T>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..world)
                .map(|rank| {
                    let f = &f;
                    let fabric = Arc::clone(&self.fabric);
                    let chunk = self.shuffle_chunk_rows;
                    let intra = self.intra_op_threads;
                    s.spawn(move || {
                        // The rank thread's intra-op budget: local
                        // kernels called below fan out over it.
                        crate::exec::set_intra_op_threads(intra);
                        let mut ctx = RankCtx {
                            rank,
                            size: world,
                            shuffle_chunk_rows: chunk,
                            intra_op_threads: intra,
                            fabric,
                        };
                        // A panicking closure behaves like one returning
                        // an error (the documented abort contract: rank
                        // failures before any collective end the job
                        // cleanly; asymmetric mid-collective failures
                        // are out of contract on every fabric).
                        std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| f(&mut ctx)),
                        )
                        .unwrap_or_else(|_| {
                            Err(RylonError::comm(format!(
                                "rank {rank} panicked"
                            )))
                        })
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(RylonError::comm("rank thread panicked"))
                    })
                })
                .collect()
        });
        results.into_iter().collect()
    }

    /// Simulated makespan of the last job (sim fabric only).
    pub fn makespan(&self) -> Option<f64> {
        self.sim.as_ref().map(|s| s.makespan())
    }

    /// Total bytes posted to the fabric across all exchanges.
    pub fn bytes_sent(&self) -> u64 {
        self.fabric.bytes_sent()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_in_rank_order() {
        let cluster = Cluster::new(DistConfig::threads(5)).unwrap();
        let outs = cluster.run(|ctx| Ok(ctx.rank * 10)).unwrap();
        assert_eq!(outs, vec![0, 10, 20, 30, 40]);
        assert_eq!(cluster.world(), 5);
        assert!(cluster.makespan().is_none());
    }

    #[test]
    fn zero_world_rejected() {
        assert!(Cluster::new(DistConfig {
            world: 0,
            ..DistConfig::default()
        })
        .is_err());
    }

    #[test]
    fn sim_cluster_reports_makespan() {
        let cluster =
            Cluster::new(DistConfig::sim(3, CostModel::default())).unwrap();
        cluster
            .run(|ctx| {
                crate::net::collectives::barrier(ctx.fabric(), ctx.rank)
            })
            .unwrap();
        assert!(cluster.makespan().is_some());
    }

    #[test]
    fn intra_op_budget_reaches_rank_threads() {
        let cfg = DistConfig::threads(2).with_intra_op_threads(3);
        let cluster = Cluster::new(cfg).unwrap();
        assert_eq!(cluster.intra_op_threads(), 3);
        let outs = cluster
            .run(|ctx| {
                assert_eq!(ctx.intra_op_threads, 3);
                Ok(crate::exec::current().threads())
            })
            .unwrap();
        assert_eq!(outs, vec![3, 3]);
    }

    #[test]
    fn rank_errors_propagate() {
        let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
        let r: Result<Vec<()>> =
            cluster.run(|_| Err(RylonError::invalid("boom")));
        assert!(r.is_err());
    }
}
