//! Distributed execution: a [`Cluster`] of SPMD rank threads over a
//! pluggable [`crate::net::Fabric`], plus the `dist_*` operators that
//! compose the local kernels with a key-based shuffle — exactly the
//! paper's recipe (§III-C: "a key-based partition followed by a
//! key-based shuffle ... to collect similar records into a single
//! process").
//!
//! Execution is **two-level** (the hybrid model of Perera et al. 2023):
//!
//! * **Inter-rank** — `world` rank threads exchange through the fabric
//!   (threads for real concurrency, the calibrated BSP simulator for
//!   scaling figures).
//! * **Intra-rank** — each rank's local kernels fan out over the morsel
//!   worker pool ([`crate::exec`]), budgeted by
//!   [`DistConfig::intra_op_threads`]: `0` = auto (available cores /
//!   world, so rank threads × morsel workers never oversubscribe), `1`
//!   = the paper's serial-per-rank behaviour. Parallel kernels are
//!   bit-identical to serial ones, so the knob never changes results.
//!   With the `[exec] work_steal` knob on (the default on the threads
//!   fabric), the per-rank pools are **steal-linked**: a worker that
//!   drains its own rank's queue claims morsels from sibling ranks'
//!   queues, so one skewed partition no longer idles the rest of the
//!   cluster's workers — and since stealing only changes which worker
//!   runs a morsel, results still never change
//!   (`docs/ARCHITECTURE.md` has the scheduling walk-through).
//!
//! Ingest is distributed too: [`read_csv_partition`] loads one shared
//! CSV as per-rank partitions, by default through a **single-pass
//! byte-range scheme** in which each rank reads only its `file_len /
//! world` slice of bytes and a summary exchange splices the true
//! record boundaries across rank seams (`docs/INGEST.md` walks the
//! protocol).

#![warn(missing_docs)]

mod ingest;
mod partition;
mod ops;

use std::cell::Cell;
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Result, RylonError};
use crate::net::checked::CheckedFabric;
use crate::net::faulty::{FaultPlan, FaultyFabric};
use crate::net::local::LocalFabric;
use crate::net::sim::SimFabric;
use crate::net::tcp::{TcpFabric, TcpOpts};
use crate::net::{CostModel, Fabric, FabricRef, Fault, OutBufs};

pub use self::ingest::{
    read_csv_partition, read_csv_partition_with, IngestMode, IngestStats,
};
pub use self::ops::{
    dist_difference, dist_groupby, dist_groupby_preagg, dist_intersect,
    dist_join, dist_sort, dist_union,
};
pub use self::partition::{
    rebalance, shuffle, shuffle_all_columns, shuffle_with, HashPartitioner,
    Partitioner,
};

/// Which communication substrate a cluster runs on.
#[derive(Debug, Clone)]
pub enum FabricKind {
    /// Real shared-memory rank threads (correctness-grade execution).
    Threads,
    /// The calibrated BSP simulator (scaling figures on small hosts).
    Sim(CostModel),
    /// One OS process per rank over TCP sockets
    /// ([`crate::net::tcp::TcpFabric`]): the paper's MPI-style
    /// deployment model. The cluster hosts exactly one rank —
    /// `opts.rank` — and [`Cluster::run`] returns only that rank's
    /// result; peers are the other processes at the rendezvous.
    Tcp(TcpOpts),
}

/// Cluster configuration.
#[derive(Debug, Clone)]
pub struct DistConfig {
    /// World size (number of ranks).
    pub world: usize,
    /// Communication substrate (real rank threads or the simulator).
    pub fabric: FabricKind,
    /// Rows per shuffle chunk (backpressure: bounds in-flight bytes).
    pub shuffle_chunk_rows: usize,
    /// Morsel workers per rank for the local kernels. `0` = auto
    /// (available cores / world), `1` = serial (the seed behaviour).
    pub intra_op_threads: usize,
    /// Rows below which kernels stay serial (`[exec]
    /// par_row_threshold`; default [`crate::exec::PAR_ROW_THRESHOLD`]).
    pub par_row_threshold: usize,
    /// Streaming-ingest chunk size in bytes for each rank's CSV reads
    /// (`[exec] ingest_chunk_bytes`). `0` = the process default
    /// ([`crate::exec::INGEST_CHUNK_BYTES`], env-overridable). Bounds
    /// raw-text memory at O(chunk) for the streaming readers and the
    /// two-pass ingest fallback; the single-pass scheme instead holds
    /// each rank's own byte range (O(file / world) — the same order as
    /// its parsed partition) until boundaries resolve.
    pub ingest_chunk_bytes: usize,
    /// Single-pass distributed CSV ingest (`[exec]
    /// ingest_single_pass`): each rank reads only its byte range of a
    /// shared CSV, once, and rank seams are spliced through a summary
    /// exchange. `None` = the process default
    /// ([`crate::exec::INGEST_SINGLE_PASS`], overridable via the
    /// `INGEST_SINGLE_PASS` env var); `Some(false)` forces the
    /// two-pass count-then-parse fallback. Bit-identical either way.
    pub ingest_single_pass: Option<bool>,
    /// Cross-rank work stealing (`[exec] work_steal`): morsel workers
    /// that drain their own rank's queue steal tasks from sibling
    /// ranks' queues, so one skewed partition no longer idles every
    /// other rank's workers. `None` = the process default
    /// ([`crate::exec::WORK_STEAL`], overridable via the `WORK_STEAL`
    /// env var); `Some(false)` keeps the isolated per-rank pools.
    /// Stealing changes which worker runs a morsel, never where its
    /// result lands, so results are bit-identical either way. Forced
    /// off on the sim fabric, whose cost model meters compute with
    /// per-rank-thread CPU clocks that cross-rank workers would escape.
    pub work_steal: Option<bool>,
    /// Fused pipeline execution (`[exec] pipeline_fuse`): rank-local
    /// stage chains in [`crate::pipeline::Pipeline::run_dist`] run as
    /// fused segments (one pass per morsel, no intermediate `Table`
    /// between fused stages) instead of operator-at-a-time. `None` =
    /// the process default ([`crate::exec::PIPELINE_FUSE`], overridable
    /// via the `PIPELINE_FUSE` env var); `Some(false)` forces the
    /// materializing executor. Bit-identical either way — fusion moves
    /// work between morsels, never changes per-row arithmetic or merge
    /// order.
    pub pipeline_fuse: Option<bool>,
    /// Encoded RYF row groups (`[exec] ryf_encoding`): rank-local RYF
    /// writes ([`crate::io::ryf::RyfWriter`] — ingest convert, spill
    /// directories) emit the encoded `RYF2` format with per-group
    /// zone-map statistics instead of raw `RYF1`. `None` = the process
    /// default ([`crate::exec::RYF_ENCODING`], overridable via the
    /// `RYF_ENCODING` env var); `Some(false)` forces the raw oracle
    /// format. Readers accept both formats whatever this says, and
    /// scans are bit-identical either way (`docs/STORAGE.md`).
    pub ryf_encoding: Option<bool>,
    /// Deterministic fault-injection plan (`[exec] fault_plan`;
    /// grammar in [`crate::net::faulty::FaultPlan`]). `None` = the
    /// process default (empty unless the `FAULT_PLAN` env var is set);
    /// a non-empty plan wraps the fabric in a
    /// [`crate::net::faulty::FaultyFabric`] firing `error`/`panic`/
    /// `delay` faults at exact `(rank, exchange)` coordinates.
    pub fault_plan: Option<String>,
    /// Collective timeout in milliseconds (`[exec]
    /// collective_timeout_ms`). `None` = the process default
    /// (0 unless the `COLLECTIVE_TIMEOUT_MS` env var is set); `0` = no
    /// timeout. Non-zero bounds every fabric collective, turning a
    /// hung rank into a symmetric rank-attributed comm error.
    pub collective_timeout_ms: Option<u64>,
    /// Per-rank memory budget in bytes (`[exec] memory_budget_bytes`):
    /// the working-set ceiling each rank's operators reserve against
    /// through [`crate::exec::MemoryBudget`]. `0` = the process
    /// default ([`crate::exec::MEMORY_BUDGET_BYTES`], overridable via
    /// the `MEMORY_BUDGET_BYTES` env var — which is also `0`,
    /// unbounded, unless set). Under a non-zero budget, joins, sorts,
    /// and groupbys whose working set does not fit degrade to their
    /// spill-to-disk paths — bit-identical results either way
    /// (`docs/MEMORY.md`).
    pub memory_budget_bytes: usize,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            world: 1,
            fabric: FabricKind::Threads,
            shuffle_chunk_rows: 1 << 16,
            intra_op_threads: 0,
            par_row_threshold: crate::exec::PAR_ROW_THRESHOLD,
            ingest_chunk_bytes: 0,
            ingest_single_pass: None,
            work_steal: None,
            pipeline_fuse: None,
            ryf_encoding: None,
            fault_plan: None,
            collective_timeout_ms: None,
            memory_budget_bytes: 0,
        }
    }
}

impl DistConfig {
    /// Real rank threads.
    pub fn threads(world: usize) -> DistConfig {
        DistConfig {
            world,
            fabric: FabricKind::Threads,
            ..DistConfig::default()
        }
    }

    /// Simulated fabric with the given cost model.
    pub fn sim(world: usize, cost: CostModel) -> DistConfig {
        DistConfig {
            world,
            fabric: FabricKind::Sim(cost),
            ..DistConfig::default()
        }
    }

    /// One-process-per-rank TCP fabric: this process joins a
    /// `world`-rank job as `rank`, meeting its peers at `rendezvous`
    /// (`host:port`; rank 0 listens there).
    pub fn tcp(
        world: usize,
        rank: usize,
        rendezvous: impl Into<String>,
    ) -> DistConfig {
        DistConfig::default().with_tcp(world, rank, rendezvous)
    }

    /// Switch an existing config to the TCP fabric (see
    /// [`DistConfig::tcp`]).
    pub fn with_tcp(
        mut self,
        world: usize,
        rank: usize,
        rendezvous: impl Into<String>,
    ) -> DistConfig {
        self.world = world;
        self.fabric = FabricKind::Tcp(TcpOpts::new(rank, rendezvous));
        self
    }

    /// Override the intra-rank morsel worker budget.
    pub fn with_intra_op_threads(mut self, threads: usize) -> DistConfig {
        self.intra_op_threads = threads;
        self
    }

    /// Override the parallelism row threshold (rows below it run the
    /// serial kernel paths).
    pub fn with_par_row_threshold(mut self, rows: usize) -> DistConfig {
        self.par_row_threshold = rows;
        self
    }

    /// Override the streaming-ingest chunk size (`0` = the process
    /// default).
    pub fn with_ingest_chunk_bytes(mut self, bytes: usize) -> DistConfig {
        self.ingest_chunk_bytes = bytes;
        self
    }

    /// Force single-pass distributed ingest on (`true`) or off
    /// (`false`, the two-pass fallback/oracle).
    pub fn with_ingest_single_pass(mut self, on: bool) -> DistConfig {
        self.ingest_single_pass = Some(on);
        self
    }

    /// Force cross-rank work stealing on (`true`) or off (`false`, the
    /// isolated per-rank pools). The sim fabric ignores `true` (see
    /// [`DistConfig::work_steal`]).
    pub fn with_work_steal(mut self, on: bool) -> DistConfig {
        self.work_steal = Some(on);
        self
    }

    /// Force fused pipeline execution on (`true`) or off (`false`, the
    /// operator-at-a-time oracle).
    pub fn with_pipeline_fuse(mut self, on: bool) -> DistConfig {
        self.pipeline_fuse = Some(on);
        self
    }

    /// Force encoded RYF writes on (`true`) or off (`false`, the raw
    /// `RYF1` oracle format).
    pub fn with_ryf_encoding(mut self, on: bool) -> DistConfig {
        self.ryf_encoding = Some(on);
        self
    }

    /// Install a deterministic fault-injection plan (empty string =
    /// explicitly no faults, overriding a `FAULT_PLAN` env default).
    pub fn with_fault_plan(mut self, plan: impl Into<String>) -> DistConfig {
        self.fault_plan = Some(plan.into());
        self
    }

    /// Bound every fabric collective to `ms` milliseconds (`0` =
    /// explicitly no timeout, overriding a `COLLECTIVE_TIMEOUT_MS` env
    /// default).
    pub fn with_collective_timeout_ms(mut self, ms: u64) -> DistConfig {
        self.collective_timeout_ms = Some(ms);
        self
    }

    /// Cap each rank's operator working set at `bytes` (`0` = the
    /// process default, itself unbounded unless `MEMORY_BUDGET_BYTES`
    /// is set). Operators that do not fit spill to disk and return
    /// bit-identical results (see [`DistConfig::memory_budget_bytes`]).
    pub fn with_memory_budget(mut self, bytes: usize) -> DistConfig {
        self.memory_budget_bytes = bytes;
        self
    }
}

/// Per-rank execution context handed to the SPMD closure.
pub struct RankCtx {
    /// This rank's id (`0..size`).
    pub rank: usize,
    /// World size (number of ranks in the job).
    pub size: usize,
    /// Rows per shuffle chunk (see [`DistConfig::shuffle_chunk_rows`]).
    pub shuffle_chunk_rows: usize,
    /// Resolved morsel worker budget for this rank's local kernels.
    pub intra_op_threads: usize,
    fabric: FabricRef,
    /// The checked collective layer (the same object `fabric` points
    /// at) — kept concretely typed for the verdict-carrying calls.
    checked: Arc<CheckedFabric>,
    /// Label of the collective operation this rank is currently
    /// running, for fault attribution (`docs/FAULTS.md`).
    op: Cell<&'static str>,
}

impl RankCtx {
    /// The communication substrate (collectives take `&dyn Fabric`).
    /// All collectives through it carry per-rank Ok/Err verdicts — it
    /// is the cluster's [`crate::net::checked::CheckedFabric`].
    pub fn fabric(&self) -> &dyn Fabric {
        self.fabric.as_ref()
    }

    /// Label the collective operation this rank is about to run
    /// (`"shuffle"`, `"dist_join"`, `"ingest.summary"`, …). Every
    /// `dist_*` entry point sets it; a fault surfacing afterwards is
    /// attributed to this label in [`crate::error::AbortInfo::op`].
    pub fn set_op(&self, op: &'static str) {
        self.op.set(op);
    }

    /// The current fault-attribution label (see [`RankCtx::set_op`]).
    pub fn current_op(&self) -> &'static str {
        self.op.get()
    }

    /// Summary exchange: allgather one small per-rank blob, returned
    /// indexed by source rank. The building block protocol steps like
    /// the single-pass ingest's boundary-summary swap are made of —
    /// every rank must call it (BSP superstep semantics).
    pub fn allgather(&self, data: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        crate::net::collectives::allgather(self.fabric(), self.rank, data)
    }

    /// Raw AllToAllv: deliver `out[d]` to rank `d`, receive one buffer
    /// per source (empty buffers allowed — how the ingest routes
    /// record fragments only to the ranks that own them). Every rank
    /// must call it.
    pub fn exchange(&self, out: OutBufs) -> Result<OutBufs> {
        self.fabric().exchange(self.rank, out)
    }

    /// Allgather each rank's fallible payload. If any rank failed,
    /// **every** rank returns the lowest-failing-rank's error (so a
    /// rank-local failure aborts the whole job symmetrically instead
    /// of stranding peers in a collective). Every rank must call it —
    /// including failed ranks, which is the point.
    pub fn allgather_checked(
        &self,
        local: std::result::Result<Vec<u8>, &RylonError>,
    ) -> Result<Vec<Vec<u8>>> {
        let size = self.size;
        self.checked.exchange_verdict(
            self.rank,
            self.op.get(),
            local.map(|payload| vec![payload; size]),
        )
    }

    /// AllToAllv where each rank contributes either its buffers or its
    /// rank-local error; any rank's error aborts every rank with the
    /// same attribution (see [`RankCtx::allgather_checked`]).
    pub fn exchange_checked(
        &self,
        local: std::result::Result<OutBufs, &RylonError>,
    ) -> Result<OutBufs> {
        self.checked
            .exchange_verdict(self.rank, self.op.get(), local)
    }
}

/// A cluster: spawns one thread per rank per [`Cluster::run`], runs the
/// SPMD closure on each, and gathers the per-rank results in rank
/// order. The cluster owns one **persistent executor pool per rank**
/// ([`crate::exec::WorkerPool`]): rank threads install their pool at
/// the start of every run, so morsel workers park between operators
/// *and* between runs, and are only joined when the cluster drops.
pub struct Cluster {
    world: usize,
    shuffle_chunk_rows: usize,
    intra_op_threads: usize,
    par_row_threshold: usize,
    ingest_chunk_bytes: usize,
    ingest_single_pass: bool,
    work_steal: bool,
    pipeline_fuse: bool,
    ryf_encoding: bool,
    collective_timeout_ms: u64,
    memory_budget_bytes: usize,
    /// Bytes rank threads have written to spill files, summed over all
    /// runs (drained from the rank threads' thread-local counters at
    /// the end of each run — success or abort).
    spilled_bytes: std::sync::atomic::AtomicU64,
    /// Spill partitions/runs written by rank threads, summed likewise.
    spilled_partitions: std::sync::atomic::AtomicU64,
    /// RYF scan-pushdown counters drained from rank threads at the end
    /// of each run (success or abort), one atomic per
    /// [`crate::exec::ScanCounters`] field.
    scan_groups_total: std::sync::atomic::AtomicU64,
    scan_groups_skipped: std::sync::atomic::AtomicU64,
    scan_decoded_bytes: std::sync::atomic::AtomicU64,
    scan_decoded_bytes_avoided: std::sync::atomic::AtomicU64,
    scan_pruned_columns: std::sync::atomic::AtomicU64,
    /// The outermost fabric every collective goes through: the checked
    /// verdict layer over (optionally) the fault injector over the
    /// base rendezvous fabric.
    fabric: FabricRef,
    /// Concretely-typed handle to the same checked layer.
    checked: Arc<CheckedFabric>,
    /// The fault injector, when a fault plan is installed.
    faulty: Option<Arc<FaultyFabric>>,
    sim: Option<Arc<SimFabric>>,
    /// The ranks this process hosts: every rank for the in-process
    /// fabrics, exactly one for TCP (the rest are peer processes).
    local_ranks: Vec<usize>,
    /// One long-lived morsel-worker pool per **local** rank, indexed
    /// by `local_ranks` slot; steal-linked to each other when
    /// `work_steal` resolved on.
    pools: Vec<Arc<crate::exec::WorkerPool>>,
}

impl Cluster {
    /// Build a cluster for `cfg` (fabric, pools, resolved knobs).
    pub fn new(cfg: DistConfig) -> Result<Cluster> {
        if cfg.world == 0 {
            return Err(RylonError::invalid("cluster world must be ≥ 1"));
        }
        let collective_timeout_ms =
            crate::exec::resolve_collective_timeout_ms(
                cfg.collective_timeout_ms,
            );
        let timeout = (collective_timeout_ms > 0)
            .then(|| Duration::from_millis(collective_timeout_ms));
        let plan = FaultPlan::parse(&crate::exec::resolve_fault_plan(
            cfg.fault_plan.as_deref(),
        ))?;
        let (base, sim): (FabricRef, Option<Arc<SimFabric>>) =
            match &cfg.fabric {
                FabricKind::Threads => (
                    Arc::new(
                        LocalFabric::new(cfg.world).with_timeout(timeout),
                    ),
                    None,
                ),
                FabricKind::Sim(cost) => {
                    let sim = Arc::new(
                        SimFabric::new(cfg.world, *cost)
                            .with_timeout(timeout),
                    );
                    (sim.clone(), Some(sim))
                }
                FabricKind::Tcp(opts) => (
                    Arc::new(TcpFabric::connect(cfg.world, opts, timeout)?),
                    None,
                ),
            };
        // The in-process fabrics host every rank; a TCP cluster hosts
        // exactly one — the rest are peer processes at the rendezvous.
        let local_ranks: Vec<usize> = match &cfg.fabric {
            FabricKind::Tcp(opts) => vec![opts.rank],
            _ => (0..cfg.world).collect(),
        };
        // Fabric layering: checked verdicts outermost (every collective
        // carries per-rank Ok/Err), then the fault injector (so
        // injected faults hit *under* the verdict layer, like real
        // ones), then the rendezvous fabric.
        let (faulty, inner): (Option<Arc<FaultyFabric>>, FabricRef) =
            if plan.is_empty() {
                (None, base)
            } else {
                let f = Arc::new(FaultyFabric::new(base, plan));
                (Some(Arc::clone(&f)), f)
            };
        let checked = Arc::new(CheckedFabric::new(inner));
        let fabric: FabricRef = Arc::clone(&checked) as FabricRef;
        // The sim fabric meters compute with per-thread CPU clocks, so
        // work done on unmetered morsel workers would corrupt the
        // modeled makespan: auto (0) resolves to serial ranks there.
        // An explicit setting is honoured (caveat emptor for figures).
        let intra_op_threads = match &cfg.fabric {
            FabricKind::Sim(_) if cfg.intra_op_threads == 0 => 1,
            // A TCP rank is alone in its process, so auto gets every
            // available core rather than a 1/world share.
            FabricKind::Tcp(_) => crate::exec::resolve_intra_op_threads(
                cfg.intra_op_threads,
                1,
            ),
            _ => crate::exec::resolve_intra_op_threads(
                cfg.intra_op_threads,
                cfg.world,
            ),
        };
        // One pool per *locally hosted* rank (indexed positionally by
        // `local_ranks` slot).
        let pools: Vec<Arc<crate::exec::WorkerPool>> = local_ranks
            .iter()
            .map(|_| Arc::new(crate::exec::WorkerPool::new()))
            .collect();
        // Work stealing runs rank morsels on sibling ranks' workers,
        // which the sim fabric's per-rank-thread CPU metering cannot
        // see — so the sim keeps isolated pools whatever the knob says
        // (mirroring the auto-threads-resolve-to-serial rule above).
        let work_steal = match &cfg.fabric {
            FabricKind::Sim(_) => false,
            // One local rank per process: no sibling pool to steal from.
            FabricKind::Tcp(_) => false,
            FabricKind::Threads => {
                crate::exec::resolve_work_steal(cfg.work_steal)
                    && cfg.world > 1
            }
        };
        if work_steal {
            crate::exec::link_steal_group(&pools);
        }
        Ok(Cluster {
            world: cfg.world,
            shuffle_chunk_rows: cfg.shuffle_chunk_rows.max(1),
            intra_op_threads,
            par_row_threshold: cfg.par_row_threshold.max(1),
            ingest_chunk_bytes: crate::exec::resolve_ingest_chunk_bytes(
                cfg.ingest_chunk_bytes,
            ),
            ingest_single_pass: crate::exec::resolve_ingest_single_pass(
                cfg.ingest_single_pass,
            ),
            work_steal,
            pipeline_fuse: crate::exec::resolve_pipeline_fuse(
                cfg.pipeline_fuse,
            ),
            ryf_encoding: crate::exec::resolve_ryf_encoding(
                cfg.ryf_encoding,
            ),
            collective_timeout_ms,
            memory_budget_bytes: crate::exec::resolve_memory_budget_bytes(
                cfg.memory_budget_bytes,
            ),
            spilled_bytes: std::sync::atomic::AtomicU64::new(0),
            spilled_partitions: std::sync::atomic::AtomicU64::new(0),
            scan_groups_total: std::sync::atomic::AtomicU64::new(0),
            scan_groups_skipped: std::sync::atomic::AtomicU64::new(0),
            scan_decoded_bytes: std::sync::atomic::AtomicU64::new(0),
            scan_decoded_bytes_avoided: std::sync::atomic::AtomicU64::new(
                0,
            ),
            scan_pruned_columns: std::sync::atomic::AtomicU64::new(0),
            fabric,
            checked,
            faulty,
            sim,
            local_ranks,
            pools,
        })
    }

    /// Number of ranks.
    pub fn world(&self) -> usize {
        self.world
    }

    /// The ranks this process hosts, in the order [`Cluster::run`]
    /// returns their results: `0..world` for the in-process fabrics,
    /// just the configured rank for `FabricKind::Tcp`.
    pub fn local_ranks(&self) -> &[usize] {
        &self.local_ranks
    }

    /// The resolved per-rank morsel worker budget.
    pub fn intra_op_threads(&self) -> usize {
        self.intra_op_threads
    }

    /// Whether the rank pools are steal-linked (the resolved
    /// `[exec] work_steal` knob; always `false` on the sim fabric and
    /// at world 1).
    pub fn work_steal(&self) -> bool {
        self.work_steal
    }

    /// Whether rank-local pipeline chains run fused segments (the
    /// resolved `[exec] pipeline_fuse` knob).
    pub fn pipeline_fuse(&self) -> bool {
        self.pipeline_fuse
    }

    /// The resolved per-rank memory budget in bytes (`0` = unbounded;
    /// the `[exec] memory_budget_bytes` knob).
    pub fn memory_budget_bytes(&self) -> usize {
        self.memory_budget_bytes
    }

    /// Whether rank-local RYF writes emit the encoded `RYF2` format
    /// (the resolved `[exec] ryf_encoding` knob).
    pub fn ryf_encoding(&self) -> bool {
        self.ryf_encoding
    }

    /// RYF scan-pushdown counters summed over every rank thread and
    /// run so far (drained from the rank threads' thread-local
    /// counters at the end of each run — success or abort). The CLI
    /// folds these into its ETL phase JSON (`groups_skipped`,
    /// `decoded_bytes`, …; `docs/STORAGE.md`).
    pub fn scan_stats(&self) -> crate::exec::ScanCounters {
        use std::sync::atomic::Ordering::Relaxed;
        crate::exec::ScanCounters {
            groups_total: self.scan_groups_total.load(Relaxed),
            groups_skipped: self.scan_groups_skipped.load(Relaxed),
            decoded_bytes: self.scan_decoded_bytes.load(Relaxed),
            decoded_bytes_avoided: self
                .scan_decoded_bytes_avoided
                .load(Relaxed),
            pruned_columns: self.scan_pruned_columns.load(Relaxed),
        }
    }

    /// Bytes rank threads have written to spill files, summed over all
    /// pools and runs so far (0 with an unbounded budget, or whenever
    /// every working set fit). The out-of-core gauge the CLI folds
    /// into its phase JSON as `bytes_spilled`.
    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Spill partitions/runs written by rank threads, summed over all
    /// runs so far (the `spill_partitions` counter).
    pub fn spilled_partitions(&self) -> u64 {
        self.spilled_partitions
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Total morsel tasks executed by a rank's worker on a **sibling**
    /// rank's behalf, summed over all pools and runs so far — the
    /// load-balance gauge the skew bench reports (0 with stealing
    /// off, or whenever partitions were balanced enough that no worker
    /// ever went idle while a sibling had queued work).
    pub fn stolen_tasks(&self) -> u64 {
        self.pools.iter().map(|p| p.stolen_tasks()).sum()
    }

    /// Run the SPMD closure on every **locally hosted** rank; returns
    /// their results in [`Cluster::local_ranks`] order (rank order `0..
    /// world` on the in-process fabrics, the single configured rank on
    /// TCP), or the first rank error.
    ///
    /// Rank failures are symmetric: any rank's error or panic is
    /// recorded on the fabric as a [`Fault`], waking every peer parked
    /// in a collective, and **every** rank's closure then returns the
    /// same rank/op/step-attributed [`RylonError::Aborted`]. The fault
    /// also poisons the cluster — subsequent `run` calls fail fast
    /// with it until [`Cluster::clear_fault`] — so no rank can
    /// rendezvous with a dead peer.
    pub fn run<T, F>(&self, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(&mut RankCtx) -> Result<T> + Send + Sync,
    {
        if let Some(fault) = self.fabric.fault() {
            return Err(fault.to_error());
        }
        let world = self.world;
        let results: Vec<Result<T>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .local_ranks
                .iter()
                .enumerate()
                .map(|(slot, &rank)| {
                    let f = &f;
                    let fabric = Arc::clone(&self.fabric);
                    let checked = Arc::clone(&self.checked);
                    let chunk = self.shuffle_chunk_rows;
                    let intra = self.intra_op_threads;
                    let threshold = self.par_row_threshold;
                    let ingest_chunk = self.ingest_chunk_bytes;
                    let single_pass = self.ingest_single_pass;
                    let steal = self.work_steal;
                    let fuse = self.pipeline_fuse;
                    let ryf_enc = self.ryf_encoding;
                    let budget = self.memory_budget_bytes;
                    let spilled_bytes = &self.spilled_bytes;
                    let spilled_partitions = &self.spilled_partitions;
                    let pool = Arc::clone(&self.pools[slot]);
                    s.spawn(move || {
                        // The rank thread's intra-op budget: local
                        // kernels called below fan out over it, onto
                        // this rank's long-lived worker pool.
                        crate::exec::set_intra_op_threads(intra);
                        crate::exec::set_par_row_threshold(threshold);
                        crate::exec::set_ingest_chunk_bytes(ingest_chunk);
                        crate::exec::set_ingest_single_pass(single_pass);
                        crate::exec::set_work_steal(steal);
                        crate::exec::set_pipeline_fuse(fuse);
                        crate::exec::set_ryf_encoding(ryf_enc);
                        crate::exec::set_memory_budget_bytes(budget);
                        crate::exec::install_thread_pool(pool);
                        let mut ctx = RankCtx {
                            rank,
                            size: world,
                            shuffle_chunk_rows: chunk,
                            intra_op_threads: intra,
                            fabric,
                            checked: Arc::clone(&checked),
                            op: Cell::new("job"),
                        };
                        // A panicking closure behaves like one
                        // returning an error; either way the failure
                        // joins the fault domain below. Panics from
                        // pooled morsel tasks re-raise here too (the
                        // pool routes them to the submitting rank), so
                        // they take the same path.
                        let result = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| f(&mut ctx)),
                        )
                        .unwrap_or_else(|payload| {
                            Err(RylonError::comm(format!(
                                "rank {rank} panicked: {}",
                                crate::exec::panic_message(
                                    payload.as_ref()
                                )
                            )))
                        });
                        // Fold this rank thread's spill activity into
                        // the cluster totals — on success *and* after
                        // an error or panic, so aborted spills are
                        // still visible in the gauges.
                        let (sb, sp) = crate::exec::take_spill_stats();
                        spilled_bytes.fetch_add(
                            sb,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                        spilled_partitions.fetch_add(
                            sp,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                        // Likewise this rank thread's scan-pushdown
                        // counters (zone-map skips, decoded bytes, …).
                        let sc = crate::exec::take_scan_stats();
                        {
                            use std::sync::atomic::Ordering::Relaxed;
                            self.scan_groups_total
                                .fetch_add(sc.groups_total, Relaxed);
                            self.scan_groups_skipped
                                .fetch_add(sc.groups_skipped, Relaxed);
                            self.scan_decoded_bytes
                                .fetch_add(sc.decoded_bytes, Relaxed);
                            self.scan_decoded_bytes_avoided.fetch_add(
                                sc.decoded_bytes_avoided,
                                Relaxed,
                            );
                            self.scan_pruned_columns
                                .fetch_add(sc.pruned_columns, Relaxed);
                        }
                        // Deliver any failure to every peer: record it
                        // on the fabric (waking parked ranks) and
                        // return it with rank/op/step attribution. A
                        // fault received *from* a peer keeps its
                        // original attribution.
                        result.map_err(|e| {
                            let fault = Fault::from_error(
                                rank,
                                ctx.op.get(),
                                checked.step(rank),
                                &e,
                            );
                            checked.abort(fault.clone());
                            fault.to_error()
                        })
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(RylonError::comm(
                            "rank thread panicked outside the fault \
                             domain",
                        ))
                    })
                })
                .collect()
        });
        results.into_iter().collect()
    }

    /// Simulated makespan of the last job (sim fabric only).
    pub fn makespan(&self) -> Option<f64> {
        self.sim.as_ref().map(|s| s.makespan())
    }

    /// Total bytes posted to the fabric across all exchanges.
    pub fn bytes_sent(&self) -> u64 {
        self.fabric.bytes_sent()
    }

    /// The fault poisoning the cluster, if a collective has aborted.
    /// While set, [`Cluster::run`] fails fast with it.
    pub fn fault(&self) -> Option<Fault> {
        self.fabric.fault()
    }

    /// Clear a poisoning fault and reset the fabric's rendezvous state
    /// so the cluster can run jobs again. Abort counters are *not*
    /// reset — they are cumulative across clears.
    pub fn clear_fault(&self) {
        self.fabric.clear_fault()
    }

    /// Number of collectives aborted so far (out-of-band faults
    /// recorded on the fabric: rank aborts, collective timeouts,
    /// rendezvous corruption). Cumulative across [`Cluster::clear_fault`].
    pub fn aborted_collectives(&self) -> u64 {
        self.fabric.aborts()
    }

    /// Number of faults the configured `[exec] fault_plan` has fired so
    /// far (0 when no plan is active).
    pub fn injected_faults(&self) -> u64 {
        self.faulty.as_ref().map_or(0, |f| f.injected_faults())
    }

    /// The resolved `[exec] collective_timeout_ms` (0 = no timeout).
    pub fn collective_timeout_ms(&self) -> u64 {
        self.collective_timeout_ms
    }

    /// Snapshot of the fault-domain counters
    /// ([`crate::metrics::FaultStats`]) — what the CLI and benches fold
    /// into their JSON breakdowns.
    pub fn fault_stats(&self) -> crate::metrics::FaultStats {
        crate::metrics::FaultStats {
            aborted_collectives: self.aborted_collectives(),
            injected_faults: self.injected_faults(),
        }
    }
}

impl Drop for Cluster {
    /// Graceful executor shutdown: park-wake every rank's morsel
    /// workers and join them. Rank threads are scoped per `run`, so no
    /// job can still be in flight here.
    fn drop(&mut self) {
        for pool in &self.pools {
            pool.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_in_rank_order() {
        let cluster = Cluster::new(DistConfig::threads(5)).unwrap();
        let outs = cluster.run(|ctx| Ok(ctx.rank * 10)).unwrap();
        assert_eq!(outs, vec![0, 10, 20, 30, 40]);
        assert_eq!(cluster.world(), 5);
        assert!(cluster.makespan().is_none());
    }

    #[test]
    fn tcp_world_one_cluster_runs_locally() {
        // Rendezvous is never dialed at world 1, so any address works.
        let cluster =
            Cluster::new(DistConfig::tcp(1, 0, "127.0.0.1:1")).unwrap();
        assert_eq!(cluster.local_ranks(), &[0]);
        assert!(!cluster.work_steal());
        let outs = cluster
            .run(|ctx| {
                assert_eq!((ctx.rank, ctx.size), (0, 1));
                ctx.allgather(vec![42u8]).map(|bufs| bufs[0][0])
            })
            .unwrap();
        assert_eq!(outs, vec![42]);
    }

    #[test]
    fn zero_world_rejected() {
        assert!(Cluster::new(DistConfig {
            world: 0,
            ..DistConfig::default()
        })
        .is_err());
    }

    #[test]
    fn sim_cluster_reports_makespan() {
        let cluster =
            Cluster::new(DistConfig::sim(3, CostModel::default())).unwrap();
        cluster
            .run(|ctx| {
                crate::net::collectives::barrier(ctx.fabric(), ctx.rank)
            })
            .unwrap();
        assert!(cluster.makespan().is_some());
    }

    #[test]
    fn intra_op_budget_reaches_rank_threads() {
        let cfg = DistConfig::threads(2).with_intra_op_threads(3);
        let cluster = Cluster::new(cfg).unwrap();
        assert_eq!(cluster.intra_op_threads(), 3);
        let outs = cluster
            .run(|ctx| {
                assert_eq!(ctx.intra_op_threads, 3);
                Ok(crate::exec::current().threads())
            })
            .unwrap();
        assert_eq!(outs, vec![3, 3]);
    }

    #[test]
    fn rank_errors_propagate() {
        let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
        let r: Result<Vec<()>> =
            cluster.run(|_| Err(RylonError::invalid("boom")));
        assert!(r.is_err());
    }

    #[test]
    fn rank_pools_persist_across_runs() {
        let cfg = DistConfig::threads(2).with_intra_op_threads(3);
        let cluster = Cluster::new(cfg).unwrap();
        let job = |_ctx: &mut RankCtx| {
            // Two back-to-back parallel operators on this rank, then
            // report the rank pool's thread-generation counter.
            let exec = crate::exec::current();
            let a = crate::exec::for_each_morsel(1 << 18, exec, |m| m.len());
            let b = crate::exec::for_each_morsel(1 << 18, exec, |m| m.len());
            assert_eq!(a, b);
            Ok(crate::exec::current_pool_spawned_threads())
        };
        let first = cluster.run(job).unwrap();
        let second = cluster.run(job).unwrap();
        assert!(first.iter().all(|&g| g >= 2), "workers were spawned");
        // Same generation on the second run ⇒ the cluster-owned pools
        // (and their worker threads) were reused, not respawned.
        assert_eq!(first, second);
    }

    #[test]
    fn par_row_threshold_reaches_rank_threads() {
        let cfg = DistConfig::threads(2)
            .with_intra_op_threads(2)
            .with_par_row_threshold(7);
        let cluster = Cluster::new(cfg).unwrap();
        let outs = cluster
            .run(|_| Ok(crate::exec::par_row_threshold()))
            .unwrap();
        assert_eq!(outs, vec![7, 7]);
    }

    #[test]
    fn ingest_single_pass_reaches_rank_threads() {
        let cfg = DistConfig::threads(2).with_ingest_single_pass(false);
        let cluster = Cluster::new(cfg).unwrap();
        let outs = cluster
            .run(|_| Ok(crate::exec::ingest_single_pass()))
            .unwrap();
        assert_eq!(outs, vec![false, false]);
        // None resolves to the process default on every rank.
        let cluster = Cluster::new(DistConfig::threads(2)).unwrap();
        let outs = cluster
            .run(|_| Ok(crate::exec::ingest_single_pass()))
            .unwrap();
        let d = crate::exec::default_ingest_single_pass();
        assert_eq!(outs, vec![d, d]);
    }

    #[test]
    fn ingest_chunk_bytes_reaches_rank_threads() {
        let cfg = DistConfig::threads(2).with_ingest_chunk_bytes(4096);
        let cluster = Cluster::new(cfg).unwrap();
        let outs = cluster
            .run(|_| Ok(crate::exec::ingest_chunk_bytes()))
            .unwrap();
        assert_eq!(outs, vec![4096, 4096]);
        // 0 resolves to the process default on every rank.
        let cluster =
            Cluster::new(DistConfig::threads(2)).unwrap();
        let outs = cluster
            .run(|_| Ok(crate::exec::ingest_chunk_bytes()))
            .unwrap();
        let d = crate::exec::default_ingest_chunk_bytes();
        assert_eq!(outs, vec![d, d]);
    }

    #[test]
    fn work_steal_resolves_and_reaches_rank_threads() {
        // Explicit off wins; world 1 and the sim fabric force off.
        let off = Cluster::new(
            DistConfig::threads(2).with_work_steal(false),
        )
        .unwrap();
        assert!(!off.work_steal());
        let outs = off.run(|_| Ok(crate::exec::work_steal())).unwrap();
        assert_eq!(outs, vec![false, false]);
        let on =
            Cluster::new(DistConfig::threads(2).with_work_steal(true))
                .unwrap();
        assert!(on.work_steal());
        let outs = on.run(|_| Ok(crate::exec::work_steal())).unwrap();
        assert_eq!(outs, vec![true, true]);
        assert_eq!(on.stolen_tasks(), 0, "no work submitted yet");
        let solo =
            Cluster::new(DistConfig::threads(1).with_work_steal(true))
                .unwrap();
        assert!(!solo.work_steal(), "a lone rank has nobody to steal from");
        let sim = Cluster::new(
            DistConfig::sim(3, CostModel::default()).with_work_steal(true),
        )
        .unwrap();
        assert!(!sim.work_steal(), "sim metering excludes stealing");
    }

    #[test]
    fn pipeline_fuse_resolves_and_reaches_rank_threads() {
        let off = Cluster::new(
            DistConfig::threads(2).with_pipeline_fuse(false),
        )
        .unwrap();
        assert!(!off.pipeline_fuse());
        let outs = off.run(|_| Ok(crate::exec::pipeline_fuse())).unwrap();
        assert_eq!(outs, vec![false, false]);
        let on = Cluster::new(
            DistConfig::threads(2).with_pipeline_fuse(true),
        )
        .unwrap();
        assert!(on.pipeline_fuse());
        let outs = on.run(|_| Ok(crate::exec::pipeline_fuse())).unwrap();
        assert_eq!(outs, vec![true, true]);
        // None resolves to the process default on every rank.
        let def = Cluster::new(DistConfig::threads(2)).unwrap();
        let outs = def.run(|_| Ok(crate::exec::pipeline_fuse())).unwrap();
        let d = crate::exec::default_pipeline_fuse();
        assert_eq!(outs, vec![d, d]);
    }

    #[test]
    fn ryf_encoding_resolves_and_reaches_rank_threads() {
        let off = Cluster::new(
            DistConfig::threads(2).with_ryf_encoding(false),
        )
        .unwrap();
        assert!(!off.ryf_encoding());
        let outs = off.run(|_| Ok(crate::exec::ryf_encoding())).unwrap();
        assert_eq!(outs, vec![false, false]);
        let on = Cluster::new(
            DistConfig::threads(2).with_ryf_encoding(true),
        )
        .unwrap();
        assert!(on.ryf_encoding());
        let outs = on.run(|_| Ok(crate::exec::ryf_encoding())).unwrap();
        assert_eq!(outs, vec![true, true]);
        // None resolves to the process default on every rank.
        let def = Cluster::new(DistConfig::threads(2)).unwrap();
        let outs = def.run(|_| Ok(crate::exec::ryf_encoding())).unwrap();
        let d = crate::exec::default_ryf_encoding();
        assert_eq!(outs, vec![d, d]);
    }

    #[test]
    fn scan_counters_drain_into_cluster_totals() {
        let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
        assert_eq!(cluster.scan_stats(), crate::exec::ScanCounters::new());
        cluster
            .run(|ctx| {
                crate::exec::note_scan(&crate::exec::ScanCounters {
                    groups_total: 10,
                    groups_skipped: ctx.rank as u64,
                    decoded_bytes: 100,
                    decoded_bytes_avoided: 7,
                    pruned_columns: 1,
                });
                Ok(())
            })
            .unwrap();
        let s = cluster.scan_stats();
        assert_eq!(s.groups_total, 30);
        assert_eq!(s.groups_skipped, 3, "rank-distinct shares summed");
        assert_eq!(s.decoded_bytes, 300);
        assert_eq!(s.decoded_bytes_avoided, 21);
        assert_eq!(s.pruned_columns, 3);
        // Additive across runs.
        cluster
            .run(|_| {
                crate::exec::note_scan(&crate::exec::ScanCounters {
                    groups_total: 1,
                    ..crate::exec::ScanCounters::new()
                });
                Ok(())
            })
            .unwrap();
        assert_eq!(cluster.scan_stats().groups_total, 33);
    }

    #[test]
    fn memory_budget_reaches_rank_threads() {
        let cfg = DistConfig::threads(2).with_memory_budget(1 << 20);
        let cluster = Cluster::new(cfg).unwrap();
        assert_eq!(cluster.memory_budget_bytes(), 1 << 20);
        let outs = cluster
            .run(|_| Ok(crate::exec::memory_budget_bytes()))
            .unwrap();
        assert_eq!(outs, vec![1 << 20, 1 << 20]);
        assert_eq!(cluster.spilled_bytes(), 0, "nothing spilled yet");
        assert_eq!(cluster.spilled_partitions(), 0);
        // 0 resolves to the process default on every rank.
        let cluster = Cluster::new(DistConfig::threads(2)).unwrap();
        let outs = cluster
            .run(|_| Ok(crate::exec::memory_budget_bytes()))
            .unwrap();
        let d = crate::exec::default_memory_budget_bytes();
        assert_eq!(outs, vec![d, d]);
    }

    #[test]
    fn steal_group_widens_split_width_on_serial_ranks() {
        // An intra_op_threads=1 rank in a 3-pool steal group splits
        // wide enough for the two sibling pools to claim a share.
        let linked = Cluster::new(
            DistConfig::threads(3)
                .with_intra_op_threads(1)
                .with_work_steal(true),
        )
        .unwrap();
        let outs = linked
            .run(|_| Ok(crate::exec::split_width(crate::exec::current())))
            .unwrap();
        assert_eq!(outs, vec![3, 3, 3]);
        // Isolated pools keep the serial width.
        let isolated = Cluster::new(
            DistConfig::threads(3)
                .with_intra_op_threads(1)
                .with_work_steal(false),
        )
        .unwrap();
        let outs = isolated
            .run(|_| Ok(crate::exec::split_width(crate::exec::current())))
            .unwrap();
        assert_eq!(outs, vec![1, 1, 1]);
    }

    #[test]
    fn skewed_ranks_steal_and_stay_bit_identical() {
        // Rank 0 gets 32× the rows of its siblings; after the siblings
        // drain their own queues their workers must pick up rank 0's
        // morsels, and the gathered results must match the isolated
        // scheduler exactly.
        let run_skew = |steal: bool| -> (Vec<Vec<usize>>, u64) {
            use std::sync::atomic::{AtomicUsize, Ordering};
            let cfg = DistConfig::threads(3)
                .with_intra_op_threads(2)
                .with_work_steal(steal);
            let cluster = Cluster::new(cfg).unwrap();
            // Two gates make the steals-happened assertion robust
            // rather than a scheduling race: every rank-0 morsel
            // first waits for both siblings to check in (each does so
            // before submitting its own job), and — with stealing on
            // — rank 0's two *first-claimed* morsels then hold their
            // workers until a steal has actually been observed, so
            // rank 0's queue stays open (62 unclaimed tasks) until a
            // thief gets scheduled. The hold is bounded, so a genuine
            // stealing bug fails the assertion below instead of
            // hanging the test.
            let ready = AtomicUsize::new(0);
            let cluster_ref = &cluster;
            let outs = cluster_ref
                .run(|ctx| {
                    let rank = ctx.rank;
                    if rank != 0 {
                        ready.fetch_add(1, Ordering::SeqCst);
                    }
                    // Siblings get two morsels — enough to spawn their
                    // workers — while rank 0 queues 64.
                    let rows = if rank == 0 { 1 << 22 } else { 1 << 17 };
                    let exec = crate::exec::current();
                    Ok(crate::exec::for_each_morsel(rows, exec, |m| {
                        if rank == 0 {
                            while ready.load(Ordering::SeqCst) < 2 {
                                std::thread::yield_now();
                            }
                            if steal && m.index < 2 {
                                let mut spins = 0u32;
                                while cluster_ref.stolen_tasks() == 0
                                    && spins < 5_000_000
                                {
                                    std::thread::yield_now();
                                    spins += 1;
                                }
                            }
                        }
                        m.range().map(|i| i.wrapping_mul(31)).sum::<usize>()
                    }))
                })
                .unwrap();
            (outs, cluster.stolen_tasks())
        };
        let (outs_on, stolen_on) = run_skew(true);
        let (outs_off, stolen_off) = run_skew(false);
        assert_eq!(outs_on, outs_off, "stealing changed results");
        assert_eq!(stolen_off, 0, "isolated pools must not steal");
        assert!(
            stolen_on > 0,
            "skewed partition produced no steals (32× skew, 3 ranks)"
        );
    }

    #[test]
    fn rank_panic_maps_to_error_through_pool() {
        // A panic inside a pooled morsel task resurfaces on the rank
        // thread and is mapped to a job error — not a process abort.
        let cfg = DistConfig::threads(2).with_intra_op_threads(2);
        let cluster = Cluster::new(cfg).unwrap();
        let r: Result<Vec<usize>> = cluster.run(|ctx| {
            let rank = ctx.rank;
            let exec = crate::exec::current();
            let sums =
                crate::exec::for_each_morsel(1 << 18, exec, |m| {
                    if rank == 1 && m.index == 2 {
                        panic!("poisoned morsel");
                    }
                    m.len()
                });
            Ok(sums.len())
        });
        let e = r.unwrap_err();
        let info = e.abort_info().expect("panic joins the fault domain");
        assert_eq!(info.rank, 1, "the panicking rank is attributed");
        assert!(info.source.to_string().contains("poisoned morsel"));
        // The failure poisons the cluster: runs fail fast with the
        // same fault until it is cleared.
        let fault = cluster.fault().expect("cluster poisoned");
        assert_eq!(fault.rank, 1);
        let again: Result<Vec<()>> = cluster.run(|_| Ok(()));
        assert!(again.is_err(), "poisoned cluster must fail fast");
        assert_eq!(cluster.aborted_collectives(), 1);
        cluster.clear_fault();
        assert!(cluster.fault().is_none());
        // The cluster (and its pools) remain serviceable afterwards.
        let ok = cluster
            .run(|_| {
                let exec = crate::exec::current();
                Ok(crate::exec::for_each_morsel(1 << 18, exec, |m| m.len())
                    .len())
            })
            .unwrap();
        assert_eq!(ok.len(), 2);
    }
}
