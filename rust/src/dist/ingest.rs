//! Distributed CSV ingest: every rank materialises its **block of
//! records** from one shared CSV file. Two schemes share the entry
//! point [`read_csv_partition`]:
//!
//! * **Single-pass byte-range speculation** (the default,
//!   [`IngestMode::SinglePass`]) — each rank reads only `file_len /
//!   world` bytes, **once**: it scans its range through the boundary
//!   DFA under *all three* possible entry states (it cannot know which
//!   state the previous rank's bytes leave it in), then the ranks
//!   exchange tiny per-range summaries (exit state per hypothesis,
//!   boundary-newline count / first / last, raw newline count) over
//!   the fabric. A prefix pass over the summaries — the same fix-up
//!   the intra-rank speculative scan uses, lifted to rank granularity
//!   — tells every rank its true entry state, so each rank disowns its
//!   leading partial record to the left neighbour that owns the
//!   record's start byte (a second, targeted exchange carries those
//!   fragments), parses exactly the records that **start** in its
//!   range, and a final [`super::rebalance`] restores the rank-major
//!   block layout — elided entirely when the record counts show byte
//!   ownership already *is* the block partition (uniform row lengths),
//!   so such files move zero rows. No byte of the file is read twice
//!   by any rank:
//!   across the cluster the file is read exactly once (asserted
//!   through [`IngestStats`] in the test suite).
//!
//! * **Two-pass count-then-parse** ([`IngestMode::TwoPass`], the
//!   fallback and bit-identity oracle) — a boundary-scan-only pass
//!   counts the data records ([`crate::io::csv::count_csv_records`]),
//!   giving every rank the same block partition, then a parse pass
//!   streams the file again, materialising only this rank's block and
//!   **stopping at the block's end** rather than scanning to EOF.
//!   Needs no coordination, but the count pass alone reads `world ×
//!   file` bytes per cluster and the parse pass adds roughly
//!   `(world + 1) / 2 × file` more (rank `r` reads up to the end of
//!   block `r`).
//!
//! Both schemes produce **bit-identical per-rank tables** — schema
//! inference included, because the single-pass sample exchange ships
//! the raw text of exactly the records whole-file inference would
//! sample — so the toggle (`[exec] ingest_single_pass`,
//! `--ingest-single-pass`, `INGEST_SINGLE_PASS`,
//! `DistConfig::with_ingest_single_pass`) never changes results, only
//! I/O cost. See `docs/INGEST.md` for the full protocol walk-through.

use std::io::{Read, Seek, SeekFrom};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use super::RankCtx;
use crate::error::{Result, RylonError};
use crate::exec;
use crate::io::csv::{
    self, count_csv_records, CsvOptions, ScanState,
};
use crate::net::OutBufs;
use crate::table::Table;

/// The rank-major block `(offset, len)` of `n` records for `rank` of
/// `world` — base rows each, one extra for the first `n % world` ranks
/// (the same layout the integration tests slice by hand). Also used to
/// split a file's **bytes** across ranks in the single-pass scheme.
pub(crate) fn block_range(n: usize, rank: usize, world: usize) -> (usize, usize) {
    let base = n / world;
    let extra = n % world;
    let len = base + usize::from(rank < extra);
    let off = base * rank + rank.min(extra);
    (off, len)
}

/// Which distributed-ingest scheme [`read_csv_partition_with`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IngestMode {
    /// Single-pass byte-range speculation: each byte of the file is
    /// read exactly once across the cluster (see the module docs).
    SinglePass,
    /// Count-then-parse: two streaming passes over the whole file per
    /// rank. The coordination-free fallback and bit-identity oracle.
    TwoPass,
}

/// Byte-level I/O accounting for distributed ingest. Share one
/// instance across the rank closures of a job to observe the
/// cluster-wide read volume — the single-pass guarantee ("each byte
/// read exactly once") is asserted against exactly this counter.
#[derive(Debug, Default)]
pub struct IngestStats {
    bytes_read: AtomicU64,
    rows_moved: AtomicU64,
}

impl IngestStats {
    /// Fresh zeroed counters.
    pub fn new() -> IngestStats {
        IngestStats::default()
    }

    /// Total bytes read from source files by every ingest call handed
    /// this instance.
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.load(Ordering::Relaxed)
    }

    /// Data rows the single-pass scheme's post-parse rebalance shipped
    /// to a different rank, summed across ranks. `0` when byte
    /// ownership already matched the rank-major block partition (the
    /// uniform-row-length case) — the rebalance exchange is then elided
    /// entirely.
    pub fn rows_moved(&self) -> u64 {
        self.rows_moved.load(Ordering::Relaxed)
    }

    fn add(&self, n: u64) {
        self.bytes_read.fetch_add(n, Ordering::Relaxed);
    }

    fn add_moved(&self, n: u64) {
        self.rows_moved.fetch_add(n, Ordering::Relaxed);
    }
}

/// `Read` adapter that feeds [`IngestStats`] (when present).
struct CountingReader<'a, R> {
    inner: R,
    stats: Option<&'a IngestStats>,
}

impl<R: Read> Read for CountingReader<'_, R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        if let Some(s) = self.stats {
            s.add(n as u64);
        }
        Ok(n)
    }
}

/// Stream this rank's block of a CSV file into a table, using the
/// scheme selected by the calling thread's `[exec] ingest_single_pass`
/// knob (single-pass byte-range speculation by default; non-ASCII
/// delimiters always take the two-pass path, whose whole-buffer
/// fallback handles them). The per-rank tables concatenate (in rank
/// order) to exactly the whole-file [`crate::io::csv::read_csv`]
/// result, whichever scheme runs.
pub fn read_csv_partition(
    ctx: &mut RankCtx,
    path: impl AsRef<Path>,
    opts: &CsvOptions,
) -> Result<Table> {
    let mode = if exec::ingest_single_pass() && opts.delimiter.is_ascii() {
        IngestMode::SinglePass
    } else {
        IngestMode::TwoPass
    };
    read_csv_partition_with(ctx, path, opts, mode, None)
}

/// [`read_csv_partition`] with an explicit scheme and optional byte
/// accounting — the instrumented entry point tests and benches use to
/// pin the two schemes against each other.
pub fn read_csv_partition_with(
    ctx: &mut RankCtx,
    path: impl AsRef<Path>,
    opts: &CsvOptions,
    mode: IngestMode,
    stats: Option<&IngestStats>,
) -> Result<Table> {
    let path = path.as_ref();
    match mode {
        IngestMode::SinglePass if opts.delimiter.is_ascii() => {
            single_pass(ctx, path, opts, stats)
        }
        _ => two_pass(ctx, path, opts, stats),
    }
}

/// The two-pass fallback: count records (pass 1, whole file), then
/// stream-parse only this rank's block (pass 2, stopping at the
/// block's end), both bounded-memory through the chunked sink. No
/// collectives — every rank derives the same block partition from the
/// same count.
fn two_pass(
    ctx: &RankCtx,
    path: &Path,
    opts: &CsvOptions,
    stats: Option<&IngestStats>,
) -> Result<Table> {
    let counter = CountingReader {
        inner: std::fs::File::open(path)?,
        stats,
    };
    let total = count_csv_records(counter, opts)?;
    let (off, len) = block_range(total, ctx.rank, ctx.size);
    let parser = CountingReader {
        inner: std::fs::File::open(path)?,
        stats,
    };
    let mut parts: Vec<Table> = Vec::new();
    let schema =
        csv::read_csv_records_chunked(parser, opts, off..off + len, |t| {
            parts.push(t);
            Ok(())
        })?;
    if parts.is_empty() {
        return Ok(Table::empty(schema));
    }
    Table::concat_all(&schema, &parts)
}

// ---------------------------------------------------------------------
// Single-pass byte-range speculation
// ---------------------------------------------------------------------

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn get_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let s = buf
        .get(*pos..*pos + 8)
        .ok_or_else(|| RylonError::comm("truncated ingest summary"))?;
    *pos += 8;
    Ok(u64::from_le_bytes(s.try_into().expect("8-byte slice")))
}

fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    let s = buf
        .get(*pos..*pos + 4)
        .ok_or_else(|| RylonError::comm("truncated ingest summary"))?;
    *pos += 4;
    Ok(u32::from_le_bytes(s.try_into().expect("4-byte slice")))
}

/// Rank-local result of the one read pass: the range's raw bytes plus
/// its three-way speculative scan.
struct RangeScan {
    /// Absolute file offset of `buf[0]`.
    start: u64,
    /// The rank's raw byte range (held until boundaries resolve — the
    /// price of reading each byte once; same order as the parsed rows).
    buf: Vec<u8>,
    /// Boundary-newline offsets (relative to `buf`) per entry
    /// hypothesis.
    nls: [Vec<usize>; 3],
    /// Exit state per entry hypothesis.
    exit: [ScanState; 3],
    /// Raw `\n` count in `buf` (hypothesis-independent; for absolute
    /// line numbers in error messages).
    raw_nls: u64,
}

/// Read this rank's byte range (exactly once) and scan it under all
/// three entry states. The scan runs morsel-parallel on the rank's
/// worker pool.
fn scan_rank_range(
    path: &Path,
    d: u8,
    rank: usize,
    world: usize,
    stats: Option<&IngestStats>,
) -> Result<RangeScan> {
    let file_len = std::fs::metadata(path)?.len() as usize;
    let (off, len) = block_range(file_len, rank, world);
    let mut f = std::fs::File::open(path)?;
    f.seek(SeekFrom::Start(off as u64))?;
    let mut reader = CountingReader {
        inner: f.take(len as u64),
        stats,
    };
    let mut buf = vec![0u8; len];
    let n = csv::read_full(&mut reader, &mut buf)?;
    if n != len {
        return Err(RylonError::parse(format!(
            "csv shrank while reading: rank {rank} got {n} of {len} bytes"
        )));
    }
    let (nls, exit) = if off == 0 {
        // A range starting at byte 0 enters the DFA in a statically
        // known state (field start — only this rank can hold byte 0),
        // so the 3-hypothesis scan would triple the DFA work for
        // nothing: run the known-entry scan into slot 0 and leave the
        // never-read other slots as identities. The identity exits
        // also keep empty ranges (0-byte file) threading correctly.
        let (nl0, exit0) =
            csv::scan_boundaries(&buf, d, ScanState::FieldStart);
        (
            [nl0, Vec::new(), Vec::new()],
            [exit0, ScanState::Unquoted, ScanState::Quoted],
        )
    } else {
        let summary = csv::scan_summary(&buf, d);
        (summary.nls, summary.exit)
    };
    let raw_nls = csv::count_newlines(&buf);
    Ok(RangeScan {
        start: off as u64,
        buf,
        nls,
        exit,
        raw_nls,
    })
}

/// The tiny per-range summary that crosses the fabric: everything the
/// prefix pass needs, nothing sized by the data.
struct RankSummary {
    start: u64,
    len: u64,
    raw_nls: u64,
    /// Exit state per entry hypothesis.
    exit: [ScanState; 3],
    /// Boundary-newline count per entry hypothesis.
    count: [u64; 3],
    /// Absolute offset of the first/last boundary newline per entry
    /// hypothesis (`u64::MAX` when there is none).
    first: [u64; 3],
    last: [u64; 3],
}

fn encode_summary(s: &RangeScan) -> Vec<u8> {
    let mut out = Vec::with_capacity(24 + 3 * 25);
    put_u64(&mut out, s.start);
    put_u64(&mut out, s.buf.len() as u64);
    put_u64(&mut out, s.raw_nls);
    for h in 0..3 {
        out.push(csv::hyp_index(s.exit[h]) as u8);
        put_u64(&mut out, s.nls[h].len() as u64);
        let first = s.nls[h]
            .first()
            .map(|&i| s.start + i as u64)
            .unwrap_or(u64::MAX);
        let last = s.nls[h]
            .last()
            .map(|&i| s.start + i as u64)
            .unwrap_or(u64::MAX);
        put_u64(&mut out, first);
        put_u64(&mut out, last);
    }
    out
}

fn decode_summary(buf: &[u8]) -> Result<RankSummary> {
    let mut pos = 0usize;
    let start = get_u64(buf, &mut pos)?;
    let len = get_u64(buf, &mut pos)?;
    let raw_nls = get_u64(buf, &mut pos)?;
    let mut exit = [ScanState::FieldStart; 3];
    let mut count = [0u64; 3];
    let mut first = [u64::MAX; 3];
    let mut last = [u64::MAX; 3];
    for h in 0..3 {
        let tag = *buf
            .get(pos)
            .ok_or_else(|| RylonError::comm("truncated ingest summary"))?;
        pos += 1;
        exit[h] = csv::state_from_index(tag).ok_or_else(|| {
            RylonError::comm("bad scan state in ingest summary")
        })?;
        count[h] = get_u64(buf, &mut pos)?;
        first[h] = get_u64(buf, &mut pos)?;
        last[h] = get_u64(buf, &mut pos)?;
    }
    Ok(RankSummary {
        start,
        len,
        raw_nls,
        exit,
        count,
        first,
        last,
    })
}

/// The prefix pass over the allgathered summaries — pure and
/// deterministic, so every rank derives the identical picture.
struct Resolved {
    /// True DFA entry state per rank.
    entry: Vec<ScanState>,
    /// Offset (relative to the rank's range) where the records it owns
    /// begin; everything before it is the leading fragment of a record
    /// owned further left.
    owned_from: Vec<usize>,
    /// Destination rank of each rank's leading fragment (`None` when a
    /// record starts exactly at the rank's range start, or the rank
    /// has no bytes).
    frag_owner: Vec<Option<usize>>,
    /// Raw `\n` count in the file before each rank's range.
    raw_before: Vec<u64>,
}

fn resolve(summaries: &[RankSummary]) -> Resolved {
    let world = summaries.len();
    let mut entry = Vec::with_capacity(world);
    let mut owned_from = Vec::with_capacity(world);
    let mut frag_owner = vec![None; world];
    let mut raw_before = Vec::with_capacity(world);
    let mut state = ScanState::FieldStart;
    // Largest true boundary newline seen so far (absolute offset).
    let mut prev_nl: Option<u64> = None;
    let mut raw_acc = 0u64;
    for (r, s) in summaries.iter().enumerate() {
        entry.push(state);
        raw_before.push(raw_acc);
        raw_acc += s.raw_nls;
        let h = csv::hyp_index(state);
        let starts_record = s.start == 0 || prev_nl == Some(s.start - 1);
        if s.len == 0 || starts_record {
            owned_from.push(0);
        } else {
            // The leading bytes continue a record that started in the
            // range containing the byte after the previous true
            // boundary — disown them to that rank.
            let of = if s.count[h] > 0 {
                (s.first[h] - s.start) as usize + 1
            } else {
                s.len as usize
            };
            owned_from.push(of);
            let record_start = prev_nl.map(|n| n + 1).unwrap_or(0);
            frag_owner[r] = Some(rank_of_byte(summaries, record_start));
        }
        if s.count[h] > 0 {
            prev_nl = Some(s.last[h]);
        }
        state = s.exit[h];
    }
    Resolved {
        entry,
        owned_from,
        frag_owner,
        raw_before,
    }
}

/// The rank whose (non-empty) byte range contains `byte`.
fn rank_of_byte(summaries: &[RankSummary], byte: u64) -> usize {
    for (r, s) in summaries.iter().enumerate() {
        if s.len > 0 && byte >= s.start && byte < s.start + s.len {
            return r;
        }
    }
    0
}

/// Rank-local state after fragments arrived: the contiguous text of
/// every record this rank owns, with record ranges already cut.
struct Assembled {
    text: String,
    /// Record byte ranges within `text` (empty lines skipped, trailing
    /// `\r` stripped — [`csv::push_record_range`] semantics).
    ranges: Vec<(usize, usize)>,
    /// Absolute file offset of `text[0]`.
    byte_base: u64,
    /// Raw `\n` count in the file before `text[0]`.
    line_base: u64,
}

/// Glue the rank's owned region to the fragments received from the
/// right, validate UTF-8, and cut record ranges from the resolved
/// boundary list.
fn assemble(
    mut scan: RangeScan,
    resolved: &Resolved,
    summaries: &[RankSummary],
    incoming: &[Vec<u8>],
    rank: usize,
) -> Result<Assembled> {
    let owned_from = resolved.owned_from[rank];
    let line_base = resolved.raw_before[rank]
        + csv::count_newlines(&scan.buf[..owned_from]);
    let byte_base = scan.start + owned_from as u64;

    // My own true boundaries, shifted into owned-text coordinates.
    let h = csv::hyp_index(resolved.entry[rank]);
    let mut bounds: Vec<usize> = scan.nls[h]
        .iter()
        .filter(|&&i| i >= owned_from)
        .map(|&i| i - owned_from)
        .collect();

    let mut text_bytes = scan.buf.split_off(owned_from);
    // Fragments arrive from consecutive right-hand ranks; the chain is
    // terminated (ends with a true boundary newline) iff the last
    // sender saw a true boundary in its own range — a trailing `\n`
    // byte alone proves nothing (it could sit inside a quoted field).
    let mut terminated = false;
    for q in rank + 1..summaries.len() {
        if resolved.frag_owner[q] == Some(rank) {
            text_bytes.extend_from_slice(&incoming[q]);
            let hq = csv::hyp_index(resolved.entry[q]);
            terminated = summaries[q].count[hq] > 0;
        }
    }
    if terminated {
        bounds.push(text_bytes.len() - 1);
    }

    let text = String::from_utf8(text_bytes).map_err(|_| {
        RylonError::parse(format!(
            "csv: invalid utf-8 near byte {byte_base}"
        ))
    })?;
    let bytes = text.as_bytes();
    let mut ranges = Vec::new();
    let mut start = 0usize;
    for &nl in &bounds {
        csv::push_record_range(&mut ranges, bytes, start, nl);
        start = nl + 1;
    }
    csv::push_record_range(&mut ranges, bytes, start, bytes.len());
    Ok(Assembled {
        text,
        ranges,
        byte_base,
        line_base,
    })
}

/// Encode this rank's record count plus the raw text (and absolute
/// byte/line position) of its first `min(count, needed)` records — the
/// sample prefix every rank needs to resolve the header and infer the
/// schema exactly like a whole-file read.
fn encode_block_summary(a: &Assembled, needed: usize) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, a.ranges.len() as u64);
    let n = a.ranges.len().min(needed);
    put_u32(&mut out, n as u32);
    for &(s, e) in a.ranges.iter().take(n) {
        let byte = a.byte_base + s as u64;
        let line =
            a.line_base + csv::count_newlines(&a.text.as_bytes()[..s]) + 1;
        put_u64(&mut out, byte);
        put_u64(&mut out, line);
        put_u32(&mut out, (e - s) as u32);
        out.extend_from_slice(&a.text.as_bytes()[s..e]);
    }
    out
}

/// One sampled record: raw text plus the absolute (byte, 1-based line)
/// of its start, so split errors report whole-file positions.
struct Sample {
    text: String,
    byte: u64,
    line: u64,
}

fn decode_block_summary(
    buf: &[u8],
) -> Result<(u64, Vec<Sample>)> {
    let mut pos = 0usize;
    let count = get_u64(buf, &mut pos)?;
    let n = get_u32(buf, &mut pos)? as usize;
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let byte = get_u64(buf, &mut pos)?;
        let line = get_u64(buf, &mut pos)?;
        let len = get_u32(buf, &mut pos)? as usize;
        let raw = buf
            .get(pos..pos + len)
            .ok_or_else(|| RylonError::comm("truncated ingest sample"))?;
        pos += len;
        let text = String::from_utf8(raw.to_vec()).map_err(|_| {
            RylonError::comm("non-utf8 ingest sample")
        })?;
        samples.push(Sample { text, byte, line });
    }
    Ok((count, samples))
}

/// The single-pass scheme (see the module docs for the protocol). All
/// fabric steps run on every rank in lockstep; fallible rank-local
/// stages are wrapped in [`RankCtx::allgather_checked`] — the
/// fabric-wide verdict layer this ingest protocol pioneered — so a
/// local failure aborts the job symmetrically instead of stranding
/// peers in a later collective.
fn single_pass(
    ctx: &mut RankCtx,
    path: &Path,
    opts: &CsvOptions,
    stats: Option<&IngestStats>,
) -> Result<Table> {
    let world = ctx.size;
    let d = opts.delimiter as u8;

    // 1. Read my byte range (the only time any of its bytes are read)
    //    and scan it under all three entry states.
    let scan = scan_rank_range(path, d, ctx.rank, world, stats);

    // 2. Summary exchange + prefix pass: every rank learns every
    //    range's true entry state and boundary picture.
    ctx.set_op("ingest.summary");
    let payloads =
        ctx.allgather_checked(scan.as_ref().map(encode_summary))?;
    let scan = scan.expect("checked exchange surfaced scan errors");
    let summaries = payloads
        .iter()
        .map(|b| decode_summary(b))
        .collect::<Result<Vec<RankSummary>>>()?;
    // The ranges must tile the file each rank observed: if the file
    // grew or shrank between the per-rank `metadata` calls, ranks hold
    // inconsistent partitions — abort cleanly (identically on every
    // rank, since every rank checks the same summaries) rather than
    // splice a corrupt prefix chain.
    let mut expect_start = 0u64;
    for (r, s) in summaries.iter().enumerate() {
        if s.start != expect_start {
            return Err(RylonError::parse(format!(
                "csv changed size during distributed ingest: rank {r}'s \
                 byte range starts at {} but the previous ranges end at \
                 {expect_start}",
                s.start
            )));
        }
        expect_start += s.len;
    }
    let resolved = resolve(&summaries);

    // 3. Fragment exchange: disown my leading partial record to the
    //    rank owning its start; collect the continuations of my own
    //    trailing record from the right.
    let mut out: OutBufs = vec![Vec::new(); world];
    if let Some(owner) = resolved.frag_owner[ctx.rank] {
        out[owner] = scan.buf[..resolved.owned_from[ctx.rank]].to_vec();
    }
    ctx.set_op("ingest.fragments");
    let incoming = ctx.exchange(out)?;

    // 4. Assemble my owned records (fallible: UTF-8), then swap record
    //    counts + the schema-sample prefix.
    let assembled =
        assemble(scan, &resolved, &summaries, &incoming, ctx.rank);
    let header_rows = opts.has_header as usize;
    let needed = header_rows
        + if opts.schema.is_none() {
            opts.infer_rows
        } else {
            0
        };
    ctx.set_op("ingest.samples");
    let payloads = ctx.allgather_checked(
        assembled.as_ref().map(|a| encode_block_summary(a, needed)),
    )?;
    let assembled = assembled.expect("checked exchange surfaced errors");
    let mut counts = vec![0u64; world];
    let mut samples: Vec<Sample> = Vec::new();
    for (r, b) in payloads.iter().enumerate() {
        let (count, ranks_samples) = decode_block_summary(b)?;
        counts[r] = count;
        samples.extend(ranks_samples);
    }
    samples.truncate(needed);

    // 5. Resolve header + schema from the global sample prefix —
    //    identical on every rank, and identical to what a whole-file
    //    read would split and infer (same records, same order, same
    //    error positions).
    let mut header: Option<Vec<String>> = None;
    if opts.has_header {
        if let Some(s) = samples.first() {
            header = Some(csv::split_record(&s.text, opts.delimiter, || {
                (s.byte, s.line)
            })?);
        }
    }
    let schema = match &opts.schema {
        Some(s) => s.clone(),
        None => {
            let mut rows = Vec::with_capacity(
                samples.len().saturating_sub(header_rows),
            );
            for s in samples.iter().skip(header_rows) {
                rows.push(csv::split_record(&s.text, opts.delimiter, || {
                    (s.byte, s.line)
                })?);
            }
            csv::infer_schema(header.as_ref(), &rows)?
        }
    };

    // 6. Parse my owned records (morsel-parallel), dropping the header
    //    if ordinal 0 is mine.
    let my_ordinal: u64 = counts[..ctx.rank].iter().sum();
    let owns_header =
        opts.has_header && my_ordinal == 0 && !assembled.ranges.is_empty();
    let data_ranges = &assembled.ranges[owns_header as usize..];
    let first_record = my_ordinal as usize + owns_header as usize;
    let parsed = csv::parse_ranges_parallel(
        &assembled.text,
        data_ranges,
        &schema,
        first_record,
        opts.delimiter,
        assembled.byte_base,
        assembled.line_base,
    );

    // 7. Status barrier (a ragged record on one rank must not strand
    //    the others in the rebalance), then restore the rank-major
    //    block layout — after which the per-rank tables are
    //    bit-identical to the two-pass partition. When byte ownership
    //    already matches the block partition (uniform row lengths —
    //    every rank parsed exactly its block), the rebalance exchange
    //    is elided: every rank derives the same verdict from the same
    //    `counts`, so all ranks skip the collective together.
    ctx.set_op("ingest.barrier");
    ctx.allgather_checked(parsed.as_ref().map(|_| Vec::new()))?;
    let table = parsed.expect("checked exchange surfaced parse errors");
    // Per-rank *data* rows: the header record, owned by the first
    // non-empty rank, parses to no row.
    let mut data_counts = counts;
    if opts.has_header {
        if let Some(r0) = data_counts.iter().position(|&c| c > 0) {
            data_counts[r0] -= 1;
        }
    }
    let total: u64 = data_counts.iter().sum();
    let aligned = (0..world).all(|r| {
        data_counts[r] == block_range(total as usize, r, world).1 as u64
    });
    if aligned {
        // Byte ownership already is the rank-major block partition
        // (uniform row lengths): zero rows would move, so skip the
        // rebalance exchange outright. Every rank derives the same
        // verdict from the same counts, so all ranks skip together.
        return Ok(table);
    }
    if let Some(st) = stats {
        // Rows leaving this rank: its parsed span minus the overlap
        // with its target block.
        let my_start: u64 = data_counts[..ctx.rank].iter().sum();
        let (t_off, t_len) = block_range(total as usize, ctx.rank, world);
        let lo = my_start.max(t_off as u64);
        let hi = (my_start + data_counts[ctx.rank])
            .min(t_off as u64 + t_len as u64);
        st.add_moved(data_counts[ctx.rank] - hi.saturating_sub(lo));
    }
    super::rebalance(ctx, &table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_ranges_cover_exactly() {
        for (n, world) in [(0usize, 3usize), (7, 3), (9, 3), (100, 7)] {
            let mut next = 0usize;
            for r in 0..world {
                let (off, len) = block_range(n, r, world);
                assert_eq!(off, next, "n={n} world={world} rank={r}");
                next += len;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn error_wire_roundtrip_preserves_message() {
        // The shared fault codec the checked collectives ride on.
        for e in [
            RylonError::parse("bad record"),
            RylonError::invalid("nope"),
            RylonError::comm("closed"),
            RylonError::from(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                "gone",
            )),
        ] {
            let msg = e.to_string();
            let (tag, m) = e.to_wire();
            assert_eq!(RylonError::from_wire(tag, m).to_string(), msg);
        }
    }
}
