//! Distributed streaming CSV ingest: every rank streams its **block of
//! records** out of a shared CSV file with the bounded-memory reader
//! ([`crate::io::csv::read_csv_records`]), so a world of ranks holds
//! O(world × chunk + file rows) instead of world × file bytes — the
//! chunked parallel ingest both Cylon papers treat as a first-class
//! scaling lever.
//!
//! Two streaming passes per rank, no coordination required:
//!
//! 1. a boundary-scan-only pass counts the data records
//!    ([`crate::io::csv::count_csv_records`]), giving every rank the
//!    same total and therefore the same block partition;
//! 2. a parse pass materialises only this rank's records (the scan
//!    still covers the whole file — record boundaries cannot be found
//!    without it — but foreign records are skipped unparsed and their
//!    raw text is dropped chunk by chunk).
//!
//! The block partition matches `Table::slice`'s rank-major layout, so
//! concatenating the per-rank tables in rank order reproduces the
//! whole-file read bit for bit (schema inference included: it always
//! samples the file's first records, whichever rank reads them).

use std::path::Path;

use super::RankCtx;
use crate::error::Result;
use crate::io::csv::{count_csv_records, read_csv_records, CsvOptions};
use crate::table::Table;

/// The rank-major block `(offset, len)` of `n` records for `rank` of
/// `world` — base rows each, one extra for the first `n % world` ranks
/// (the same layout the integration tests slice by hand).
pub(crate) fn block_range(n: usize, rank: usize, world: usize) -> (usize, usize) {
    let base = n / world;
    let extra = n % world;
    let len = base + usize::from(rank < extra);
    let off = base * rank + rank.min(extra);
    (off, len)
}

/// Stream this rank's block of a CSV file into a table. Rank memory is
/// bounded by the ingest chunk size plus the rank's own rows; the
/// per-rank tables concatenate (in rank order) to exactly the
/// whole-file [`crate::io::csv::read_csv`] result.
pub fn read_csv_partition(
    ctx: &RankCtx,
    path: impl AsRef<Path>,
    opts: &CsvOptions,
) -> Result<Table> {
    let path = path.as_ref();
    let total = count_csv_records(std::fs::File::open(path)?, opts)?;
    let (off, len) = block_range(total, ctx.rank, ctx.size);
    read_csv_records(std::fs::File::open(path)?, opts, off..off + len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_ranges_cover_exactly() {
        for (n, world) in [(0usize, 3usize), (7, 3), (9, 3), (100, 7)] {
            let mut next = 0usize;
            for r in 0..world {
                let (off, len) = block_range(n, r, world);
                assert_eq!(off, next, "n={n} world={world} rank={r}");
                next += len;
            }
            assert_eq!(next, n);
        }
    }
}
