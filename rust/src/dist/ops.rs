//! The distributed operators: shuffle + local kernel, per the paper's
//! §III-C recipe. Every rank calls these SPMD with its own partition;
//! each function performs the same sequence of collectives on every
//! rank (validation failures happen identically everywhere, before any
//! exchange, so jobs abort without deadlock).
//!
//! Each entry point labels the rank context ([`RankCtx::set_op`]) for
//! fault attribution; nested primitives (`shuffle`, `rebalance`)
//! re-label on entry, so an abort reports the innermost collective
//! that was actually running.

use std::cmp::Ordering;

use crate::column::Column;
use crate::dist::partition::{shuffle, shuffle_all_columns};
use crate::dist::RankCtx;
use crate::error::Result;
use crate::net::collectives::allgather;
use crate::net::wire::{deserialize_table, serialize_table, serialize_table_into};
use crate::net::OutBufs;
use crate::ops;
use crate::ops::groupby::{Agg, GroupByOptions};
use crate::ops::join::JoinOptions;
use crate::ops::orderby::{SortKey, SortOrder};
use crate::table::Table;
use crate::types::{DataType, Field, Schema};

/// Distributed join: co-partition both sides by key hash, then join
/// locally (all four join types compose — null keys co-locate on one
/// rank and null-extend there exactly once).
pub fn dist_join(
    ctx: &mut RankCtx,
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
) -> Result<Table> {
    ctx.set_op("dist_join");
    let ls = shuffle(ctx, left, &opts.left_on)?;
    let rs = shuffle(ctx, right, &opts.right_on)?;
    ops::join(&ls, &rs, opts)
}

/// Distributed group-by: shuffle rows by key hash so each group lands
/// whole on one rank, then aggregate locally.
pub fn dist_groupby(
    ctx: &mut RankCtx,
    table: &Table,
    opts: &GroupByOptions,
) -> Result<Table> {
    ctx.set_op("dist_groupby");
    let shuffled = shuffle(ctx, table, &opts.keys)?;
    ops::groupby(&shuffled, opts)
}

/// How one user-facing aggregate decomposes into algebraic partials for
/// the pre-aggregation strategy.
enum MergeSpec {
    /// One partial column, merged with the given aggregate.
    Direct { merged: String },
    /// Mean = merged sum / merged count (null when the count is 0).
    MeanOf { sum: String, cnt: String },
}

/// Distributed group-by via local pre-aggregation: aggregate locally
/// first (shrinking rows to distinct local keys), shuffle the partials,
/// and merge. Algebraically exact for sum/count/min/max; mean is
/// decomposed into sum+count partials, so it is exact too (up to f64
/// fold order across ranks).
pub fn dist_groupby_preagg(
    ctx: &mut RankCtx,
    table: &Table,
    opts: &GroupByOptions,
) -> Result<Table> {
    use crate::compute::aggregate::AggKind;

    ctx.set_op("dist_groupby_preagg");

    // 1. Decompose into partial aggregates with reserved names.
    let mut partial_aggs: Vec<Agg> = Vec::new();
    let mut specs: Vec<MergeSpec> = Vec::new();
    for (i, a) in opts.aggs.iter().enumerate() {
        match a.kind {
            AggKind::Mean => {
                let sum_name = format!("__p{i}_msum");
                let cnt_name = format!("__p{i}_mcnt");
                partial_aggs
                    .push(Agg::new(AggKind::Sum, &a.column).named(&sum_name));
                partial_aggs.push(
                    Agg::new(AggKind::Count, &a.column).named(&cnt_name),
                );
                specs.push(MergeSpec::MeanOf {
                    sum: sum_name,
                    cnt: cnt_name,
                });
            }
            kind => {
                let name = format!("__p{i}_{}", kind.name());
                partial_aggs.push(Agg::new(kind, &a.column).named(&name));
                specs.push(MergeSpec::Direct { merged: name });
            }
        }
    }
    let local = ops::groupby(
        table,
        &GroupByOptions {
            keys: opts.keys.clone(),
            aggs: partial_aggs.clone(),
        },
    )?;

    // 2. Shuffle the (small) partials by key.
    let shuffled = shuffle(ctx, &local, &opts.keys)?;

    // 3. Merge partials: sums and counts add, min/max fold.
    let merge_aggs: Vec<Agg> = partial_aggs
        .iter()
        .map(|p| {
            let merge_kind = match p.kind {
                AggKind::Sum | AggKind::Count => AggKind::Sum,
                AggKind::Min => AggKind::Min,
                AggKind::Max => AggKind::Max,
                AggKind::Mean => unreachable!("mean decomposed above"),
            };
            Agg::new(merge_kind, &p.name).named(&p.name)
        })
        .collect();
    let merged = ops::groupby(
        &shuffled,
        &GroupByOptions {
            keys: opts.keys.clone(),
            aggs: merge_aggs,
        },
    )?;

    // 4. Re-assemble the user-facing schema.
    let mut fields: Vec<Field> = Vec::new();
    let mut cols: Vec<Column> = Vec::new();
    for k in &opts.keys {
        let c = merged.column_by_name(k)?;
        fields.push(Field::new(k.clone(), c.dtype()));
        cols.push(c.clone());
    }
    for (a, spec) in opts.aggs.iter().zip(&specs) {
        match spec {
            MergeSpec::Direct { merged: name } => {
                let c = merged.column_by_name(name)?;
                fields.push(Field::new(a.name.clone(), c.dtype()));
                cols.push(c.clone());
            }
            MergeSpec::MeanOf { sum, cnt } => {
                let s = merged.column_by_name(sum)?;
                let c = merged.column_by_name(cnt)?;
                let vals: Vec<Option<f64>> = (0..merged.num_rows())
                    .map(|r| {
                        let n = c.value(r).as_i64().unwrap_or(0);
                        if n == 0 {
                            None
                        } else {
                            s.value(r).as_f64().map(|sv| sv / n as f64)
                        }
                    })
                    .collect();
                fields.push(Field::new(a.name.clone(), DataType::Float64));
                cols.push(Column::from_opt_f64(vals));
            }
        }
    }
    Table::try_new(Schema::new(fields), cols)
}

/// Distributed sample sort: local sort, regular-sample splitters agreed
/// through an allgather, range-partition, one exchange, local merge.
/// Afterwards rank r holds the r-th contiguous range of the global
/// order (rank-major concatenation is globally sorted).
pub fn dist_sort(
    ctx: &mut RankCtx,
    table: &Table,
    keys: &[SortKey],
) -> Result<Table> {
    let local = ops::orderby(table, keys)?;
    if ctx.size == 1 || keys.is_empty() {
        return Ok(local);
    }
    ctx.set_op("dist_sort");
    let key_names: Vec<&str> =
        keys.iter().map(|k| k.column.as_str()).collect();
    let desc: Vec<bool> = keys
        .iter()
        .map(|k| k.order == SortOrder::Descending)
        .collect();

    // Regular samples of the local sorted key columns.
    let keys_only = ops::project(&local, &key_names)?;
    let n = local.num_rows();
    let want = (ctx.size * 4).min(n);
    let sample_idx: Vec<usize> = (0..want).map(|k| k * n / want.max(1)).collect();
    let samples = keys_only.take(&sample_idx);

    // Agree on splitters: gather every rank's samples, sort, pick
    // size-1 regular positions.
    let all = allgather(ctx.fabric(), ctx.rank, serialize_table(&samples))?;
    let mut sample_parts = Vec::with_capacity(all.len());
    for buf in all {
        sample_parts.push(deserialize_table(&buf)?);
    }
    let gathered = Table::concat_all(samples.schema(), &sample_parts)?;
    let sorted_samples = ops::orderby(&gathered, keys)?;
    let m = sorted_samples.num_rows();
    let splitter_idx: Vec<usize> = (1..ctx.size)
        .map(|d| d * m / ctx.size)
        .filter(|&i| i < m)
        .collect();
    let splitters = sorted_samples.take(&splitter_idx);

    // Range-partition the locally sorted rows against the splitters.
    let local_keys: Result<Vec<&Column>> = key_names
        .iter()
        .map(|name| local.column_by_name(name))
        .collect();
    let local_keys = local_keys?;
    let spl_keys: Vec<&Column> = splitters.columns().collect();
    let cmp_row_to_splitter = |row: usize, s: usize| -> Ordering {
        for ((lc, sc), &d) in local_keys.iter().zip(&spl_keys).zip(&desc) {
            let ord = lc.cmp_rows(row, sc, s);
            let ord = if d { ord.reverse() } else { ord };
            if ord != Ordering::Equal {
                return ord;
            }
        }
        Ordering::Equal
    };
    let nspl = splitters.num_rows();
    let mut bounds: Vec<usize> = Vec::with_capacity(nspl);
    for s in 0..nspl {
        // First row not Less than splitter s (rows are sorted, and
        // splitters ascend, so the search can start at the last bound).
        let mut lo = bounds.last().copied().unwrap_or(0);
        let mut hi = n;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if cmp_row_to_splitter(mid, s) == Ordering::Less {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        bounds.push(lo);
    }
    let mut out: OutBufs = vec![Vec::new(); ctx.size];
    let mut start = 0usize;
    for (dst, buf) in out.iter_mut().enumerate() {
        let end = if dst < nspl { bounds[dst] } else { n };
        if end > start {
            serialize_table_into(&local.slice(start, end - start), buf);
        }
        start = end;
    }
    let incoming = ctx.fabric().exchange(ctx.rank, out)?;
    let mut parts = Vec::new();
    for buf in incoming {
        if !buf.is_empty() {
            parts.push(deserialize_table(&buf)?);
        }
    }
    let merged = Table::concat_all(local.schema(), &parts)?;
    ops::orderby(&merged, keys)
}

/// Distributed union: whole-row-hash shuffle co-locates equal rows,
/// then the local distinct-union runs per rank.
pub fn dist_union(ctx: &mut RankCtx, a: &Table, b: &Table) -> Result<Table> {
    ctx.set_op("dist_union");
    let sa = shuffle_all_columns(ctx, a)?;
    let sb = shuffle_all_columns(ctx, b)?;
    ops::union(&sa, &sb)
}

/// Distributed intersect (whole-row co-location, local intersect).
pub fn dist_intersect(
    ctx: &mut RankCtx,
    a: &Table,
    b: &Table,
) -> Result<Table> {
    ctx.set_op("dist_intersect");
    let sa = shuffle_all_columns(ctx, a)?;
    let sb = shuffle_all_columns(ctx, b)?;
    ops::intersect(&sa, &sb)
}

/// Distributed symmetric difference (whole-row co-location, local op).
pub fn dist_difference(
    ctx: &mut RankCtx,
    a: &Table,
    b: &Table,
) -> Result<Table> {
    ctx.set_op("dist_difference");
    let sa = shuffle_all_columns(ctx, a)?;
    let sb = shuffle_all_columns(ctx, b)?;
    ops::difference(&sa, &sb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Cluster, DistConfig};
    use crate::io::datagen::{gen_partition, DataGenSpec};
    use crate::ops::groupby::Agg;
    use crate::types::Value;

    fn block_slice(t: &Table, rank: usize, size: usize) -> Table {
        let n = t.num_rows();
        let base = n / size;
        let extra = n % size;
        let my = base + usize::from(rank < extra);
        let off = base * rank + rank.min(extra);
        t.slice(off, my)
    }

    #[test]
    fn dist_groupby_matches_local() {
        let whole = crate::io::datagen::gen_table(
            &DataGenSpec::paper_scaling(3000, 9),
        )
        .unwrap();
        let gopts = GroupByOptions::new(
            &["id"],
            vec![Agg::sum("d0"), Agg::count("d0"), Agg::mean("d1")],
        );
        let local = ops::groupby(&whole, &gopts).unwrap();

        let cluster = Cluster::new(DistConfig::threads(4)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let part = block_slice(&whole, ctx.rank, ctx.size);
                dist_groupby(ctx, &part, &gopts)
            })
            .unwrap();
        let merged = Table::concat_all(outs[0].schema(), &outs).unwrap();
        assert_eq!(merged.num_rows(), local.num_rows());
        let count = |t: &Table| -> i64 {
            let c = t.column_by_name("count_d0").unwrap();
            (0..t.num_rows())
                .map(|i| c.value(i).as_i64().unwrap())
                .sum()
        };
        assert_eq!(count(&merged), count(&local));
    }

    #[test]
    fn preagg_matches_shuffle_all_strategy() {
        let gopts = GroupByOptions::new(
            &["id"],
            vec![
                Agg::sum("d0"),
                Agg::count("d0"),
                Agg::min("d0"),
                Agg::max("d0"),
                Agg::mean("d0"),
            ],
        );
        let run = |preagg: bool| -> Vec<(i64, i64)> {
            let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
            let outs = cluster
                .run(|ctx| {
                    let part = gen_partition(
                        &DataGenSpec {
                            rows: 2000,
                            payload_cols: 1,
                            key_dist: crate::io::datagen::KeyDist::Uniform {
                                domain: 50,
                            },
                            seed: 4,
                        },
                        ctx.rank,
                        ctx.size,
                    )?;
                    if preagg {
                        dist_groupby_preagg(ctx, &part, &gopts)
                    } else {
                        dist_groupby(ctx, &part, &gopts)
                    }
                })
                .unwrap();
            let merged =
                Table::concat_all(outs[0].schema(), &outs).unwrap();
            let mut rows: Vec<(i64, i64)> = (0..merged.num_rows())
                .map(|i| {
                    (
                        merged.column(0).value(i).as_i64().unwrap(),
                        merged
                            .column_by_name("count_d0")
                            .unwrap()
                            .value(i)
                            .as_i64()
                            .unwrap(),
                    )
                })
                .collect();
            rows.sort();
            rows
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn preagg_schema_matches_user_aggs() {
        let gopts = GroupByOptions::new(
            &["id"],
            vec![Agg::mean("d0").named("avg0"), Agg::sum("d0")],
        );
        let cluster = Cluster::new(DistConfig::threads(2)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let part = gen_partition(
                    &DataGenSpec::paper_load(500, 8),
                    ctx.rank,
                    ctx.size,
                )?;
                dist_groupby_preagg(ctx, &part, &gopts)
            })
            .unwrap();
        let names: Vec<String> = outs[0]
            .schema()
            .fields()
            .iter()
            .map(|f| f.name.clone())
            .collect();
        assert_eq!(names, vec!["id", "avg0", "sum_d0"]);
        assert_eq!(outs[0].schema().field(1).dtype, DataType::Float64);
    }

    #[test]
    fn dist_sort_descending_global_order() {
        let whole = crate::io::datagen::gen_table(
            &DataGenSpec::paper_scaling(2500, 3),
        )
        .unwrap();
        let keys = vec![SortKey::desc("id")];
        let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let part = block_slice(&whole, ctx.rank, ctx.size);
                dist_sort(ctx, &part, &keys)
            })
            .unwrap();
        // Rank-major concatenation must be globally sorted descending.
        let merged = Table::concat_all(outs[0].schema(), &outs).unwrap();
        assert_eq!(merged.num_rows(), whole.num_rows());
        let ids = merged.column_by_name("id").unwrap();
        for i in 1..merged.num_rows() {
            assert!(
                ids.cmp_rows(i - 1, ids, i) != Ordering::Less,
                "row {i} out of order"
            );
        }
    }

    #[test]
    fn dist_set_ops_match_local() {
        let ta = Table::from_columns(vec![(
            "x",
            Column::from_i64((0..40).map(|i| i % 10).collect()),
        )])
        .unwrap();
        let tb = Table::from_columns(vec![(
            "x",
            Column::from_i64((5..25).map(|i| i % 15).collect()),
        )])
        .unwrap();
        let local_union = ops::union(&ta, &tb).unwrap().num_rows();
        let local_intersect = ops::intersect(&ta, &tb).unwrap().num_rows();
        let local_diff = ops::difference(&ta, &tb).unwrap().num_rows();

        let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let pa = block_slice(&ta, ctx.rank, ctx.size);
                let pb = block_slice(&tb, ctx.rank, ctx.size);
                let u = dist_union(ctx, &pa, &pb)?.num_rows();
                let i = dist_intersect(ctx, &pa, &pb)?.num_rows();
                let d = dist_difference(ctx, &pa, &pb)?.num_rows();
                Ok((u, i, d))
            })
            .unwrap();
        let sum3 = |f: fn(&(usize, usize, usize)) -> usize| -> usize {
            outs.iter().map(f).sum()
        };
        assert_eq!(sum3(|o| o.0), local_union);
        assert_eq!(sum3(|o| o.1), local_intersect);
        assert_eq!(sum3(|o| o.2), local_diff);
    }

    #[test]
    fn dist_join_outer_counts_match_local() {
        let whole_l = crate::io::datagen::gen_table(
            &DataGenSpec::paper_scaling(1200, 21),
        )
        .unwrap();
        let whole_r = crate::io::datagen::gen_table(
            &DataGenSpec::paper_scaling(1200, 22),
        )
        .unwrap();
        for jt in ["left", "right", "outer"] {
            let jty = crate::ops::join::JoinType::parse(jt).unwrap();
            let opts = JoinOptions::new(jty, &["id"], &["id"]);
            let expect = ops::join(&whole_l, &whole_r, &opts)
                .unwrap()
                .num_rows();
            let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
            let outs = cluster
                .run(|ctx| {
                    dist_join(
                        ctx,
                        &block_slice(&whole_l, ctx.rank, ctx.size),
                        &block_slice(&whole_r, ctx.rank, ctx.size),
                        &opts,
                    )
                })
                .unwrap();
            let got: usize = outs.iter().map(|t| t.num_rows()).sum();
            assert_eq!(got, expect, "{jt}");
        }
    }

    #[test]
    fn preagg_all_null_group_mean_is_null() {
        let gopts =
            GroupByOptions::new(&["k"], vec![Agg::mean("v")]);
        let cluster = Cluster::new(DistConfig::threads(2)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let t = Table::from_columns(vec![
                    (
                        "k",
                        Column::from_i64(vec![1, 2]),
                    ),
                    (
                        "v",
                        Column::from_opt_f64(vec![None, Some(3.0)]),
                    ),
                ])
                .unwrap();
                let part = block_slice(&t, ctx.rank, ctx.size);
                dist_groupby_preagg(ctx, &part, &gopts)
            })
            .unwrap();
        let merged = Table::concat_all(outs[0].schema(), &outs).unwrap();
        let k = merged.column_by_name("k").unwrap();
        let m = merged.column_by_name("mean_v").unwrap();
        for i in 0..merged.num_rows() {
            match k.value(i) {
                Value::Int64(1) => assert!(m.value(i).is_null()),
                Value::Int64(2) => {
                    assert_eq!(m.value(i), Value::Float64(3.0))
                }
                other => panic!("unexpected key {other:?}"),
            }
        }
    }
}
