//! The checked collective layer — every exchange carries a per-rank
//! Ok/Err verdict on the wire, generalising single-pass ingest's
//! checked allgather to *every* collective (`docs/FAULTS.md`).
//!
//! [`CheckedFabric`] wraps any inner [`Fabric`] and appends one verdict
//! byte to every buffer of every exchange:
//!
//! ```text
//! Ok  frame: payload bytes | 0x01
//! Err frame: Fault frame (net::Fault::encode) | 0x00
//! ```
//!
//! The verdict trails the payload so the happy path never copies:
//! senders push one byte, receivers pop it, and the payload `Vec` is
//! handed through untouched. On the Err path the failing rank still
//! *arrives* at the rendezvous — it posts its encoded [`Fault`] to every
//! peer — so no rank is left parked. Receivers scan sources in
//! ascending rank order and return the first fault found, giving every
//! rank the same lowest-failing-rank attribution (the contract the
//! ingest layer documented, now fabric-wide).
//!
//! What the verdict cannot cover — a rank that fails *between*
//! collectives and never arrives at the next one — is handled
//! out-of-band by [`Fabric::abort`] (called by the cluster's rank
//! wrapper), which this layer delegates to the inner fabric.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Result, RylonError};
use crate::net::{Fabric, FabricRef, Fault, OutBufs};

/// Verdict byte: the sender's rank-local stage succeeded; the frame
/// body is the payload.
pub const VERDICT_OK: u8 = 1;
/// Verdict byte: the sender failed; the frame body is an encoded
/// [`Fault`].
pub const VERDICT_ERR: u8 = 0;

/// Fabric decorator adding per-rank verdicts to every collective step.
pub struct CheckedFabric {
    inner: FabricRef,
    /// Per-rank completed-exchange counters (fault step attribution).
    steps: Vec<AtomicU64>,
}

impl CheckedFabric {
    /// Wrap `inner`; all collectives through `self` carry verdicts.
    pub fn new(inner: FabricRef) -> CheckedFabric {
        let steps = (0..inner.size()).map(|_| AtomicU64::new(0)).collect();
        CheckedFabric { inner, steps }
    }

    /// The wrapped fabric.
    pub fn inner(&self) -> &dyn Fabric {
        self.inner.as_ref()
    }

    /// `rank`'s completed checked-exchange count — the step index the
    /// *next* collective (or a between-collectives fault) is attributed
    /// to.
    pub fn step(&self, rank: usize) -> u64 {
        self.steps[rank].load(Ordering::Relaxed)
    }

    /// The core checked collective: every rank contributes either its
    /// per-destination buffers or its rank-local error. If any rank
    /// contributed an error, **every** rank (including the failing one,
    /// via self-delivery) returns the lowest-failing-rank's fault as a
    /// rank/op/step-attributed [`RylonError::Aborted`]; otherwise the
    /// payloads are delivered bit-identically to an unchecked exchange.
    pub fn exchange_verdict(
        &self,
        rank: usize,
        op: &str,
        local: std::result::Result<OutBufs, &RylonError>,
    ) -> Result<OutBufs> {
        let size = self.inner.size();
        let step = self.steps[rank].load(Ordering::Relaxed);
        let wires: OutBufs = match local {
            Ok(bufs) => {
                if bufs.len() != size {
                    return Err(RylonError::comm(format!(
                        "checked exchange from rank {rank}: {} buffers \
                         for {size} ranks",
                        bufs.len()
                    )));
                }
                bufs.into_iter()
                    .map(|mut b| {
                        b.push(VERDICT_OK);
                        b
                    })
                    .collect()
            }
            Err(e) => {
                let fault = Fault::from_error(rank, op, step, e);
                let mut frame = fault.encode();
                frame.push(VERDICT_ERR);
                vec![frame; size]
            }
        };
        let incoming = self.inner.exchange(rank, wires)?;
        let mut out: OutBufs = Vec::with_capacity(size);
        let mut first_fault: Option<Fault> = None;
        for (src, mut buf) in incoming.into_iter().enumerate() {
            match buf.pop() {
                Some(VERDICT_OK) => out.push(buf),
                Some(VERDICT_ERR) => {
                    if first_fault.is_none() {
                        first_fault =
                            Some(Fault::decode(&buf).unwrap_or_else(
                                |_| {
                                    Fault::comm(
                                        src,
                                        op,
                                        step,
                                        "malformed fault frame in \
                                         checked exchange",
                                    )
                                },
                            ));
                    }
                    out.push(Vec::new());
                }
                _ => {
                    return Err(RylonError::comm(format!(
                        "rank {src} sent a frame without a verdict \
                         byte in checked exchange #{step}"
                    )))
                }
            }
        }
        if let Some(fault) = first_fault {
            return Err(fault.to_error());
        }
        self.steps[rank].fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }
}

impl Fabric for CheckedFabric {
    fn size(&self) -> usize {
        self.inner.size()
    }

    fn exchange(&self, rank: usize, outgoing: OutBufs) -> Result<OutBufs> {
        self.exchange_verdict(rank, "collective", Ok(outgoing))
    }

    fn tick_compute(&self, rank: usize) {
        self.inner.tick_compute(rank)
    }

    fn model_time(&self, rank: usize) -> Option<f64> {
        self.inner.model_time(rank)
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }

    fn fault(&self) -> Option<Fault> {
        self.inner.fault()
    }

    fn abort(&self, fault: Fault) {
        self.inner.abort(fault)
    }

    fn clear_fault(&self) {
        self.inner.clear_fault()
    }

    fn aborts(&self) -> u64 {
        self.inner.aborts()
    }

    fn steps(&self, rank: usize) -> u64 {
        self.step(rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::local::LocalFabric;
    use std::sync::Arc;

    fn checked(size: usize) -> Arc<CheckedFabric> {
        Arc::new(CheckedFabric::new(Arc::new(LocalFabric::new(size))))
    }

    fn run_ranks<F, T>(fab: Arc<CheckedFabric>, f: F) -> Vec<T>
    where
        F: Fn(usize, Arc<CheckedFabric>) -> T + Send + Sync + 'static,
        T: Send + 'static,
    {
        let size = fab.size();
        let f = Arc::new(f);
        let handles: Vec<_> = (0..size)
            .map(|r| {
                let fab = Arc::clone(&fab);
                let f = Arc::clone(&f);
                std::thread::spawn(move || f(r, fab))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn happy_path_is_bit_identical_and_counts_steps() {
        let size = 3;
        let fab = checked(size);
        let results = run_ranks(Arc::clone(&fab), move |rank, fab| {
            let out: OutBufs = (0..size)
                .map(|d| format!("{rank}->{d}").into_bytes())
                .collect();
            fab.exchange(rank, out).unwrap()
        });
        for (dst, incoming) in results.iter().enumerate() {
            for (src, buf) in incoming.iter().enumerate() {
                assert_eq!(
                    String::from_utf8_lossy(buf),
                    format!("{src}->{dst}")
                );
            }
        }
        for r in 0..size {
            assert_eq!(fab.step(r), 1);
        }
    }

    #[test]
    fn empty_payloads_survive_the_verdict_byte() {
        let fab = checked(2);
        let results = run_ranks(fab, |rank, fab| {
            fab.exchange(rank, vec![Vec::new(), Vec::new()]).unwrap()
        });
        for incoming in results {
            assert!(incoming.iter().all(|b| b.is_empty()));
        }
    }

    #[test]
    fn one_rank_error_aborts_every_rank_with_attribution() {
        let size = 3;
        let fab = checked(size);
        let results = run_ranks(fab, move |rank, fab| {
            let err = RylonError::parse("rank-local failure");
            let local = if rank == 1 {
                Err(&err)
            } else {
                Ok(vec![vec![rank as u8]; size])
            };
            fab.exchange_verdict(rank, "unit_op", local)
        });
        for res in &results {
            let e = res.as_ref().unwrap_err();
            let i = e.abort_info().expect("attributed abort");
            assert_eq!((i.rank, i.op.as_str(), i.step), (1, "unit_op", 0));
            assert!(matches!(*i.source, RylonError::Parse(_)));
        }
    }

    #[test]
    fn lowest_failing_rank_wins() {
        let size = 4;
        let fab = checked(size);
        let results = run_ranks(fab, move |rank, fab| {
            let err = RylonError::invalid(format!("bad rank {rank}"));
            let local = if rank == 1 || rank == 3 {
                Err(&err)
            } else {
                Ok(vec![Vec::new(); size])
            };
            fab.exchange_verdict(rank, "unit_op", local)
        });
        for res in &results {
            let i = res.as_ref().unwrap_err().abort_info().unwrap();
            assert_eq!(i.rank, 1, "lowest failing rank attributed");
        }
    }

    #[test]
    fn failed_step_does_not_advance_the_counter() {
        let fab = checked(1);
        let err = RylonError::comm("boom");
        assert!(fab.exchange_verdict(0, "op", Err(&err)).is_err());
        assert_eq!(fab.step(0), 0);
        assert!(fab.exchange(0, vec![b"ok".to_vec()]).is_ok());
        assert_eq!(fab.step(0), 1);
    }
}
