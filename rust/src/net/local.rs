//! Shared-memory fabric: rank threads rendezvous through a mailbox
//! matrix guarded by a mutex + condvar, generation-counted so back-to-
//! back exchanges never cross. This is the "real concurrency" fabric —
//! every correctness test runs on it.
//!
//! The fabric is one half of the cluster-wide fault domain
//! (`docs/FAULTS.md`): a recorded [`Fault`] — set by [`Fabric::abort`]
//! when a rank fails outside an exchange, or internally when a
//! collective times out — wakes every parked rank and makes every
//! subsequent exchange fail fast with the same attributed error until
//! [`Fabric::clear_fault`] resets the rendezvous.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::error::{Result, RylonError};
use crate::net::{Fabric, Fault, OutBufs};

struct State {
    /// `mailbox[src][dst]`: buffer posted by `src` for `dst` in the
    /// current generation.
    mailbox: Vec<Vec<Option<Vec<u8>>>>,
    /// Ranks that have posted this generation.
    posted: usize,
    /// Ranks that have collected their incoming buffers this generation.
    collected: usize,
    /// Exchange generation (collection phase opens when all posted).
    generation: u64,
    /// Per-rank arrival flags for the current generation (who has
    /// posted) — names the missing ranks when a collective times out.
    arrived: Vec<bool>,
    /// The fault poisoning this fabric, if any. First fault wins.
    fault: Option<Fault>,
}

/// In-process fabric for `size` rank threads.
pub struct LocalFabric {
    size: usize,
    state: Mutex<State>,
    cond: Condvar,
    bytes: AtomicU64,
    aborts: AtomicU64,
    /// Collective timeout; `None` parks forever (the pre-fault-domain
    /// behaviour).
    timeout: Option<Duration>,
}

impl LocalFabric {
    pub fn new(size: usize) -> LocalFabric {
        assert!(size > 0, "fabric needs at least one rank");
        LocalFabric {
            size,
            state: Mutex::new(State {
                mailbox: vec![vec![None; size]; size],
                posted: 0,
                collected: 0,
                generation: 0,
                arrived: vec![false; size],
                fault: None,
            }),
            cond: Condvar::new(),
            bytes: AtomicU64::new(0),
            aborts: AtomicU64::new(0),
            timeout: None,
        }
    }

    /// Abort any collective that does not complete within `timeout`
    /// (attributing the lowest rank that never arrived). `None` waits
    /// forever.
    pub fn with_timeout(mut self, timeout: Option<Duration>) -> Self {
        self.timeout = timeout;
        self
    }

    /// Lock the state, converting a poisoned mutex (a rank panicked
    /// while holding it) into an attributed error rather than a panic.
    fn lock(&self, rank: usize) -> Result<MutexGuard<'_, State>> {
        self.state.lock().map_err(|p| {
            let st = p.into_inner();
            match &st.fault {
                Some(f) => f.to_error(),
                None => RylonError::comm(format!(
                    "fabric poisoned: a rank panicked inside exchange #{} \
                     (observed by rank {rank})",
                    st.generation
                )),
            }
        })
    }

    /// One condvar wait, bounded by the deadline. Returns the attributed
    /// timeout error once the deadline passes (recording the fault so
    /// every other rank aborts identically).
    fn wait<'a>(
        &self,
        st: MutexGuard<'a, State>,
        rank: usize,
        deadline: Option<Instant>,
    ) -> Result<MutexGuard<'a, State>> {
        let poison = |p: std::sync::PoisonError<MutexGuard<'_, State>>| {
            let st = p.into_inner();
            match &st.fault {
                Some(f) => f.to_error(),
                None => RylonError::comm(format!(
                    "fabric poisoned: a rank panicked inside exchange #{} \
                     (observed by rank {rank})",
                    st.generation
                )),
            }
        };
        let Some(dl) = deadline else {
            return self.cond.wait(st).map_err(poison);
        };
        let remaining = dl.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(self.record_timeout(st, rank));
        }
        let (st, _) =
            self.cond.wait_timeout(st, remaining).map_err(poison)?;
        Ok(st)
    }

    /// Record a collective-timeout fault (first fault wins), attributing
    /// the lowest rank that never arrived at the current generation.
    fn record_timeout(
        &self,
        mut st: MutexGuard<'_, State>,
        rank: usize,
    ) -> RylonError {
        if let Some(f) = &st.fault {
            return f.to_error();
        }
        let timeout = self.timeout.unwrap_or_default();
        let missing: Vec<usize> =
            (0..self.size).filter(|&r| !st.arrived[r]).collect();
        let culprit = missing.first().copied().unwrap_or(rank);
        let msg = if missing.is_empty() {
            format!(
                "collective timed out after {timeout:?}: exchange #{} \
                 never closed (observed by rank {rank})",
                st.generation
            )
        } else {
            format!(
                "collective timed out after {timeout:?}: rank(s) \
                 {missing:?} never arrived at exchange #{}",
                st.generation
            )
        };
        let fault = Fault::comm(culprit, "exchange", st.generation, msg);
        st.fault = Some(fault.clone());
        self.aborts.fetch_add(1, Ordering::Relaxed);
        self.cond.notify_all();
        fault.to_error()
    }
}

impl Fabric for LocalFabric {
    fn size(&self) -> usize {
        self.size
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn fault(&self) -> Option<Fault> {
        match self.state.lock() {
            Ok(st) => st.fault.clone(),
            Err(p) => p.into_inner().fault.clone(),
        }
    }

    fn abort(&self, fault: Fault) {
        // Must deliver even if the mutex is poisoned: the whole point
        // is waking peers after a rank died mid-collective.
        let mut st = match self.state.lock() {
            Ok(st) => st,
            Err(p) => p.into_inner(),
        };
        if st.fault.is_none() {
            st.fault = Some(fault);
            self.aborts.fetch_add(1, Ordering::Relaxed);
        }
        self.cond.notify_all();
    }

    fn clear_fault(&self) {
        let mut st = match self.state.lock() {
            Ok(st) => st,
            Err(p) => p.into_inner(),
        };
        st.fault = None;
        st.posted = 0;
        st.collected = 0;
        st.generation += 1;
        st.arrived.fill(false);
        for row in &mut st.mailbox {
            for slot in row {
                *slot = None;
            }
        }
        self.cond.notify_all();
    }

    fn aborts(&self) -> u64 {
        self.aborts.load(Ordering::Relaxed)
    }

    fn exchange(&self, rank: usize, outgoing: OutBufs) -> Result<OutBufs> {
        if outgoing.len() != self.size {
            return Err(RylonError::comm(format!(
                "exchange from rank {rank}: {} buffers for {} ranks",
                outgoing.len(),
                self.size
            )));
        }
        let posted_bytes: usize = outgoing.iter().map(|b| b.len()).sum();
        self.bytes.fetch_add(posted_bytes as u64, Ordering::Relaxed);
        let deadline = self.timeout.map(|t| Instant::now() + t);
        let mut st = self.lock(rank)?;
        if let Some(f) = &st.fault {
            return Err(f.to_error());
        }
        let my_gen = st.generation;

        // Post.
        for (dst, buf) in outgoing.into_iter().enumerate() {
            debug_assert!(st.mailbox[rank][dst].is_none());
            st.mailbox[rank][dst] = Some(buf);
        }
        st.posted += 1;
        st.arrived[rank] = true;
        if st.posted == self.size {
            self.cond.notify_all();
        }
        // Wait for everyone to post this generation.
        while st.generation == my_gen && st.posted < self.size {
            st = self.wait(st, rank, deadline)?;
            if let Some(f) = &st.fault {
                return Err(f.to_error());
            }
        }

        // Collect column `rank`.
        let mut incoming: OutBufs = Vec::with_capacity(self.size);
        for src in 0..self.size {
            match st.mailbox[src][rank].take() {
                Some(buf) => incoming.push(buf),
                None => {
                    let fault = Fault::comm(
                        src,
                        "exchange",
                        st.generation,
                        format!(
                            "mailbox slot empty: rank {src} never \
                             delivered to rank {rank} in exchange #{}",
                            st.generation
                        ),
                    );
                    if st.fault.is_none() {
                        st.fault = Some(fault.clone());
                        self.aborts.fetch_add(1, Ordering::Relaxed);
                    }
                    self.cond.notify_all();
                    return Err(fault.to_error());
                }
            }
        }
        st.collected += 1;
        if st.collected == self.size {
            // Last collector resets for the next generation.
            st.posted = 0;
            st.collected = 0;
            st.generation += 1;
            st.arrived.fill(false);
            self.cond.notify_all();
        } else {
            // Wait until the generation closes so a fast rank can't
            // lap the slowest and double-post into the same slots.
            let gen = st.generation;
            while st.generation == gen {
                st = self.wait(st, rank, deadline)?;
                if let Some(f) = &st.fault {
                    return Err(f.to_error());
                }
            }
        }
        Ok(incoming)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn run_ranks<F, T>(size: usize, f: F) -> Vec<T>
    where
        F: Fn(usize, Arc<LocalFabric>) -> T + Send + Sync + 'static,
        T: Send + 'static,
    {
        run_ranks_on(Arc::new(LocalFabric::new(size)), f)
    }

    fn run_ranks_on<F, T>(fabric: Arc<LocalFabric>, f: F) -> Vec<T>
    where
        F: Fn(usize, Arc<LocalFabric>) -> T + Send + Sync + 'static,
        T: Send + 'static,
    {
        let size = fabric.size();
        let f = Arc::new(f);
        let handles: Vec<_> = (0..size)
            .map(|r| {
                let fab = Arc::clone(&fabric);
                let f = Arc::clone(&f);
                std::thread::spawn(move || f(r, fab))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn exchange_routes_point_to_point() {
        let size = 4;
        let results = run_ranks(size, move |rank, fab| {
            // Send "{src}->{dst}" to every dst.
            let out: OutBufs = (0..size)
                .map(|d| format!("{rank}->{d}").into_bytes())
                .collect();
            fab.exchange(rank, out).unwrap()
        });
        for (dst, incoming) in results.iter().enumerate() {
            for (src, buf) in incoming.iter().enumerate() {
                assert_eq!(
                    String::from_utf8_lossy(buf),
                    format!("{src}->{dst}")
                );
            }
        }
    }

    #[test]
    fn repeated_exchanges_do_not_cross_generations() {
        let size = 3;
        let results = run_ranks(size, move |rank, fab| {
            let mut got = Vec::new();
            for round in 0..10u8 {
                let out: OutBufs =
                    (0..size).map(|_| vec![round, rank as u8]).collect();
                let inc = fab.exchange(rank, out).unwrap();
                for (src, buf) in inc.iter().enumerate() {
                    assert_eq!(buf, &vec![round, src as u8]);
                }
                got.push(inc.len());
            }
            got
        });
        assert!(results.iter().all(|r| r.iter().all(|&n| n == size)));
    }

    #[test]
    fn wrong_buffer_count_rejected() {
        let fab = LocalFabric::new(1);
        assert!(fab.exchange(0, vec![]).is_err());
    }

    #[test]
    fn single_rank_self_delivery() {
        let fab = LocalFabric::new(1);
        let inc = fab.exchange(0, vec![b"self".to_vec()]).unwrap();
        assert_eq!(inc[0], b"self");
    }

    #[test]
    fn abort_wakes_parked_ranks_with_the_fault() {
        let fabric = Arc::new(LocalFabric::new(2));
        let results = run_ranks_on(fabric, |rank, fab| {
            if rank == 1 {
                // Rank 1 dies before posting; rank 0 parks until the
                // abort arrives.
                fab.abort(Fault::comm(1, "unit", 0, "rank 1 gave up"));
                return Err(RylonError::comm("local failure"));
            }
            fab.exchange(0, vec![vec![]; 2]).map(drop)
        });
        let e = results[0].as_ref().unwrap_err();
        let i = e.abort_info().expect("attributed abort");
        assert_eq!(i.rank, 1);
        assert!(e.to_string().contains("rank 1 gave up"));
    }

    #[test]
    fn fault_makes_exchange_fail_fast_until_cleared() {
        let fab = LocalFabric::new(1);
        fab.abort(Fault::comm(0, "unit", 3, "boom"));
        assert_eq!(fab.aborts(), 1);
        let e = fab.exchange(0, vec![vec![]]).unwrap_err();
        assert_eq!(e.abort_info().unwrap().step, 3);
        fab.clear_fault();
        assert!(fab.fault().is_none());
        assert!(fab.exchange(0, vec![b"ok".to_vec()]).is_ok());
        // The abort count is cumulative across clears.
        assert_eq!(fab.aborts(), 1);
    }

    #[test]
    fn timeout_attributes_the_missing_rank() {
        let fabric = Arc::new(
            LocalFabric::new(2)
                .with_timeout(Some(Duration::from_millis(50))),
        );
        let results = run_ranks_on(fabric, |rank, fab| {
            if rank == 1 {
                // Never shows up.
                return Err(RylonError::comm("absent"));
            }
            fab.exchange(0, vec![vec![]; 2]).map(drop)
        });
        let e = results[0].as_ref().unwrap_err();
        let i = e.abort_info().expect("attributed timeout");
        assert_eq!(i.rank, 1, "lowest non-arrived rank blamed");
        assert!(e.to_string().contains("timed out"));
    }
}
