//! Shared-memory fabric: rank threads rendezvous through a mailbox
//! matrix guarded by a mutex + condvar, generation-counted so back-to-
//! back exchanges never cross. This is the "real concurrency" fabric —
//! every correctness test runs on it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use crate::error::{Result, RylonError};
use crate::net::{Fabric, OutBufs};

struct State {
    /// `mailbox[src][dst]`: buffer posted by `src` for `dst` in the
    /// current generation.
    mailbox: Vec<Vec<Option<Vec<u8>>>>,
    /// Ranks that have posted this generation.
    posted: usize,
    /// Ranks that have collected their incoming buffers this generation.
    collected: usize,
    /// Exchange generation (collection phase opens when all posted).
    generation: u64,
}

/// In-process fabric for `size` rank threads.
pub struct LocalFabric {
    size: usize,
    state: Mutex<State>,
    cond: Condvar,
    bytes: AtomicU64,
}

impl LocalFabric {
    pub fn new(size: usize) -> LocalFabric {
        assert!(size > 0, "fabric needs at least one rank");
        LocalFabric {
            size,
            state: Mutex::new(State {
                mailbox: vec![vec![None; size]; size],
                posted: 0,
                collected: 0,
                generation: 0,
            }),
            cond: Condvar::new(),
            bytes: AtomicU64::new(0),
        }
    }
}

impl Fabric for LocalFabric {
    fn size(&self) -> usize {
        self.size
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn exchange(&self, rank: usize, outgoing: OutBufs) -> Result<OutBufs> {
        if outgoing.len() != self.size {
            return Err(RylonError::comm(format!(
                "exchange from rank {rank}: {} buffers for {} ranks",
                outgoing.len(),
                self.size
            )));
        }
        let posted_bytes: usize = outgoing.iter().map(|b| b.len()).sum();
        self.bytes.fetch_add(posted_bytes as u64, Ordering::Relaxed);
        let mut st = self.state.lock().map_err(|_| {
            RylonError::comm("fabric poisoned (a rank panicked)")
        })?;
        let my_gen = st.generation;

        // Post.
        for (dst, buf) in outgoing.into_iter().enumerate() {
            debug_assert!(st.mailbox[rank][dst].is_none());
            st.mailbox[rank][dst] = Some(buf);
        }
        st.posted += 1;
        if st.posted == self.size {
            self.cond.notify_all();
        }
        // Wait for everyone to post this generation.
        while st.generation == my_gen && st.posted < self.size {
            st = self.cond.wait(st).map_err(|_| {
                RylonError::comm("fabric poisoned (a rank panicked)")
            })?;
        }

        // Collect column `rank`.
        let mut incoming: OutBufs = Vec::with_capacity(self.size);
        for src in 0..self.size {
            incoming.push(
                st.mailbox[src][rank]
                    .take()
                    .expect("mailbox slot missing"),
            );
        }
        st.collected += 1;
        if st.collected == self.size {
            // Last collector resets for the next generation.
            st.posted = 0;
            st.collected = 0;
            st.generation += 1;
            self.cond.notify_all();
        } else {
            // Wait until the generation closes so a fast rank can't
            // lap the slowest and double-post into the same slots.
            let gen = st.generation;
            while st.generation == gen {
                st = self.cond.wait(st).map_err(|_| {
                    RylonError::comm("fabric poisoned (a rank panicked)")
                })?;
            }
        }
        Ok(incoming)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn run_ranks<F, T>(size: usize, f: F) -> Vec<T>
    where
        F: Fn(usize, Arc<LocalFabric>) -> T + Send + Sync + 'static,
        T: Send + 'static,
    {
        let fabric = Arc::new(LocalFabric::new(size));
        let f = Arc::new(f);
        let handles: Vec<_> = (0..size)
            .map(|r| {
                let fab = Arc::clone(&fabric);
                let f = Arc::clone(&f);
                std::thread::spawn(move || f(r, fab))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn exchange_routes_point_to_point() {
        let size = 4;
        let results = run_ranks(size, move |rank, fab| {
            // Send "{src}->{dst}" to every dst.
            let out: OutBufs = (0..size)
                .map(|d| format!("{rank}->{d}").into_bytes())
                .collect();
            fab.exchange(rank, out).unwrap()
        });
        for (dst, incoming) in results.iter().enumerate() {
            for (src, buf) in incoming.iter().enumerate() {
                assert_eq!(
                    String::from_utf8_lossy(buf),
                    format!("{src}->{dst}")
                );
            }
        }
    }

    #[test]
    fn repeated_exchanges_do_not_cross_generations() {
        let size = 3;
        let results = run_ranks(size, move |rank, fab| {
            let mut got = Vec::new();
            for round in 0..10u8 {
                let out: OutBufs =
                    (0..size).map(|_| vec![round, rank as u8]).collect();
                let inc = fab.exchange(rank, out).unwrap();
                for (src, buf) in inc.iter().enumerate() {
                    assert_eq!(buf, &vec![round, src as u8]);
                }
                got.push(inc.len());
            }
            got
        });
        assert!(results.iter().all(|r| r.iter().all(|&n| n == size)));
    }

    #[test]
    fn wrong_buffer_count_rejected() {
        let fab = LocalFabric::new(1);
        assert!(fab.exchange(0, vec![]).is_err());
    }

    #[test]
    fn single_rank_self_delivery() {
        let fab = LocalFabric::new(1);
        let inc = fab.exchange(0, vec![b"self".to_vec()]).unwrap();
        assert_eq!(inc[0], b"self");
    }
}
