//! MPI-like collectives derived from the [`Fabric::exchange`] primitive:
//! barrier, gather, allgather, bcast, allreduce. These are what the
//! distributed operators and the sample-sort splitter exchange use; the
//! user-facing API never sees them (paper §IV: "We do not expose the
//! communication API to the data scientist").

use crate::error::{Result, RylonError};
use crate::net::{Fabric, OutBufs, ReduceOp};

/// Validate a peer's allreduce contribution: every rank must send
/// exactly `n` little-endian 8-byte words. A short, long, or ragged
/// buffer used to be silently truncated by `chunks_exact` (or to panic
/// on the accumulator index) — now it is the symmetric, rank-attributed
/// comm error the fault domain promises (`docs/FAULTS.md`).
fn check_allreduce_buf(src: usize, buf: &[u8], n: usize) -> Result<()> {
    if buf.len() == n * 8 {
        Ok(())
    } else {
        Err(RylonError::comm(format!(
            "allreduce: rank {src} sent {} bytes, expected {} \
             ({n} × 8-byte words)",
            buf.len(),
            n * 8
        )))
    }
}

/// Synchronise all ranks.
pub fn barrier(fabric: &dyn Fabric, rank: usize) -> Result<()> {
    let empty: OutBufs = vec![Vec::new(); fabric.size()];
    fabric.exchange(rank, empty)?;
    Ok(())
}

/// Gather every rank's buffer at `root`. Returns `Some(bufs)` (indexed
/// by source rank) at the root, `None` elsewhere.
pub fn gather(
    fabric: &dyn Fabric,
    rank: usize,
    root: usize,
    data: Vec<u8>,
) -> Result<Option<Vec<Vec<u8>>>> {
    let size = fabric.size();
    let mut out: OutBufs = vec![Vec::new(); size];
    out[root] = data;
    let incoming = fabric.exchange(rank, out)?;
    if rank == root {
        Ok(Some(incoming))
    } else {
        Ok(None)
    }
}

/// Every rank receives every rank's buffer (indexed by source).
pub fn allgather(
    fabric: &dyn Fabric,
    rank: usize,
    data: Vec<u8>,
) -> Result<Vec<Vec<u8>>> {
    let size = fabric.size();
    let out: OutBufs = (0..size).map(|_| data.clone()).collect();
    fabric.exchange(rank, out)
}

/// Broadcast `root`'s buffer to every rank.
pub fn bcast(
    fabric: &dyn Fabric,
    rank: usize,
    root: usize,
    data: Vec<u8>,
) -> Result<Vec<u8>> {
    let size = fabric.size();
    let out: OutBufs = if rank == root {
        (0..size).map(|_| data.clone()).collect()
    } else {
        vec![Vec::new(); size]
    };
    let mut incoming = fabric.exchange(rank, out)?;
    Ok(std::mem::take(&mut incoming[root]))
}

/// Element-wise allreduce over an f64 vector.
pub fn allreduce_f64(
    fabric: &dyn Fabric,
    rank: usize,
    vals: &[f64],
    op: ReduceOp,
) -> Result<Vec<f64>> {
    let bytes: Vec<u8> =
        vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    let all = allgather(fabric, rank, bytes)?;
    let mut acc = vals.to_vec();
    for (src, buf) in all.iter().enumerate() {
        if src == rank {
            continue;
        }
        check_allreduce_buf(src, buf, vals.len())?;
        for (i, chunk) in buf.chunks_exact(8).enumerate() {
            let v = f64::from_le_bytes(chunk.try_into().unwrap());
            acc[i] = op.fold(acc[i], v);
        }
    }
    Ok(acc)
}

/// Element-wise allreduce over a u64 vector (exact, no f64 rounding).
pub fn allreduce_u64(
    fabric: &dyn Fabric,
    rank: usize,
    vals: &[u64],
    op: ReduceOp,
) -> Result<Vec<u64>> {
    let bytes: Vec<u8> =
        vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    let all = allgather(fabric, rank, bytes)?;
    let mut acc = vals.to_vec();
    for (src, buf) in all.iter().enumerate() {
        if src == rank {
            continue;
        }
        check_allreduce_buf(src, buf, vals.len())?;
        for (i, chunk) in buf.chunks_exact(8).enumerate() {
            let v = u64::from_le_bytes(chunk.try_into().unwrap());
            acc[i] = match op {
                ReduceOp::Sum => acc[i] + v,
                ReduceOp::Min => acc[i].min(v),
                ReduceOp::Max => acc[i].max(v),
            };
        }
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::local::LocalFabric;
    use std::sync::Arc;

    fn run<F, T>(size: usize, f: F) -> Vec<T>
    where
        F: Fn(usize, Arc<LocalFabric>) -> T + Send + Sync + 'static,
        T: Send + 'static,
    {
        let fabric = Arc::new(LocalFabric::new(size));
        let f = Arc::new(f);
        let handles: Vec<_> = (0..size)
            .map(|r| {
                let fab = Arc::clone(&fabric);
                let f = Arc::clone(&f);
                std::thread::spawn(move || f(r, fab))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn barrier_completes() {
        run(4, |rank, fab| {
            for _ in 0..5 {
                barrier(fab.as_ref(), rank).unwrap();
            }
        });
    }

    #[test]
    fn gather_collects_at_root() {
        let results = run(4, |rank, fab| {
            gather(fab.as_ref(), rank, 2, vec![rank as u8]).unwrap()
        });
        for (rank, r) in results.iter().enumerate() {
            if rank == 2 {
                let bufs = r.as_ref().unwrap();
                assert_eq!(
                    bufs.iter().map(|b| b[0]).collect::<Vec<_>>(),
                    vec![0, 1, 2, 3]
                );
            } else {
                assert!(r.is_none());
            }
        }
    }

    #[test]
    fn allgather_everyone_sees_all() {
        let results = run(3, |rank, fab| {
            allgather(fab.as_ref(), rank, vec![rank as u8 * 10]).unwrap()
        });
        for r in results {
            assert_eq!(r.iter().map(|b| b[0]).collect::<Vec<_>>(), vec![
                0, 10, 20
            ]);
        }
    }

    #[test]
    fn bcast_from_root() {
        let results = run(3, |rank, fab| {
            let data = if rank == 1 { b"hello".to_vec() } else { vec![] };
            bcast(fab.as_ref(), rank, 1, data).unwrap()
        });
        for r in results {
            assert_eq!(r, b"hello");
        }
    }

    /// One-rank fabric that hands back attacker-controlled "peer"
    /// buffers: incoming[0] is the rank's own (valid) contribution,
    /// incoming[1] the canned ragged one.
    struct RaggedFabric {
        peer_buf: Vec<u8>,
    }

    impl Fabric for RaggedFabric {
        fn size(&self) -> usize {
            2
        }

        fn exchange(
            &self,
            _rank: usize,
            outgoing: OutBufs,
        ) -> Result<OutBufs> {
            let own = outgoing.into_iter().next().unwrap();
            Ok(vec![own, self.peer_buf.clone()])
        }
    }

    #[test]
    fn allreduce_rejects_short_ragged_and_long_peer_buffers() {
        for bad_len in [0usize, 7, 8, 9, 24] {
            let fab = RaggedFabric {
                peer_buf: vec![0u8; bad_len],
            };
            let vals = [1.0f64, 2.0];
            let e = allreduce_f64(&fab, 0, &vals, ReduceOp::Sum)
                .unwrap_err();
            assert!(
                e.to_string().contains("rank 1 sent"),
                "len={bad_len}: {e}"
            );
            let e = allreduce_u64(&fab, 0, &[1, 2], ReduceOp::Max)
                .unwrap_err();
            assert!(
                e.to_string().contains("expected 16"),
                "len={bad_len}: {e}"
            );
        }
        // Exact length still reduces.
        let fab = RaggedFabric {
            peer_buf: 5u64
                .to_le_bytes()
                .iter()
                .chain(&7u64.to_le_bytes())
                .copied()
                .collect(),
        };
        assert_eq!(
            allreduce_u64(&fab, 0, &[1, 2], ReduceOp::Sum).unwrap(),
            vec![6, 9]
        );
    }

    #[test]
    fn allreduce_sum_min_max() {
        let results = run(4, |rank, fab| {
            let v = vec![rank as f64, 1.0];
            (
                allreduce_f64(fab.as_ref(), rank, &v, ReduceOp::Sum).unwrap(),
                allreduce_f64(fab.as_ref(), rank, &v, ReduceOp::Max).unwrap(),
                allreduce_u64(fab.as_ref(), rank, &[rank as u64], ReduceOp::Min)
                    .unwrap(),
            )
        });
        for (sum, max, min) in results {
            assert_eq!(sum, vec![6.0, 4.0]);
            assert_eq!(max, vec![3.0, 1.0]);
            assert_eq!(min, vec![0]);
        }
    }
}
