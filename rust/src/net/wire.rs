//! Wire format: (de)serialise a [`Table`] for the shuffle. Columnar and
//! copy-friendly — fixed-width buffers round-trip as single memcpys, the
//! exact property the paper credits Arrow's format for (§III-A).
//!
//! Layout (little-endian):
//! ```text
//! u32 MAGIC | u32 ncols | u64 nrows
//! per column:
//!   u8 dtype | u8 has_validity | u16 name_len | name bytes
//!   [validity words: ceil(nrows/64) × u64]
//!   values:
//!     i64/f64: nrows × 8 bytes
//!     bool:    nrows × 1 byte
//!     utf8:    (nrows+1) × u64 offsets | u64 nbytes | bytes
//! ```

use crate::buffer::Bitmap;
use crate::column::{Column, PrimitiveColumn, StringColumn};
use crate::error::{Result, RylonError};
use crate::table::Table;
use crate::types::{DataType, Field, Schema};

pub(crate) const MAGIC: u32 = 0x52594C4E; // "RYLN"

pub(crate) fn dtype_tag(dt: DataType) -> u8 {
    match dt {
        DataType::Int64 => 0,
        DataType::Float64 => 1,
        DataType::Utf8 => 2,
        DataType::Bool => 3,
    }
}

pub(crate) fn tag_dtype(tag: u8) -> Result<DataType> {
    match tag {
        0 => Ok(DataType::Int64),
        1 => Ok(DataType::Float64),
        2 => Ok(DataType::Utf8),
        3 => Ok(DataType::Bool),
        _ => Err(RylonError::parse(format!("bad dtype tag {tag}"))),
    }
}

/// Serialise a table to a fresh byte buffer.
pub fn serialize_table(table: &Table) -> Vec<u8> {
    let mut out = Vec::with_capacity(table.byte_size() + 64);
    serialize_table_into(table, &mut out);
    out
}

/// Serialise appending to `out` (the shuffle reuses send buffers).
pub fn serialize_table_into(table: &Table, out: &mut Vec<u8>) {
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&(table.num_columns() as u32).to_le_bytes());
    out.extend_from_slice(&(table.num_rows() as u64).to_le_bytes());
    for (field, col) in table.schema().fields().iter().zip(table.columns()) {
        out.push(dtype_tag(field.dtype));
        let validity = col.validity();
        out.push(validity.is_some() as u8);
        let name = field.name.as_bytes();
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name);
        if let Some(bm) = validity {
            for w in bm.words() {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
        match col {
            Column::Int64(c) => {
                for v in c.values() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Column::Float64(c) => {
                for v in c.values() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Column::Bool(c) => {
                out.extend(c.values().iter().map(|&b| b as u8));
            }
            Column::Utf8(c) => {
                for o in c.offsets() {
                    out.extend_from_slice(&o.to_le_bytes());
                }
                out.extend_from_slice(
                    &(c.bytes().len() as u64).to_le_bytes(),
                );
                out.extend_from_slice(c.bytes());
            }
        }
    }
}

/// Little-endian cursor over a wire buffer. Shared with the fault-frame
/// codec in [`crate::net::checked`] so both layers decode one way.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn need(&self, n: usize) -> Result<()> {
        if self.pos + n > self.buf.len() {
            Err(RylonError::parse(format!(
                "wire buffer truncated at byte {} (need {n} more)",
                self.pos
            )))
        } else {
            Ok(())
        }
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        self.need(1)?;
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    pub(crate) fn u16(&mut self) -> Result<u16> {
        self.need(2)?;
        let v = u16::from_le_bytes(
            self.buf[self.pos..self.pos + 2].try_into().unwrap(),
        );
        self.pos += 2;
        Ok(v)
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        self.need(4)?;
        let v = u32::from_le_bytes(
            self.buf[self.pos..self.pos + 4].try_into().unwrap(),
        );
        self.pos += 4;
        Ok(v)
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        self.need(8)?;
        let v = u64::from_le_bytes(
            self.buf[self.pos..self.pos + 8].try_into().unwrap(),
        );
        self.pos += 8;
        Ok(v)
    }

    pub(crate) fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.need(n)?;
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Bytes left after the cursor — the bound every header-declared
    /// count is validated against before it sizes an allocation.
    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Reject a header-declared `count` of `width`-byte items that
    /// cannot possibly fit in the remaining buffer. Frames arrive off
    /// the network, so counts are attacker-controlled until this check:
    /// allocating `count` slots first would let a corrupt frame
    /// claiming `u64::MAX` rows abort or OOM the rank.
    pub(crate) fn check_count(
        &self,
        count: usize,
        width: usize,
        what: &str,
    ) -> Result<()> {
        let fits = count
            .checked_mul(width)
            .is_some_and(|need| need <= self.remaining());
        if fits {
            Ok(())
        } else {
            Err(RylonError::parse(format!(
                "wire header claims {count} {what} ({width} bytes each) \
                 but only {} bytes remain at byte {}",
                self.remaining(),
                self.pos
            )))
        }
    }
}

/// Deserialise a table from a wire buffer.
pub fn deserialize_table(buf: &[u8]) -> Result<Table> {
    let mut r = Reader::new(buf);
    if r.u32()? != MAGIC {
        return Err(RylonError::parse("bad wire magic"));
    }
    let ncols = r.u32()? as usize;
    let nrows = r.u64()? as usize;
    // Every column consumes at least its 4-byte header, so `ncols`
    // beyond that bound is a lie; the field/column vecs themselves grow
    // per parsed column (each of which consumed real buffer bytes)
    // rather than pre-sizing from the untrusted header.
    r.check_count(ncols, 4, "columns")?;
    let mut fields = Vec::new();
    let mut cols = Vec::new();
    for _ in 0..ncols {
        let dtype = tag_dtype(r.u8()?)?;
        let has_validity = r.u8()? != 0;
        let name_len = r.u16()? as usize;
        let name =
            String::from_utf8(r.bytes(name_len)?.to_vec()).map_err(|_| {
                RylonError::parse("column name is not utf-8")
            })?;
        let validity = if has_validity {
            let nwords = nrows.div_ceil(64);
            r.check_count(nwords, 8, "validity words")?;
            let words: Result<Vec<u64>> =
                (0..nwords).map(|_| r.u64()).collect();
            Some(Bitmap::from_words(words?, nrows))
        } else {
            None
        };
        let col = match dtype {
            DataType::Int64 => {
                r.check_count(nrows, 8, "i64 rows")?;
                let mut values = Vec::with_capacity(nrows);
                for _ in 0..nrows {
                    values.push(r.u64()? as i64);
                }
                Column::Int64(prim_from_parts(values, validity))
            }
            DataType::Float64 => {
                r.check_count(nrows, 8, "f64 rows")?;
                let mut values = Vec::with_capacity(nrows);
                for _ in 0..nrows {
                    values.push(f64::from_bits(r.u64()?));
                }
                Column::Float64(prim_from_parts(values, validity))
            }
            DataType::Bool => {
                let raw = r.bytes(nrows)?;
                let values = raw.iter().map(|&b| b != 0).collect();
                Column::Bool(prim_from_parts(values, validity))
            }
            DataType::Utf8 => {
                let noffsets = nrows.checked_add(1).ok_or_else(|| {
                    RylonError::parse("utf8 offset count overflows")
                })?;
                r.check_count(noffsets, 8, "utf8 offsets")?;
                let mut offsets = Vec::with_capacity(noffsets);
                for _ in 0..noffsets {
                    offsets.push(r.u64()?);
                }
                let nbytes = r.u64()? as usize;
                let bytes = r.bytes(nbytes)?.to_vec();
                // Validate UTF-8 once on ingest; value() reads unchecked.
                let s = std::str::from_utf8(&bytes).map_err(|_| {
                    RylonError::parse("string column is not utf-8")
                })?;
                // `StringColumn::value` slices `bytes[off[i]..off[i+1]]`
                // without checks, so a malformed frame here would be an
                // out-of-bounds read (or a non-boundary `&str` slice):
                // offsets must be monotonic non-decreasing, end exactly
                // at `nbytes` (which bounds them all within the
                // buffer), and land on UTF-8 character boundaries.
                let mut prev = 0u64;
                for (i, &o) in offsets.iter().enumerate() {
                    if o < prev {
                        return Err(RylonError::parse(format!(
                            "utf8 offsets decrease at row {i} \
                             ({o} after {prev})"
                        )));
                    }
                    if !s.is_char_boundary(o as usize) {
                        return Err(RylonError::parse(format!(
                            "utf8 offset {o} at row {i} splits a \
                             character or exceeds the {nbytes}-byte \
                             string buffer"
                        )));
                    }
                    prev = o;
                }
                if prev as usize != nbytes {
                    return Err(RylonError::parse(format!(
                        "utf8 offsets end at {prev}, not at the \
                         {nbytes}-byte string buffer length"
                    )));
                }
                Column::Utf8(StringColumn::from_parts(
                    offsets, bytes, validity,
                ))
            }
        };
        fields.push(Field::new(name, dtype));
        cols.push(col);
    }
    Table::try_new(Schema::new(fields), cols)
}

fn prim_from_parts<T: Copy + Default>(
    values: Vec<T>,
    validity: Option<Bitmap>,
) -> PrimitiveColumn<T> {
    match validity {
        None => PrimitiveColumn::from_values(values),
        Some(bm) => PrimitiveColumn::from_options(
            values
                .into_iter()
                .enumerate()
                .map(|(i, v)| if bm.get(i) { Some(v) } else { None })
                .collect(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Table {
        Table::from_columns(vec![
            ("id", Column::from_opt_i64(vec![Some(1), None, Some(-3)])),
            ("v", Column::from_f64(vec![0.5, f64::NAN, -0.0])),
            ("s", Column::from_opt_str(&[Some("héllo"), Some(""), None])),
            ("b", Column::from_bool(vec![true, false, true])),
        ])
        .unwrap()
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let t = table();
        let bytes = serialize_table(&t);
        let back = deserialize_table(&bytes).unwrap();
        assert_eq!(back.num_rows(), 3);
        assert_eq!(back.schema(), t.schema());
        // NaN bits survive (PartialEq on f64 columns compares values, so
        // check columns pairwise except the NaN cell).
        assert_eq!(back.column(0), t.column(0));
        assert_eq!(back.column(2), t.column(2));
        assert_eq!(back.column(3), t.column(3));
        assert!(back.column(1).f64_values()[1].is_nan());
        assert_eq!(back.column(1).f64_values()[0], 0.5);
    }

    #[test]
    fn empty_table_roundtrip() {
        let t = Table::empty(Schema::parse("a:i64,b:str").unwrap());
        let back = deserialize_table(&serialize_table(&t)).unwrap();
        assert_eq!(back.num_rows(), 0);
        assert_eq!(back.schema(), t.schema());
    }

    #[test]
    fn truncated_buffer_rejected() {
        let bytes = serialize_table(&table());
        for cut in [0, 3, 10, bytes.len() - 1] {
            assert!(
                deserialize_table(&bytes[..cut]).is_err(),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = serialize_table(&table());
        bytes[0] ^= 0xFF;
        assert!(deserialize_table(&bytes).is_err());
    }

    #[test]
    fn size_is_close_to_byte_size() {
        let t = table();
        let wire = serialize_table(&t).len();
        // Wire adds only header + names on top of the raw buffers.
        assert!(wire < t.byte_size() + 128);
    }

    #[test]
    fn huge_row_count_rejected_before_allocation() {
        // A corrupt frame claiming u64::MAX rows must fail the
        // remaining-bytes check, not reach Vec::with_capacity.
        let mut bytes = serialize_table(&table());
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        let e = deserialize_table(&bytes).unwrap_err();
        assert!(e.to_string().contains("remain"), "{e}");
        // Same for a large-but-plausible lie.
        let mut bytes = serialize_table(&table());
        bytes[8..16].copy_from_slice(&(1u64 << 40).to_le_bytes());
        assert!(deserialize_table(&bytes).is_err());
    }

    #[test]
    fn huge_column_count_rejected_before_allocation() {
        let mut bytes = serialize_table(&table());
        bytes[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = deserialize_table(&bytes).unwrap_err();
        assert!(e.to_string().contains("columns"), "{e}");
    }

    #[test]
    fn utf8_offsets_past_buffer_rejected() {
        // One string column: "ab", "c" (offsets 0,2,3; nbytes 3).
        let t = Table::from_columns(vec![(
            "s",
            Column::from_str(&["ab", "c"]),
        )])
        .unwrap();
        let good = serialize_table(&t);
        assert!(deserialize_table(&good).is_ok());
        // The last offset sits right before `u64 nbytes`+bytes (3+8+3
        // trailing bytes): point it past the string buffer.
        let last_off = good.len() - 3 - 8 - 8;
        let mut bad = good.clone();
        bad[last_off..last_off + 8]
            .copy_from_slice(&u64::MAX.to_le_bytes());
        let e = deserialize_table(&bad).unwrap_err();
        assert!(e.to_string().contains("utf8 offset"), "{e}");
        // A middle offset beyond nbytes (but with the last intact) is
        // equally out of bounds.
        let mid_off = last_off - 8;
        let mut bad = good.clone();
        bad[mid_off..mid_off + 8].copy_from_slice(&100u64.to_le_bytes());
        assert!(deserialize_table(&bad).is_err());
    }

    #[test]
    fn utf8_decreasing_offsets_rejected() {
        let t = Table::from_columns(vec![(
            "s",
            Column::from_str(&["ab", "c"]),
        )])
        .unwrap();
        let good = serialize_table(&t);
        // offsets are 0,2,3 — make the middle one 3 > last (covered by
        // monotonicity: 3 then 3 is fine, so use 0,3,2 via the last).
        let last_off = good.len() - 3 - 8 - 8;
        let mid_off = last_off - 8;
        let mut bad = good.clone();
        bad[mid_off..mid_off + 8].copy_from_slice(&3u64.to_le_bytes());
        bad[last_off..last_off + 8].copy_from_slice(&2u64.to_le_bytes());
        let e = deserialize_table(&bad).unwrap_err();
        assert!(e.to_string().contains("decrease"), "{e}");
    }

    #[test]
    fn utf8_offset_splitting_a_character_rejected() {
        // "é" is 2 bytes; an offset of 1 lands inside it.
        let t = Table::from_columns(vec![(
            "s",
            Column::from_str(&["é"]),
        )])
        .unwrap();
        let good = serialize_table(&t);
        // offsets are 0,2 (then nbytes=2, 2 string bytes).
        let last_off = good.len() - 2 - 8 - 8;
        let mut bad = good.clone();
        bad[last_off..last_off + 8].copy_from_slice(&1u64.to_le_bytes());
        let e = deserialize_table(&bad).unwrap_err();
        assert!(e.to_string().contains("splits"), "{e}");
    }
}
