//! The communication layer — the role OpenMPI plays for Cylon (§III-C).
//!
//! Everything is built on one rendezvous primitive, [`Fabric::exchange`]
//! (a synchronous AllToAllv: every rank contributes a byte buffer per
//! destination and receives one per source), exactly the collective the
//! paper implements "utilizing the asynchronous send and receive
//! capabilities of the underlying communication framework". The MPI-like
//! collectives (barrier / gather / allgather / bcast / allreduce) derive
//! from it in [`collectives`].
//!
//! Three fabrics implement the primitive:
//! * [`local::LocalFabric`] — real shared-memory rendezvous between rank
//!   threads (one thread per worker, paper §III-B). Used by every
//!   correctness test.
//! * [`sim::SimFabric`] — the same rendezvous *plus* a calibrated BSP
//!   cost model: per-rank compute is metered with per-thread CPU clocks
//!   and communication is charged `α·(p−1) + bytes/β`, yielding the
//!   simulated makespan used for the paper's scaling figures on this
//!   single-core box (DESIGN.md §3).
//! * [`tcp::TcpFabric`] — one OS process per rank over TCP sockets
//!   (rendezvous handshake, framed exchange, peer-death detection):
//!   the paper's actual MPI-style deployment model (`docs/NET.md`).

pub mod checked;
pub mod collectives;
pub mod faulty;
pub mod local;
pub mod sim;
pub mod tcp;
pub mod wire;

use std::sync::Arc;

use crate::error::{Result, RylonError};

/// Per-destination byte buffers for one rank's contribution to an
/// exchange. `msgs[d]` goes to rank `d`; empty buffers are allowed.
pub type OutBufs = Vec<Vec<u8>>;

/// The single fault currency of the cluster-wide fault domain: one
/// rank's failure, attributed to `(rank, op, step)`, in a form every
/// other rank can receive — on the wire as a verdict frame
/// ([`checked::CheckedFabric`]) or out-of-band via [`Fabric::abort`].
///
/// `kind`/`msg` are the [`RylonError::to_wire`] flattening of the
/// underlying error; [`Fault::to_error`] reconstitutes the whole thing
/// as [`RylonError::Aborted`] with identical attribution on every rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fault {
    /// The rank whose failure aborted the collective.
    pub rank: usize,
    /// The labelled operation the failing rank was running.
    pub op: String,
    /// The failing rank's collective-step count at the fault point.
    pub step: u64,
    /// [`RylonError::to_wire`] tag of the underlying error.
    pub kind: u8,
    /// Flattened message of the underlying error.
    pub msg: String,
}

impl Fault {
    /// Attribute `err` to `(rank, op, step)`. If `err` is already a
    /// collective abort, its original attribution is preserved so
    /// faults keep their identity as they propagate between ranks.
    pub fn from_error(
        rank: usize,
        op: &str,
        step: u64,
        err: &RylonError,
    ) -> Fault {
        if let Some(i) = err.abort_info() {
            let (kind, msg) = i.source.to_wire();
            return Fault {
                rank: i.rank,
                op: i.op.clone(),
                step: i.step,
                kind,
                msg,
            };
        }
        let (kind, msg) = err.to_wire();
        Fault {
            rank,
            op: op.to_string(),
            step,
            kind,
            msg,
        }
    }

    /// Shorthand for a communication-layer fault.
    pub fn comm(
        rank: usize,
        op: &str,
        step: u64,
        msg: impl Into<String>,
    ) -> Fault {
        Fault::from_error(rank, op, step, &RylonError::comm(msg))
    }

    /// Reconstitute as the rank-attributed error every rank returns.
    pub fn to_error(&self) -> RylonError {
        RylonError::aborted(
            self.rank,
            self.op.clone(),
            self.step,
            RylonError::from_wire(self.kind, self.msg.clone()),
        )
    }

    /// Encode as a little-endian fault frame (the `Err` payload of a
    /// checked-exchange verdict; layout in `docs/FAULTS.md`):
    /// `u32 rank | u64 step | u8 kind | u16 op_len | op | u32 msg_len | msg`.
    pub fn encode(&self) -> Vec<u8> {
        let op = self.op.as_bytes();
        let msg = self.msg.as_bytes();
        let op_len = op.len().min(u16::MAX as usize);
        let msg_len = msg.len().min(u32::MAX as usize);
        let mut out = Vec::with_capacity(19 + op_len + msg_len);
        out.extend_from_slice(&(self.rank as u32).to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.push(self.kind);
        out.extend_from_slice(&(op_len as u16).to_le_bytes());
        out.extend_from_slice(&op[..op_len]);
        out.extend_from_slice(&(msg_len as u32).to_le_bytes());
        out.extend_from_slice(&msg[..msg_len]);
        out
    }

    /// Decode a fault frame produced by [`Fault::encode`].
    pub fn decode(buf: &[u8]) -> Result<Fault> {
        let mut r = wire::Reader::new(buf);
        let rank = r.u32()? as usize;
        let step = r.u64()?;
        let kind = r.u8()?;
        let op_len = r.u16()? as usize;
        let op = String::from_utf8_lossy(r.bytes(op_len)?).into_owned();
        let msg_len = r.u32()? as usize;
        let msg = String::from_utf8_lossy(r.bytes(msg_len)?).into_owned();
        Ok(Fault {
            rank,
            op,
            step,
            kind,
            msg,
        })
    }
}

/// The communication substrate shared by all ranks of one job.
///
/// All methods are called *by rank threads* and block until every rank
/// of the job has arrived (BSP superstep semantics).
pub trait Fabric: Send + Sync {
    /// Number of ranks.
    fn size(&self) -> usize;

    /// Synchronous AllToAllv: deliver `outgoing[d]` to rank `d`; returns
    /// `incoming[s]` = the buffer rank `s` addressed to us.
    fn exchange(&self, rank: usize, outgoing: OutBufs) -> Result<OutBufs>;

    /// Fold the calling rank's compute time accrued since its last
    /// fabric call into the fabric's cost model (no-op on fabrics
    /// without a model).
    fn tick_compute(&self, rank: usize) {
        let _ = rank;
    }

    /// Simulated elapsed seconds for `rank` (wall-clock fabrics return
    /// `None`; callers fall back to real timers).
    fn model_time(&self, rank: usize) -> Option<f64> {
        let _ = rank;
        None
    }

    /// Total bytes posted to this fabric across all exchanges (metrics).
    fn bytes_sent(&self) -> u64 {
        0
    }

    /// The fault currently poisoning this fabric, if any. While set,
    /// every `exchange` fails fast with the same attributed error.
    fn fault(&self) -> Option<Fault> {
        None
    }

    /// Record `fault` and wake every rank parked in a collective so the
    /// abort is delivered symmetrically. First fault wins; later calls
    /// are no-ops. Must succeed even if a rank panicked mid-exchange.
    fn abort(&self, fault: Fault) {
        let _ = fault;
    }

    /// Clear a recorded fault and reset the rendezvous state. Only safe
    /// between jobs, when no rank thread is inside an exchange.
    fn clear_fault(&self) {}

    /// Cumulative count of faults recorded on this fabric (one per
    /// aborted collective; survives [`Fabric::clear_fault`]).
    fn aborts(&self) -> u64 {
        0
    }

    /// `rank`'s completed-collective count (step attribution for
    /// faults). Fabrics without per-rank counters return 0.
    fn steps(&self, rank: usize) -> u64 {
        let _ = rank;
        0
    }
}

/// Shared handle to a fabric.
pub type FabricRef = Arc<dyn Fabric>;

/// Reduction operators for `allreduce`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

impl ReduceOp {
    pub fn fold(&self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
        }
    }
}

/// Cost-model parameters for the simulated fabric, calibrated to the
/// paper's testbed (40 Gbps Infiniband, OpenMPI, 40 cores/node; §V
/// "Hardware Setup").
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-message latency in seconds (MPI pt2pt over IB ≈ a few µs).
    pub alpha: f64,
    /// Cross-node link bandwidth in bytes/second.
    pub beta: f64,
    /// Ranks per node (40 in the paper's runs) — ranks on the same node
    /// exchange through shared memory at `beta_local`.
    pub ranks_per_node: usize,
    /// Intra-node bandwidth in bytes/second (shared-memory copies).
    pub beta_local: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            alpha: 5e-6,           // 5 µs MPI message setup
            beta: 40e9 / 8.0 * 0.8, // 40 Gbps IB × 80% protocol efficiency
            ranks_per_node: 40,
            beta_local: 8e9, // shared-memory copy bandwidth
        }
    }
}

impl CostModel {
    /// Seconds to move `bytes` between `src` and `dst` ranks.
    pub fn pt2pt_cost(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        if src == dst {
            // Local "send to self" is a buffer move.
            return bytes as f64 / self.beta_local;
        }
        let same_node =
            src / self.ranks_per_node == dst / self.ranks_per_node;
        let bw = if same_node { self.beta_local } else { self.beta };
        self.alpha + bytes as f64 / bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_ops() {
        assert_eq!(ReduceOp::Sum.fold(1.0, 2.0), 3.0);
        assert_eq!(ReduceOp::Min.fold(1.0, 2.0), 1.0);
        assert_eq!(ReduceOp::Max.fold(1.0, 2.0), 2.0);
    }

    #[test]
    fn fault_frame_roundtrip() {
        let f = Fault::from_error(
            3,
            "dist_sort",
            17,
            &RylonError::parse("bad float \"x\""),
        );
        let back = Fault::decode(&f.encode()).unwrap();
        assert_eq!(back, f);
        let e = back.to_error();
        let i = e.abort_info().unwrap();
        assert_eq!((i.rank, i.op.as_str(), i.step), (3, "dist_sort", 17));
        assert!(matches!(*i.source, RylonError::Parse(_)));
        assert!(e.to_string().contains("bad float"));
    }

    #[test]
    fn fault_from_aborted_error_preserves_attribution() {
        let original = Fault::comm(1, "shuffle", 4, "injected");
        // A peer wrapping the received abort must not re-attribute it.
        let rewrapped =
            Fault::from_error(2, "job", 9, &original.to_error());
        assert_eq!(rewrapped, original);
    }

    #[test]
    fn fault_decode_rejects_truncation() {
        let enc = Fault::comm(0, "op", 1, "message text").encode();
        for cut in [0, 4, 12, enc.len() - 1] {
            assert!(Fault::decode(&enc[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn cost_model_shape() {
        let m = CostModel::default();
        // Latency dominates tiny messages.
        assert!(m.pt2pt_cost(0, 100, 8) >= m.alpha);
        // Same-node transfers are cheaper than cross-node.
        assert!(
            m.pt2pt_cost(0, 1, 1_000_000) < m.pt2pt_cost(0, 100, 1_000_000)
        );
        // Self-delivery has no latency term.
        assert_eq!(m.pt2pt_cost(3, 3, 0), 0.0);
    }
}
