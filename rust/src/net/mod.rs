//! The communication layer — the role OpenMPI plays for Cylon (§III-C).
//!
//! Everything is built on one rendezvous primitive, [`Fabric::exchange`]
//! (a synchronous AllToAllv: every rank contributes a byte buffer per
//! destination and receives one per source), exactly the collective the
//! paper implements "utilizing the asynchronous send and receive
//! capabilities of the underlying communication framework". The MPI-like
//! collectives (barrier / gather / allgather / bcast / allreduce) derive
//! from it in [`collectives`].
//!
//! Two fabrics implement the primitive:
//! * [`local::LocalFabric`] — real shared-memory rendezvous between rank
//!   threads (one thread per worker, paper §III-B). Used by every
//!   correctness test.
//! * [`sim::SimFabric`] — the same rendezvous *plus* a calibrated BSP
//!   cost model: per-rank compute is metered with per-thread CPU clocks
//!   and communication is charged `α·(p−1) + bytes/β`, yielding the
//!   simulated makespan used for the paper's scaling figures on this
//!   single-core box (DESIGN.md §3).

pub mod collectives;
pub mod local;
pub mod sim;
pub mod wire;

use std::sync::Arc;

use crate::error::Result;

/// Per-destination byte buffers for one rank's contribution to an
/// exchange. `msgs[d]` goes to rank `d`; empty buffers are allowed.
pub type OutBufs = Vec<Vec<u8>>;

/// The communication substrate shared by all ranks of one job.
///
/// All methods are called *by rank threads* and block until every rank
/// of the job has arrived (BSP superstep semantics).
pub trait Fabric: Send + Sync {
    /// Number of ranks.
    fn size(&self) -> usize;

    /// Synchronous AllToAllv: deliver `outgoing[d]` to rank `d`; returns
    /// `incoming[s]` = the buffer rank `s` addressed to us.
    fn exchange(&self, rank: usize, outgoing: OutBufs) -> Result<OutBufs>;

    /// Fold the calling rank's compute time accrued since its last
    /// fabric call into the fabric's cost model (no-op on fabrics
    /// without a model).
    fn tick_compute(&self, rank: usize) {
        let _ = rank;
    }

    /// Simulated elapsed seconds for `rank` (wall-clock fabrics return
    /// `None`; callers fall back to real timers).
    fn model_time(&self, rank: usize) -> Option<f64> {
        let _ = rank;
        None
    }

    /// Total bytes posted to this fabric across all exchanges (metrics).
    fn bytes_sent(&self) -> u64 {
        0
    }
}

/// Shared handle to a fabric.
pub type FabricRef = Arc<dyn Fabric>;

/// Reduction operators for `allreduce`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

impl ReduceOp {
    pub fn fold(&self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
        }
    }
}

/// Cost-model parameters for the simulated fabric, calibrated to the
/// paper's testbed (40 Gbps Infiniband, OpenMPI, 40 cores/node; §V
/// "Hardware Setup").
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-message latency in seconds (MPI pt2pt over IB ≈ a few µs).
    pub alpha: f64,
    /// Cross-node link bandwidth in bytes/second.
    pub beta: f64,
    /// Ranks per node (40 in the paper's runs) — ranks on the same node
    /// exchange through shared memory at `beta_local`.
    pub ranks_per_node: usize,
    /// Intra-node bandwidth in bytes/second (shared-memory copies).
    pub beta_local: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            alpha: 5e-6,           // 5 µs MPI message setup
            beta: 40e9 / 8.0 * 0.8, // 40 Gbps IB × 80% protocol efficiency
            ranks_per_node: 40,
            beta_local: 8e9, // shared-memory copy bandwidth
        }
    }
}

impl CostModel {
    /// Seconds to move `bytes` between `src` and `dst` ranks.
    pub fn pt2pt_cost(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        if src == dst {
            // Local "send to self" is a buffer move.
            return bytes as f64 / self.beta_local;
        }
        let same_node =
            src / self.ranks_per_node == dst / self.ranks_per_node;
        let bw = if same_node { self.beta_local } else { self.beta };
        self.alpha + bytes as f64 / bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_ops() {
        assert_eq!(ReduceOp::Sum.fold(1.0, 2.0), 3.0);
        assert_eq!(ReduceOp::Min.fold(1.0, 2.0), 1.0);
        assert_eq!(ReduceOp::Max.fold(1.0, 2.0), 2.0);
    }

    #[test]
    fn cost_model_shape() {
        let m = CostModel::default();
        // Latency dominates tiny messages.
        assert!(m.pt2pt_cost(0, 100, 8) >= m.alpha);
        // Same-node transfers are cheaper than cross-node.
        assert!(
            m.pt2pt_cost(0, 1, 1_000_000) < m.pt2pt_cost(0, 100, 1_000_000)
        );
        // Self-delivery has no latency term.
        assert_eq!(m.pt2pt_cost(3, 3, 0), 0.0);
    }
}
