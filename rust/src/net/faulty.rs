//! Deterministic fault injection for the cluster fabric
//! (`docs/FAULTS.md`).
//!
//! [`FaultyFabric`] wraps any inner [`Fabric`] and fires the faults of a
//! [`FaultPlan`] — parsed from the `[exec] fault_plan` knob / the
//! `FAULT_PLAN` env var — at exact `(rank, exchange)` coordinates:
//!
//! ```text
//! plan      := entry ("," entry)*  |  ""        (empty = no faults)
//! entry     := kind "@" rank ":" exchange
//! kind      := "error" | "panic" | "exit" | "delay" MILLIS
//! ```
//!
//! `error@1:2` makes rank 1's third exchange return a comm error;
//! `panic@0:0` panics rank 0 on its first exchange; `delay250@2:1`
//! parks rank 2 for 250 ms before its second exchange (pair with
//! `[exec] collective_timeout_ms` to turn the hang into a symmetric
//! abort); `exit@1:3` kills rank 1's **whole OS process**
//! (`std::process::exit`, no unwinding, no goodbye) at its fourth
//! exchange — the deterministic stand-in for SIGKILL that the TCP
//! fabric's peer-death tests are built on (meaningless on the
//! in-process fabrics, where it would take every rank down; the CI
//! kill-a-rank leg uses it only under `--fabric tcp`). Plans are fully
//! explicit — no RNG — so every injection is reproducible by
//! construction. Entries whose rank is outside the world size simply
//! never fire, letting one process-wide `FAULT_PLAN` target a specific
//! world size.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::error::{Result, RylonError};
use crate::net::{Fabric, FabricRef, Fault, OutBufs};

/// What a fault-plan entry does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The exchange returns a comm error on the injected rank.
    Error,
    /// The injected rank panics (exercising the panic→abort route).
    Panic,
    /// The injected rank's whole process exits immediately (code
    /// [`EXIT_CODE`], no unwinding) — deterministic peer death for the
    /// multi-process TCP fabric's survivor tests.
    Exit,
    /// The injected rank sleeps this many milliseconds, then proceeds.
    Delay(u64),
}

/// Exit code of an `exit@rank:exchange` injection, distinct from the
/// CLI's generic failure code 1 so the launcher's report shows *which*
/// failure mode a dead rank took.
pub const EXIT_CODE: i32 = 86;

/// One injection point: fire `kind` when `rank` makes its
/// `exchange`-th fabric exchange (0-based).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPoint {
    /// Rank the fault fires on.
    pub rank: usize,
    /// 0-based exchange index it fires at.
    pub exchange: u64,
    /// What happens.
    pub kind: FaultKind,
}

/// A parsed `[exec] fault_plan`: a fixed set of injection points.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    points: Vec<FaultPoint>,
}

impl FaultPlan {
    /// Parse the plan grammar (see module docs). Empty input (or all
    /// whitespace) is the empty plan.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut points = Vec::new();
        for entry in s.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind_s, at) = entry.split_once('@').ok_or_else(|| {
                RylonError::invalid(format!(
                    "fault plan entry '{entry}': expected \
                     kind@rank:exchange"
                ))
            })?;
            let (rank_s, exch_s) = at.split_once(':').ok_or_else(|| {
                RylonError::invalid(format!(
                    "fault plan entry '{entry}': expected \
                     kind@rank:exchange"
                ))
            })?;
            let rank: usize = rank_s.trim().parse().map_err(|_| {
                RylonError::invalid(format!(
                    "fault plan entry '{entry}': bad rank '{rank_s}'"
                ))
            })?;
            let exchange: u64 = exch_s.trim().parse().map_err(|_| {
                RylonError::invalid(format!(
                    "fault plan entry '{entry}': bad exchange \
                     '{exch_s}'"
                ))
            })?;
            let kind_s = kind_s.trim();
            let kind = match kind_s {
                "error" => FaultKind::Error,
                "panic" => FaultKind::Panic,
                "exit" => FaultKind::Exit,
                _ => match kind_s.strip_prefix("delay") {
                    Some(ms_s) => {
                        let ms: u64 = ms_s.parse().map_err(|_| {
                            RylonError::invalid(format!(
                                "fault plan entry '{entry}': bad \
                                 delay millis '{ms_s}'"
                            ))
                        })?;
                        FaultKind::Delay(ms)
                    }
                    None => {
                        return Err(RylonError::invalid(format!(
                            "fault plan entry '{entry}': unknown kind \
                             '{kind_s}' (error|panic|exit|delayMS)"
                        )))
                    }
                },
            };
            points.push(FaultPoint {
                rank,
                exchange,
                kind,
            });
        }
        Ok(FaultPlan { points })
    }

    /// True if the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The injection points.
    pub fn points(&self) -> &[FaultPoint] {
        &self.points
    }

    fn hit(&self, rank: usize, exchange: u64) -> Option<FaultPoint> {
        self.points
            .iter()
            .copied()
            .find(|p| p.rank == rank && p.exchange == exchange)
    }
}

/// Fabric decorator firing a [`FaultPlan`] at exact
/// `(rank, exchange)` coordinates.
pub struct FaultyFabric {
    inner: FabricRef,
    plan: FaultPlan,
    /// Per-rank exchange counters (the plan's exchange coordinate).
    counts: Vec<AtomicU64>,
    injected: AtomicU64,
}

impl FaultyFabric {
    /// Wrap `inner`, injecting `plan`.
    pub fn new(inner: FabricRef, plan: FaultPlan) -> FaultyFabric {
        let counts =
            (0..inner.size()).map(|_| AtomicU64::new(0)).collect();
        FaultyFabric {
            inner,
            plan,
            counts,
            injected: AtomicU64::new(0),
        }
    }

    /// Number of faults the plan has fired so far.
    pub fn injected_faults(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }
}

impl Fabric for FaultyFabric {
    fn size(&self) -> usize {
        self.inner.size()
    }

    fn exchange(&self, rank: usize, outgoing: OutBufs) -> Result<OutBufs> {
        let n = self.counts[rank].fetch_add(1, Ordering::Relaxed);
        if let Some(p) = self.plan.hit(rank, n) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            match p.kind {
                FaultKind::Error => {
                    return Err(RylonError::comm(format!(
                        "injected fault at rank {rank}, exchange #{n}"
                    )))
                }
                FaultKind::Panic => {
                    panic!("injected panic at rank {rank}, exchange #{n}")
                }
                FaultKind::Exit => {
                    // Deterministic SIGKILL stand-in: no unwinding, no
                    // Drop impls, no goodbye frames. Peers must detect
                    // the death through the fabric (EOF on TCP).
                    eprintln!(
                        "injected exit at rank {rank}, exchange #{n}"
                    );
                    std::process::exit(EXIT_CODE);
                }
                FaultKind::Delay(ms) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
            }
        }
        self.inner.exchange(rank, outgoing)
    }

    fn tick_compute(&self, rank: usize) {
        self.inner.tick_compute(rank)
    }

    fn model_time(&self, rank: usize) -> Option<f64> {
        self.inner.model_time(rank)
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }

    fn fault(&self) -> Option<Fault> {
        self.inner.fault()
    }

    fn abort(&self, fault: Fault) {
        self.inner.abort(fault)
    }

    fn clear_fault(&self) {
        self.inner.clear_fault()
    }

    fn aborts(&self) -> u64 {
        self.inner.aborts()
    }

    fn steps(&self, rank: usize) -> u64 {
        self.inner.steps(rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::local::LocalFabric;
    use std::sync::Arc;

    #[test]
    fn plan_grammar_parses() {
        let plan = FaultPlan::parse(
            "error@1:2, panic@0:0,delay250@2:1, exit@3:4",
        )
        .unwrap();
        assert_eq!(
            plan.points(),
            &[
                FaultPoint {
                    rank: 1,
                    exchange: 2,
                    kind: FaultKind::Error
                },
                FaultPoint {
                    rank: 0,
                    exchange: 0,
                    kind: FaultKind::Panic
                },
                FaultPoint {
                    rank: 2,
                    exchange: 1,
                    kind: FaultKind::Delay(250)
                },
                FaultPoint {
                    rank: 3,
                    exchange: 4,
                    kind: FaultKind::Exit
                },
            ]
        );
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ").unwrap().is_empty());
    }

    #[test]
    fn plan_grammar_rejects_garbage() {
        for bad in [
            "error",
            "error@1",
            "error@x:1",
            "error@1:y",
            "explode@1:1",
            "delay@1:1",
            "delayxx@1:1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn error_fires_at_exact_coordinates() {
        let plan = FaultPlan::parse("error@0:1").unwrap();
        let fab =
            FaultyFabric::new(Arc::new(LocalFabric::new(1)), plan);
        assert!(fab.exchange(0, vec![vec![]]).is_ok());
        assert_eq!(fab.injected_faults(), 0);
        let e = fab.exchange(0, vec![vec![]]).unwrap_err();
        assert!(e.to_string().contains("injected fault"));
        assert_eq!(fab.injected_faults(), 1);
        // Counter advanced past the point: later exchanges are clean.
        assert!(fab.exchange(0, vec![vec![]]).is_ok());
    }

    #[test]
    fn out_of_range_rank_never_fires() {
        let plan = FaultPlan::parse("error@5:0").unwrap();
        let fab =
            FaultyFabric::new(Arc::new(LocalFabric::new(1)), plan);
        assert!(fab.exchange(0, vec![vec![]]).is_ok());
        assert_eq!(fab.injected_faults(), 0);
    }
}
