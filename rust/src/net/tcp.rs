//! Multi-process fabric: one OS process per rank, framed over TCP —
//! the paper's actual deployment model (one MPI process per worker)
//! rather than the in-process rank threads of [`crate::net::local`].
//!
//! ## Rendezvous
//!
//! Rank 0 listens at the rendezvous address (`[cluster] rendezvous`,
//! `RYLON_RENDEZVOUS`, `--rendezvous`); every other rank connects to it
//! (retrying until `TcpOpts::connect_timeout_ms`) and sends a
//! versioned HELLO carrying its rank and the port of its own data
//! listener. Rank 0 validates version / world size / rank uniqueness
//! and answers with a WELCOME carrying the full address table; the
//! rendezvous connections then *become* the rank-0 data edges, and the
//! remaining mesh edges are built deterministically — rank `j`
//! connects to every rank `i` with `0 < i < j` and identifies itself
//! with the same HELLO frame. The result is a full mesh: one duplex
//! TCP stream per rank pair.
//!
//! ## Framing
//!
//! Every message is `u32 magic | u8 type | u64 seq | u64 len | payload`
//! with three frame types: `DATA` (one exchange contribution, payload
//! encoded by the caller — the shuffle uses [`crate::net::wire`]),
//! `ABORT` (an encoded [`Fault`], the out-of-band half of the fault
//! domain), and `BYE` (graceful departure, sent on drop). Payloads are
//! read in bounded slabs, so a corrupt length field cannot make a rank
//! allocate the claimed size up front.
//!
//! ## Peer death and the fault domain
//!
//! A per-peer reader thread drains frames into a sequence-keyed inbox.
//! EOF or a socket error *without* a preceding `BYE` is a dead peer:
//! the reader synthesizes a rank-attributed [`Fault`] and wakes every
//! waiter, so survivors abort symmetrically instead of hanging — and
//! [`Fabric::abort`] broadcasts `ABORT` frames so error-path failures
//! propagate before the socket even closes. A non-zero collective
//! timeout ([`crate::exec::COLLECTIVE_TIMEOUT_MS`]) bounds the wait
//! for silent hangs, blaming the lowest rank that never delivered.
//! Wrapped in [`crate::net::checked::CheckedFabric`] by
//! `dist::Cluster` (like every fabric), in-band verdicts work
//! unchanged, so the TCP transport joins the PR 6 fault domain by
//! construction.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Result, RylonError};
use crate::net::{Fabric, Fault, OutBufs};

/// Handshake/framing protocol version; bumped on any wire change. A
/// peer with a different version is rejected at rendezvous, not
/// mid-shuffle.
pub const WIRE_VERSION: u16 = 1;

/// `"RYLH"` — hello/welcome handshake frames.
const HELLO_MAGIC: u32 = 0x524C_594C;
/// `"RYLT"` — data/abort/bye frames after the handshake.
const FRAME_MAGIC: u32 = 0x544C_594C;
/// Frame header: magic u32 | type u8 | seq u64 | len u64.
const FRAME_HEADER: usize = 21;
const FRAME_DATA: u8 = 1;
const FRAME_ABORT: u8 = 2;
const FRAME_BYE: u8 = 3;
/// Payloads are pulled in slabs this large, so a frame header lying
/// about its length can never make a rank allocate the claimed size
/// up front — it just hits EOF and becomes a dead-peer fault.
const READ_SLAB: usize = 4 << 20;

/// Per-process options for joining a TCP job: which rank this process
/// is, and where to meet the others.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TcpOpts {
    /// This process's rank (`0..world`). Rank 0 hosts the rendezvous.
    pub rank: usize,
    /// Rendezvous address (`host:port`). Rank 0 binds it; every other
    /// rank connects to it.
    pub rendezvous: String,
    /// Handshake budget in milliseconds: connect retries, hello
    /// exchange, and mesh construction must all finish within it.
    pub connect_timeout_ms: u64,
}

impl TcpOpts {
    /// Options for `rank` meeting its peers at `rendezvous`, with the
    /// default 20 s handshake budget.
    pub fn new(rank: usize, rendezvous: impl Into<String>) -> TcpOpts {
        TcpOpts {
            rank,
            rendezvous: rendezvous.into(),
            connect_timeout_ms: 20_000,
        }
    }

    /// Override the handshake budget.
    pub fn with_connect_timeout_ms(mut self, ms: u64) -> TcpOpts {
        self.connect_timeout_ms = ms;
        self
    }
}

/// Receiver-side state shared between the rank thread and the per-peer
/// reader threads.
struct RecvState {
    /// `inbox[seq][src]`: contributions to exchange `seq`. Peers can
    /// run at most one exchange ahead (they block on *our* frame to
    /// finish theirs), so this holds at most two live generations.
    inbox: HashMap<u64, Vec<Option<Vec<u8>>>>,
    /// The fault poisoning this fabric, if any. First fault wins.
    fault: Option<Fault>,
    /// Peers that sent `BYE`: their EOF is a clean departure, and any
    /// exchange still expecting them faults immediately.
    departed: Vec<bool>,
    /// Set by drop/[`TcpFabric::sever`]: our own readers' EOFs are
    /// teardown, not peer death.
    shutdown: bool,
}

struct Shared {
    size: usize,
    rank: usize,
    /// The sequence number of the exchange the rank thread is in (for
    /// step attribution of reader-thread faults).
    cur_seq: AtomicU64,
    state: Mutex<RecvState>,
    cond: Condvar,
    aborts: AtomicU64,
}

impl Shared {
    /// Reader threads never panic while holding the lock, but a rank
    /// thread interrupted mid-exchange can poison it; the state stays
    /// consistent either way.
    fn lock_state(&self) -> MutexGuard<'_, RecvState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn deliver(&self, src: usize, seq: u64, payload: Vec<u8>) {
        let size = self.size;
        let mut st = self.lock_state();
        let slots = st.inbox.entry(seq).or_insert_with(|| vec![None; size]);
        slots[src] = Some(payload);
        self.cond.notify_all();
    }

    fn record_fault(&self, fault: Fault) {
        let mut st = self.lock_state();
        if st.fault.is_none() {
            st.fault = Some(fault);
            self.aborts.fetch_add(1, Ordering::Relaxed);
        }
        self.cond.notify_all();
    }

    fn mark_departed(&self, src: usize) {
        let mut st = self.lock_state();
        st.departed[src] = true;
        self.cond.notify_all();
    }

    /// A peer's stream closed. After a `BYE` (or during our own
    /// teardown) that is expected; otherwise the peer died and the
    /// survivors must abort symmetrically.
    fn on_disconnect(&self, src: usize, cause: &str) {
        let step = self.cur_seq.load(Ordering::Relaxed);
        let mut st = self.lock_state();
        if st.shutdown || st.departed[src] || st.fault.is_some() {
            self.cond.notify_all();
            return;
        }
        let fault = Fault::comm(
            src,
            "exchange",
            step,
            format!(
                "rank {src} died: {cause} with no goodbye (observed by \
                 rank {} around exchange #{step})",
                self.rank
            ),
        );
        st.fault = Some(fault);
        self.aborts.fetch_add(1, Ordering::Relaxed);
        self.cond.notify_all();
    }
}

/// One rank's endpoint of a TCP job: a full mesh of duplex streams,
/// per-peer reader threads, and the sequence-keyed inbox `exchange`
/// rendezvouses on. Build one per process with [`TcpFabric::connect`]
/// (or let `dist::Cluster::new` do it from a
/// `FabricKind::Tcp`).
pub struct TcpFabric {
    shared: Arc<Shared>,
    /// Write halves of the mesh, indexed by peer rank (`None` at our
    /// own slot). A mutex per peer keeps concurrent frame writes (the
    /// rank thread's DATA vs an abort broadcast) from interleaving.
    writers: Vec<Option<Mutex<TcpStream>>>,
    readers: Mutex<Vec<JoinHandle<()>>>,
    /// Completed-exchange counter; doubles as the next DATA seq.
    seq: AtomicU64,
    bytes: AtomicU64,
    timeout: Option<Duration>,
}

impl TcpFabric {
    /// Join a `world`-rank job as `opts.rank`: rendezvous, handshake,
    /// build the mesh, and spawn the reader threads. Blocks until
    /// every rank has joined or the handshake budget runs out.
    pub fn connect(
        world: usize,
        opts: &TcpOpts,
        timeout: Option<Duration>,
    ) -> Result<TcpFabric> {
        if world == 0 {
            return Err(RylonError::invalid("tcp fabric needs world ≥ 1"));
        }
        if opts.rank >= world {
            return Err(RylonError::invalid(format!(
                "tcp fabric: rank {} outside world {world}",
                opts.rank
            )));
        }
        let deadline = Instant::now()
            + Duration::from_millis(opts.connect_timeout_ms.max(1));
        // World 1 has nobody to meet: the rendezvous address is never
        // touched and every exchange is pure self-delivery.
        let streams = if world == 1 {
            vec![None]
        } else if opts.rank == 0 {
            rendezvous_rank0(world, &opts.rendezvous, deadline)?
        } else {
            rendezvous_peer(world, opts.rank, &opts.rendezvous, deadline)?
        };
        let shared = Arc::new(Shared {
            size: world,
            rank: opts.rank,
            cur_seq: AtomicU64::new(0),
            state: Mutex::new(RecvState {
                inbox: HashMap::new(),
                fault: None,
                departed: vec![false; world],
                shutdown: false,
            }),
            cond: Condvar::new(),
            aborts: AtomicU64::new(0),
        });
        let mut writers: Vec<Option<Mutex<TcpStream>>> =
            Vec::with_capacity(world);
        let mut readers = Vec::new();
        for (peer, slot) in streams.into_iter().enumerate() {
            let Some(stream) = slot else {
                writers.push(None);
                continue;
            };
            let read_half = stream.try_clone().map_err(|e| {
                RylonError::comm(format!(
                    "tcp rank {}: cannot clone the rank-{peer} stream: {e}",
                    opts.rank
                ))
            })?;
            let sh = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("rylon-tcp-rx{peer}"))
                .spawn(move || reader_loop(sh, peer, read_half))
                .map_err(|e| {
                    RylonError::comm(format!(
                        "tcp rank {}: cannot spawn the rank-{peer} \
                         reader thread: {e}",
                        opts.rank
                    ))
                })?;
            readers.push(handle);
            writers.push(Some(Mutex::new(stream)));
        }
        Ok(TcpFabric {
            shared,
            writers,
            readers: Mutex::new(readers),
            seq: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            timeout,
        })
    }

    /// This process's rank.
    pub fn rank(&self) -> usize {
        self.shared.rank
    }

    /// Write one frame to `dst` (no-op at our own slot — self
    /// contributions are delivered straight to the inbox).
    fn send_frame(
        &self,
        dst: usize,
        kind: u8,
        seq: u64,
        payload: &[u8],
    ) -> std::io::Result<()> {
        let Some(writer) = self.writers[dst].as_ref() else {
            return Ok(());
        };
        let mut header = [0u8; FRAME_HEADER];
        header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        header[4] = kind;
        header[5..13].copy_from_slice(&seq.to_le_bytes());
        header[13..21]
            .copy_from_slice(&(payload.len() as u64).to_le_bytes());
        let mut s = writer.lock().unwrap_or_else(PoisonError::into_inner);
        s.write_all(&header)?;
        s.write_all(payload)?;
        s.flush()
    }

    /// Best-effort `ABORT` broadcast so peers learn of a failure even
    /// before this process's sockets close.
    fn broadcast_abort(&self, fault: &Fault) {
        let payload = fault.encode();
        let seq = self.shared.cur_seq.load(Ordering::Relaxed);
        for peer in 0..self.writers.len() {
            let _ = self.send_frame(peer, FRAME_ABORT, seq, &payload);
        }
    }

    /// Record `fault`, broadcast it, and return it as the attributed
    /// error — the single failure path of `exchange`.
    fn fail_exchange(&self, fault: Fault) -> RylonError {
        self.shared.record_fault(fault.clone());
        self.broadcast_abort(&fault);
        fault.to_error()
    }

    /// Test hook: hard-close every stream *without* a goodbye,
    /// simulating this process dying (`SIGKILL`). Peers observe raw
    /// EOF and must abort symmetrically with this rank attributed.
    #[doc(hidden)]
    pub fn sever(&self) {
        {
            let mut st = self.shared.lock_state();
            st.shutdown = true;
        }
        self.shared.cond.notify_all();
        for writer in self.writers.iter().flatten() {
            let s = writer.lock().unwrap_or_else(PoisonError::into_inner);
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl Fabric for TcpFabric {
    fn size(&self) -> usize {
        self.shared.size
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn exchange(&self, rank: usize, outgoing: OutBufs) -> Result<OutBufs> {
        let size = self.shared.size;
        let me = self.shared.rank;
        if rank != me {
            return Err(RylonError::comm(format!(
                "tcp fabric: exchange as rank {rank}, but this process \
                 is rank {me}"
            )));
        }
        if outgoing.len() != size {
            return Err(RylonError::comm(format!(
                "exchange from rank {rank}: {} buffers for {size} ranks",
                outgoing.len()
            )));
        }
        {
            let st = self.shared.lock_state();
            if let Some(f) = &st.fault {
                return Err(f.to_error());
            }
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.shared.cur_seq.store(seq, Ordering::Relaxed);
        // Meter posted bytes exactly like the in-process fabrics (the
        // sum over all destinations, self included) so the sim
        // fabric's `bytes_sent` is a valid cross-check oracle.
        let posted: usize = outgoing.iter().map(|b| b.len()).sum();
        self.bytes.fetch_add(posted as u64, Ordering::Relaxed);
        let deadline = self.timeout.map(|t| Instant::now() + t);

        // Post: frames to every peer, direct delivery to ourselves.
        for (dst, buf) in outgoing.into_iter().enumerate() {
            if dst == rank {
                self.shared.deliver(rank, seq, buf);
                continue;
            }
            if let Err(e) = self.send_frame(dst, FRAME_DATA, seq, &buf) {
                return Err(self.fail_exchange(Fault::comm(
                    dst,
                    "exchange",
                    seq,
                    format!(
                        "rank {dst} unreachable in exchange #{seq}: {e}"
                    ),
                )));
            }
        }

        // Collect: wait until every rank's contribution has arrived.
        let mut st = self.shared.lock_state();
        loop {
            if let Some(f) = &st.fault {
                return Err(f.to_error());
            }
            let mut complete = true;
            let mut dead: Option<usize> = None;
            {
                let slots = st.inbox.get(&seq);
                for src in 0..size {
                    let filled =
                        slots.is_some_and(|sl| sl[src].is_some());
                    if !filled {
                        complete = false;
                        if st.departed[src] {
                            dead = Some(src);
                            break;
                        }
                    }
                }
            }
            if let Some(src) = dead {
                drop(st);
                return Err(self.fail_exchange(Fault::comm(
                    src,
                    "exchange",
                    seq,
                    format!(
                        "rank {src} left the job before exchange #{seq} \
                         completed"
                    ),
                )));
            }
            if complete {
                break;
            }
            match deadline {
                None => {
                    st = self
                        .shared
                        .cond
                        .wait(st)
                        .unwrap_or_else(PoisonError::into_inner);
                }
                Some(dl) => {
                    let now = Instant::now();
                    if now >= dl {
                        let missing: Vec<usize> = match st.inbox.get(&seq)
                        {
                            Some(sl) => (0..size)
                                .filter(|&s| sl[s].is_none())
                                .collect(),
                            None => (0..size).collect(),
                        };
                        let culprit =
                            missing.first().copied().unwrap_or(rank);
                        let timeout = self.timeout.unwrap_or_default();
                        drop(st);
                        return Err(self.fail_exchange(Fault::comm(
                            culprit,
                            "exchange",
                            seq,
                            format!(
                                "collective timed out after {timeout:?}: \
                                 rank(s) {missing:?} never delivered to \
                                 rank {rank} in exchange #{seq}"
                            ),
                        )));
                    }
                    let (guard, _) = self
                        .shared
                        .cond
                        .wait_timeout(st, dl - now)
                        .unwrap_or_else(PoisonError::into_inner);
                    st = guard;
                }
            }
        }
        let slots = st.inbox.remove(&seq).unwrap_or_default();
        drop(st);
        Ok(slots.into_iter().map(|b| b.unwrap_or_default()).collect())
    }

    fn fault(&self) -> Option<Fault> {
        self.shared.lock_state().fault.clone()
    }

    fn abort(&self, fault: Fault) {
        self.broadcast_abort(&fault);
        self.shared.record_fault(fault);
    }

    /// Local-only: clears this process's recorded fault and drops any
    /// half-collected generations. Peers clear their own ends — after
    /// a real peer death the job cannot continue (the mesh has a hole)
    /// and the process should be relaunched; clearing mainly serves
    /// world-1 jobs and in-process test harnesses.
    fn clear_fault(&self) {
        let mut st = self.shared.lock_state();
        st.fault = None;
        st.inbox.clear();
        self.shared.cond.notify_all();
    }

    fn aborts(&self) -> u64 {
        self.shared.aborts.load(Ordering::Relaxed)
    }

    fn steps(&self, rank: usize) -> u64 {
        if rank == self.shared.rank {
            self.seq.load(Ordering::Relaxed)
        } else {
            0
        }
    }
}

impl Drop for TcpFabric {
    /// Graceful teardown: tell every peer goodbye (so our EOF is a
    /// departure, not a death), close the sockets, and join the reader
    /// threads.
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock_state();
            st.shutdown = true;
        }
        let seq = self.seq.load(Ordering::Relaxed);
        for peer in 0..self.writers.len() {
            let _ = self.send_frame(peer, FRAME_BYE, seq, &[]);
        }
        for writer in self.writers.iter().flatten() {
            let s = writer.lock().unwrap_or_else(PoisonError::into_inner);
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        let handles = std::mem::take(
            &mut *self.readers.lock().unwrap_or_else(PoisonError::into_inner),
        );
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Drain frames from one peer into the shared inbox until the stream
/// closes. Runs on a dedicated thread per peer.
fn reader_loop(shared: Arc<Shared>, src: usize, mut stream: TcpStream) {
    let mut header = [0u8; FRAME_HEADER];
    loop {
        if let Err(e) = stream.read_exact(&mut header) {
            shared.on_disconnect(src, &format!("connection closed ({e})"));
            return;
        }
        let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
        let kind = header[4];
        let seq = u64::from_le_bytes(header[5..13].try_into().unwrap());
        let len =
            u64::from_le_bytes(header[13..21].try_into().unwrap()) as usize;
        if magic != FRAME_MAGIC {
            // The stream is desynchronized — nothing after this point
            // can be trusted, so treat it like a dead peer with a
            // more precise cause.
            shared.record_fault(Fault::comm(
                src,
                "exchange",
                seq,
                format!(
                    "rank {src} sent a frame with bad magic {magic:#010x} \
                     (stream desynchronized)"
                ),
            ));
            return;
        }
        // Pull the payload in bounded slabs: a lying length field
        // costs at most one slab of memory before EOF surfaces.
        let mut payload = Vec::new();
        let mut left = len;
        let mut truncated = false;
        while left > 0 {
            let take = left.min(READ_SLAB);
            let start = payload.len();
            payload.resize(start + take, 0);
            if stream.read_exact(&mut payload[start..]).is_err() {
                truncated = true;
                break;
            }
            left -= take;
        }
        if truncated {
            shared.on_disconnect(
                src,
                &format!("stream ended inside a {len}-byte frame"),
            );
            return;
        }
        match kind {
            FRAME_DATA => shared.deliver(src, seq, payload),
            FRAME_ABORT => {
                let fault = Fault::decode(&payload).unwrap_or_else(|_| {
                    Fault::comm(
                        src,
                        "exchange",
                        seq,
                        format!("rank {src} sent a malformed abort frame"),
                    )
                });
                shared.record_fault(fault);
            }
            FRAME_BYE => shared.mark_departed(src),
            other => {
                shared.record_fault(Fault::comm(
                    src,
                    "exchange",
                    seq,
                    format!(
                        "rank {src} sent unknown frame type {other} \
                         (stream desynchronized)"
                    ),
                ));
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Rendezvous / handshake
// ---------------------------------------------------------------------

fn hello_frame(world: usize, rank: usize, data_port: u16) -> [u8; 16] {
    let mut b = [0u8; 16];
    b[0..4].copy_from_slice(&HELLO_MAGIC.to_le_bytes());
    b[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    b[6..10].copy_from_slice(&(world as u32).to_le_bytes());
    b[10..14].copy_from_slice(&(rank as u32).to_le_bytes());
    b[14..16].copy_from_slice(&data_port.to_le_bytes());
    b
}

/// Validate a HELLO/ID frame against our own version and world size;
/// returns the peer's `(rank, data_port)`.
fn parse_hello(
    b: &[u8; 16],
    world: usize,
    what: &str,
) -> Result<(usize, u16)> {
    let magic = u32::from_le_bytes(b[0..4].try_into().unwrap());
    if magic != HELLO_MAGIC {
        return Err(RylonError::comm(format!(
            "tcp {what}: bad hello magic {magic:#010x} (expected \
             {HELLO_MAGIC:#010x})"
        )));
    }
    let version = u16::from_le_bytes(b[4..6].try_into().unwrap());
    if version != WIRE_VERSION {
        return Err(RylonError::comm(format!(
            "tcp {what}: peer speaks wire version {version}, this \
             process speaks {WIRE_VERSION}"
        )));
    }
    let peer_world = u32::from_le_bytes(b[6..10].try_into().unwrap());
    if peer_world as usize != world {
        return Err(RylonError::comm(format!(
            "tcp {what}: peer expects world {peer_world}, this process \
             expects {world}"
        )));
    }
    let rank = u32::from_le_bytes(b[10..14].try_into().unwrap()) as usize;
    if rank >= world {
        return Err(RylonError::comm(format!(
            "tcp {what}: peer claims rank {rank} outside world {world}"
        )));
    }
    let port = u16::from_le_bytes(b[14..16].try_into().unwrap());
    Ok((rank, port))
}

/// Accept one connection before `deadline` (polling, since
/// `TcpListener` has no native accept timeout).
fn accept_deadline(
    listener: &TcpListener,
    deadline: Instant,
    what: &str,
) -> Result<(TcpStream, SocketAddr)> {
    listener.set_nonblocking(true).map_err(|e| {
        RylonError::comm(format!("tcp {what}: cannot poll the listener: {e}"))
    })?;
    loop {
        match listener.accept() {
            Ok((s, peer)) => {
                s.set_nonblocking(false).map_err(|e| {
                    RylonError::comm(format!(
                        "tcp {what}: cannot restore blocking mode: {e}"
                    ))
                })?;
                return Ok((s, peer));
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                if Instant::now() >= deadline {
                    return Err(RylonError::comm(format!(
                        "tcp {what}: not every rank connected before \
                         the handshake deadline"
                    )));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                return Err(RylonError::comm(format!(
                    "tcp {what}: accept failed: {e}"
                )))
            }
        }
    }
}

/// Bound handshake reads so one stuck peer cannot park the whole
/// rendezvous past its deadline.
fn arm_handshake(s: &TcpStream, deadline: Instant) {
    s.set_nodelay(true).ok();
    let left = deadline
        .saturating_duration_since(Instant::now())
        .max(Duration::from_millis(10));
    s.set_read_timeout(Some(left)).ok();
}

/// Rank 0: host the rendezvous, collect every peer's HELLO, answer
/// with the address table; the rendezvous connections become the
/// rank-0 data edges. Returns the mesh indexed by peer rank (`None`
/// at slot 0, our own).
fn rendezvous_rank0(
    world: usize,
    addr: &str,
    deadline: Instant,
) -> Result<Vec<Option<TcpStream>>> {
    let listener = TcpListener::bind(addr).map_err(|e| {
        RylonError::comm(format!(
            "tcp rendezvous: rank 0 cannot listen on {addr}: {e}"
        ))
    })?;
    let mut conns: Vec<Option<(TcpStream, String)>> =
        (0..world).map(|_| None).collect();
    for _ in 1..world {
        let (mut s, peer) =
            accept_deadline(&listener, deadline, "rendezvous")?;
        arm_handshake(&s, deadline);
        let mut hello = [0u8; 16];
        s.read_exact(&mut hello).map_err(|e| {
            RylonError::comm(format!(
                "tcp rendezvous: hello from {peer} failed: {e}"
            ))
        })?;
        let (rank, data_port) = parse_hello(&hello, world, "rendezvous")?;
        if rank == 0 {
            return Err(RylonError::comm(
                "tcp rendezvous: a peer claimed rank 0 (rank 0 hosts \
                 the rendezvous)",
            ));
        }
        if conns[rank].is_some() {
            return Err(RylonError::comm(format!(
                "tcp rendezvous: two peers claimed rank {rank}"
            )));
        }
        let data_addr = SocketAddr::new(peer.ip(), data_port).to_string();
        conns[rank] = Some((s, data_addr));
    }
    // WELCOME: header + the data address of every rank 1..world, in
    // rank order, so peers can finish the mesh among themselves.
    let mut welcome = Vec::new();
    welcome.extend_from_slice(&HELLO_MAGIC.to_le_bytes());
    welcome.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    welcome.extend_from_slice(&(world as u32).to_le_bytes());
    for slot in conns.iter().skip(1) {
        let addr = slot.as_ref().map(|(_, a)| a.as_str()).unwrap_or("");
        welcome.extend_from_slice(&(addr.len() as u16).to_le_bytes());
        welcome.extend_from_slice(addr.as_bytes());
    }
    let mut streams: Vec<Option<TcpStream>> =
        (0..world).map(|_| None).collect();
    for (rank, slot) in conns.into_iter().enumerate() {
        let Some((mut s, _)) = slot else { continue };
        s.write_all(&welcome).and_then(|_| s.flush()).map_err(|e| {
            RylonError::comm(format!(
                "tcp rendezvous: welcome to rank {rank} failed: {e}"
            ))
        })?;
        s.set_read_timeout(None).ok();
        streams[rank] = Some(s);
    }
    Ok(streams)
}

/// Rank ≥ 1: bind a data listener, register with rank 0, then build
/// the remaining mesh edges — connect to every lower rank, accept
/// from every higher one.
fn rendezvous_peer(
    world: usize,
    rank: usize,
    rendezvous: &str,
    deadline: Instant,
) -> Result<Vec<Option<TcpStream>>> {
    // The data listener comes first so lower-rank peers can connect
    // the moment the WELCOME tells them the address.
    let listener = TcpListener::bind("0.0.0.0:0").map_err(|e| {
        RylonError::comm(format!(
            "tcp rank {rank}: cannot bind a data listener: {e}"
        ))
    })?;
    let data_port = listener
        .local_addr()
        .map_err(|e| {
            RylonError::comm(format!(
                "tcp rank {rank}: no local address: {e}"
            ))
        })?
        .port();
    // Rank 0 may not be up yet: retry the rendezvous connect until
    // the handshake deadline.
    let mut s = loop {
        match TcpStream::connect(rendezvous) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(RylonError::comm(format!(
                        "tcp rank {rank}: rendezvous {rendezvous} \
                         unreachable before the handshake deadline: {e}"
                    )));
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    };
    arm_handshake(&s, deadline);
    s.write_all(&hello_frame(world, rank, data_port))
        .and_then(|_| s.flush())
        .map_err(|e| {
            RylonError::comm(format!(
                "tcp rank {rank}: hello to the rendezvous failed: {e}"
            ))
        })?;
    let mut head = [0u8; 10];
    s.read_exact(&mut head).map_err(|e| {
        RylonError::comm(format!(
            "tcp rank {rank}: no welcome from the rendezvous: {e}"
        ))
    })?;
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    let version = u16::from_le_bytes(head[4..6].try_into().unwrap());
    let w = u32::from_le_bytes(head[6..10].try_into().unwrap());
    if magic != HELLO_MAGIC || version != WIRE_VERSION {
        return Err(RylonError::comm(format!(
            "tcp rank {rank}: malformed welcome (magic {magic:#010x}, \
             version {version})"
        )));
    }
    if w as usize != world {
        return Err(RylonError::comm(format!(
            "tcp rank {rank}: rendezvous runs world {w}, this process \
             expects {world}"
        )));
    }
    let mut addrs: Vec<String> = vec![String::new(); world];
    for (peer, slot) in addrs.iter_mut().enumerate().skip(1) {
        let mut lb = [0u8; 2];
        s.read_exact(&mut lb).map_err(|e| {
            RylonError::comm(format!(
                "tcp rank {rank}: welcome truncated at rank {peer}: {e}"
            ))
        })?;
        let len = u16::from_le_bytes(lb) as usize;
        if len > 300 {
            return Err(RylonError::comm(format!(
                "tcp rank {rank}: welcome advertises a {len}-byte \
                 address for rank {peer} (malformed)"
            )));
        }
        let mut ab = vec![0u8; len];
        s.read_exact(&mut ab).map_err(|e| {
            RylonError::comm(format!(
                "tcp rank {rank}: welcome truncated at rank {peer}: {e}"
            ))
        })?;
        *slot = String::from_utf8_lossy(&ab).into_owned();
    }
    let mut streams: Vec<Option<TcpStream>> =
        (0..world).map(|_| None).collect();
    s.set_read_timeout(None).ok();
    streams[0] = Some(s);
    // Deterministic mesh completion: connect downward…
    for (peer, addr) in addrs.iter().enumerate().take(rank).skip(1) {
        let mut c = TcpStream::connect(addr.as_str()).map_err(|e| {
            RylonError::comm(format!(
                "tcp rank {rank}: cannot reach rank {peer} at {addr}: {e}"
            ))
        })?;
        c.set_nodelay(true).ok();
        c.write_all(&hello_frame(world, rank, 0))
            .and_then(|_| c.flush())
            .map_err(|e| {
                RylonError::comm(format!(
                    "tcp rank {rank}: hello to rank {peer} failed: {e}"
                ))
            })?;
        streams[peer] = Some(c);
    }
    // …and accept from above.
    for _ in rank + 1..world {
        let (mut c, peer_addr) =
            accept_deadline(&listener, deadline, "mesh")?;
        arm_handshake(&c, deadline);
        let mut id = [0u8; 16];
        c.read_exact(&mut id).map_err(|e| {
            RylonError::comm(format!(
                "tcp rank {rank}: id from {peer_addr} failed: {e}"
            ))
        })?;
        let (peer_rank, _) = parse_hello(&id, world, "mesh")?;
        if peer_rank <= rank {
            return Err(RylonError::comm(format!(
                "tcp rank {rank}: rank {peer_rank} connected against \
                 the mesh order (higher ranks dial lower ones)"
            )));
        }
        if streams[peer_rank].is_some() {
            return Err(RylonError::comm(format!(
                "tcp rank {rank}: two peers claimed rank {peer_rank}"
            )));
        }
        c.set_read_timeout(None).ok();
        streams[peer_rank] = Some(c);
    }
    Ok(streams)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reserve a loopback address for a test rendezvous. The listener
    /// is dropped before use — a benign race, since nothing else on
    /// the host grabs the port in the microseconds before rank 0
    /// rebinds it.
    fn free_rendezvous() -> String {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    }

    /// Run `world` ranks as threads, each with its own `TcpFabric`
    /// over real loopback sockets.
    fn run_tcp_world<F, T>(world: usize, f: F) -> Vec<T>
    where
        F: Fn(usize, TcpFabric) -> T + Send + Sync,
        T: Send,
    {
        let rendezvous = free_rendezvous();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..world)
                .map(|rank| {
                    let rendezvous = rendezvous.clone();
                    let f = &f;
                    s.spawn(move || {
                        let opts = TcpOpts::new(rank, rendezvous);
                        let fab =
                            TcpFabric::connect(world, &opts, None).unwrap();
                        f(rank, fab)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn hello_frame_roundtrip() {
        let b = hello_frame(4, 3, 51234);
        let (rank, port) = parse_hello(&b, 4, "test").unwrap();
        assert_eq!((rank, port), (3, 51234));
        // Wrong world, wrong magic, wrong version all rejected.
        assert!(parse_hello(&b, 5, "test").is_err());
        let mut bad = b;
        bad[0] ^= 0xFF;
        assert!(parse_hello(&bad, 4, "test").is_err());
        let mut bad = b;
        bad[4] ^= 0xFF;
        assert!(parse_hello(&bad, 4, "test").is_err());
    }

    #[test]
    fn world_one_self_delivery() {
        let opts = TcpOpts::new(0, "127.0.0.1:1"); // never dialed
        let fab = TcpFabric::connect(1, &opts, None).unwrap();
        let inc = fab.exchange(0, vec![b"self".to_vec()]).unwrap();
        assert_eq!(inc[0], b"self");
        assert_eq!(fab.bytes_sent(), 4);
    }

    #[test]
    fn exchange_routes_point_to_point_over_sockets() {
        let world = 3;
        let results = run_tcp_world(world, |rank, fab| {
            let mut got = Vec::new();
            for round in 0..5u8 {
                let out: OutBufs = (0..world)
                    .map(|d| vec![round, rank as u8, d as u8])
                    .collect();
                let inc = fab.exchange(rank, out).unwrap();
                for (src, buf) in inc.iter().enumerate() {
                    assert_eq!(
                        buf,
                        &vec![round, src as u8, rank as u8],
                        "round {round}: rank {rank} from {src}"
                    );
                }
                got.push(inc.len());
            }
            got
        });
        assert!(results.iter().all(|r| r.iter().all(|&n| n == world)));
    }

    #[test]
    fn wrong_rank_and_wrong_buffer_count_rejected() {
        let opts = TcpOpts::new(0, "127.0.0.1:1");
        let fab = TcpFabric::connect(1, &opts, None).unwrap();
        assert!(fab.exchange(1, vec![vec![]]).is_err());
        assert!(fab.exchange(0, vec![]).is_err());
    }

    #[test]
    fn severed_peer_faults_survivors_with_attribution() {
        let world = 3;
        let results = run_tcp_world(world, |rank, fab| {
            // One clean round first, so the mesh is known-good.
            fab.exchange(rank, vec![vec![7u8]; world]).unwrap();
            if rank == 1 {
                // Simulated SIGKILL: close every stream, no goodbye.
                fab.sever();
                return Ok(());
            }
            // Survivors park in the next exchange until the EOF
            // surfaces as a synthesized fault.
            fab.exchange(rank, vec![vec![8u8]; world]).map(drop)
        });
        for (rank, r) in results.iter().enumerate() {
            if rank == 1 {
                assert!(r.is_ok());
            } else {
                let e = r.as_ref().unwrap_err();
                let i = e.abort_info().expect("attributed abort");
                assert_eq!(i.rank, 1, "rank {rank} blamed {}", i.rank);
                assert!(
                    e.to_string().contains("rank 1"),
                    "rank {rank} saw: {e}"
                );
            }
        }
    }

    #[test]
    fn graceful_drop_is_not_a_fault() {
        let world = 2;
        let results = run_tcp_world(world, |rank, fab| {
            fab.exchange(rank, vec![vec![1u8]; world]).unwrap();
            // Both fabrics drop at scope exit: BYE frames make the
            // teardown clean on both sides.
            fab.fault()
        });
        assert!(results.iter().all(|f| f.is_none()));
    }

    #[test]
    fn abort_broadcast_reaches_peers() {
        let world = 2;
        let results = run_tcp_world(world, |rank, fab| {
            fab.exchange(rank, vec![vec![0u8]; world]).unwrap();
            if rank == 0 {
                fab.abort(Fault::comm(0, "unit", 1, "rank 0 gave up"));
                return fab.fault().map(|f| f.rank);
            }
            // Rank 1 parks in an exchange rank 0 never joins; the
            // ABORT frame must wake it with rank 0 attributed.
            let e = fab
                .exchange(rank, vec![vec![9u8]; world])
                .expect_err("abort must surface");
            e.abort_info().map(|i| i.rank)
        });
        assert_eq!(results, vec![Some(0), Some(0)]);
    }

    #[test]
    fn collective_timeout_blames_the_silent_rank() {
        let world = 2;
        let timeout = Some(Duration::from_millis(200));
        let rendezvous = free_rendezvous();
        let results: Vec<Option<usize>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..world)
                .map(|rank| {
                    let rendezvous = rendezvous.clone();
                    s.spawn(move || {
                        let opts = TcpOpts::new(rank, rendezvous);
                        let fab =
                            TcpFabric::connect(world, &opts, timeout)
                                .unwrap();
                        if rank == 1 {
                            // Silent: alive (socket open) but never
                            // joins the collective.
                            std::thread::sleep(Duration::from_millis(
                                600,
                            ));
                            return None;
                        }
                        let e = fab
                            .exchange(0, vec![vec![]; world])
                            .expect_err("timeout must fire");
                        e.abort_info().map(|i| i.rank)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results[0], Some(1), "silent rank blamed");
    }

    #[test]
    fn version_mismatch_rejected_at_rendezvous() {
        let rendezvous = free_rendezvous();
        let addr = rendezvous.clone();
        let world = 2;
        std::thread::scope(|s| {
            let host = s.spawn(|| {
                let deadline =
                    Instant::now() + Duration::from_millis(5_000);
                rendezvous_rank0(world, &rendezvous, deadline)
            });
            let peer = s.spawn(move || {
                // Hand-rolled HELLO with a bumped version.
                let deadline = Instant::now() + Duration::from_millis(5_000);
                let mut stream = loop {
                    match TcpStream::connect(&addr) {
                        Ok(c) => break c,
                        Err(_) if Instant::now() < deadline => {
                            std::thread::sleep(Duration::from_millis(10))
                        }
                        Err(e) => panic!("connect: {e}"),
                    }
                };
                let mut hello = hello_frame(world, 1, 1);
                hello[4] = 0xEE;
                stream.write_all(&hello).unwrap();
                // Hold the socket open until the host rejects us.
                let mut buf = [0u8; 1];
                let _ = stream.read(&mut buf);
            });
            let e = host.join().unwrap().unwrap_err();
            assert!(e.to_string().contains("wire version"), "{e}");
            peer.join().unwrap();
        });
    }
}
